"""Benchmarks mirroring the paper's tables/figures.

Fig. 3 (a,c,e)  paradigm_convergence   loss-vs-walltime, 4 paradigms
Fig. 3 (b,d,f)  threshold_sweep        DSSP[3,15] vs SSP s=3..15
Fig. 4/Table I  hetero_time_to_target  mixed-speed cluster, time to loss
§V.C            wait_time_accounting   per-paradigm wait/throughput
(virtual-time rows use the discrete-event simulator — deterministic;
convergence rows run the threaded PS with real jitted steps)
"""

from __future__ import annotations

import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (ModelSpec, OptimizerSpec, RunSpec, ServerSpec,
                       SyncSpec, build_session)
from repro.core.policies import make_policy
from repro.ps.metrics import RunMetrics
from repro.ps.simulator import run_policy


# ------------------------------------------------------------ workloads
def _problem(seed=0, dim=24, n=4096, classes=8):
    """Learnable multinomial-logreg problem (fast, single-core friendly)."""
    rng = np.random.RandomState(seed)
    w_true = rng.randn(dim, classes).astype(np.float32) * 1.5
    x = rng.randn(n, dim).astype(np.float32)
    logits = x @ w_true
    y = np.argmax(logits + rng.gumbel(size=logits.shape), axis=-1)
    return x, y.astype(np.int32), classes


def _step_fn(classes):
    def loss_fn(params, batch):
        x, y = batch
        logits = x @ params["w"] + params["b"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return grads, {"loss": loss}

    return step


def _batches(x, y, worker, n_workers, bs=64, seed=0):
    sx, sy = x[worker::n_workers], y[worker::n_workers]
    rng = np.random.RandomState(seed + worker)
    while True:
        idx = rng.randint(0, len(sx), size=bs)
        yield sx[idx], sy[idx]


def _run_ps(policy_name: str, speed_factors: List[float], iters: int,
            lr: float = 0.2, **pol_kw) -> Tuple[object, float, float]:
    x, y, classes = _problem()
    n = len(speed_factors)
    params = {"w": jnp.zeros((x.shape[1], classes)),
              "b": jnp.zeros((classes,))}
    spec = RunSpec(
        model=ModelSpec(arch="custom"),
        optimizer=OptimizerSpec(lr=lr),
        sync=SyncSpec(mode=policy_name,
                      staleness=pol_kw.get("staleness", 1),
                      s_lower=pol_kw.get("s_lower", 0),
                      s_upper=pol_kw.get("s_upper", 3)),
        ps=ServerSpec(kind="mono", shards=1, workers=n))
    step = _step_fn(classes)
    t0 = time.monotonic()
    with build_session(spec, params=params, step_fn=step,
                       batches=lambda w: _batches(x, y, w, n),
                       speed_factors=list(speed_factors),
                       timeout=600.0) as session:
        session.run(iters * n)
        server = session.server
    wall = time.monotonic() - t0
    # final full-data loss
    logits = x @ np.asarray(server.params["w"]) + np.asarray(
        server.params["b"])
    logp = logits - logits.max(-1, keepdims=True)
    logp = logp - np.log(np.exp(logp).sum(-1, keepdims=True))
    final = float(-logp[np.arange(len(y)), y].mean())
    return server, wall, final


# --------------------------------------------------------------- benches
def paradigm_convergence(rows: List[str], iters: int = 60) -> None:
    """Fig. 3a analogue: homogeneous cluster, loss after fixed iterations."""
    for name, kw in (("bsp", {}), ("asp", {}),
                     ("ssp", {"staleness": 3}),
                     ("dssp", {"s_lower": 3, "s_upper": 15})):
        t0 = time.monotonic()
        server, wall, final = _run_ps(name, [1.0] * 4, iters, **kw)
        us = (time.monotonic() - t0) * 1e6 / (iters * 4)
        m = server.metrics
        rows.append(f"fig3a_{name},{us:.0f},"
                    f"final_loss={final:.4f};throughput={m.throughput:.1f}"
                    f";wait_s={m.total_wait:.2f}"
                    f";max_stale={m.max_staleness}")


def _updates_to_loss(metrics: RunMetrics, target: float) -> Optional[int]:
    """First applied-update count at which the loss hit ``target``."""
    for _, version, loss in metrics.loss_trajectory:
        if loss <= target:
            return version
    return None


def hetero_time_to_target(rows: List[str], iters: int = 60) -> None:
    """Fig. 4 / Table I analogue: one 4x-slower worker (mixed GPUs).

    Methodology note: all PS workers share ONE cpu core here, so
    wall-clock cannot exhibit asynchrony wins.  We therefore measure the
    statistical efficiency (loss vs *applied updates*) on the threaded
    PS with real jitted SGD, the systems efficiency (applied updates vs
    *virtual time*) on the discrete-event simulator with the same speed
    profile and FINITE per-worker iteration budgets (the paper's
    300-epoch setup: fast workers front-load their updates), and compose
    the two into virtual time-to-target — the Table I quantity.
    """
    speeds = [1.0, 1.0, 1.0, 4.0]
    iters_budget = 400
    target = 0.95
    for name, kw in (("bsp", {}), ("asp", {}),
                     ("ssp", {"staleness": 3}),
                     ("dssp", {"s_lower": 3, "s_upper": 15})):
        t0 = time.monotonic()
        server, wall, final = _run_ps(name, speeds, iters, **kw)
        us = (time.monotonic() - t0) * 1e6 / (iters * 4)
        m = server.metrics
        need = _updates_to_loss(m, target)
        # virtual-time schedule with finite budgets (simulator)
        from repro.ps.simulator import PSSimulator, constant_intervals
        pol = make_policy(name, n_workers=4, **kw)
        sim = PSSimulator(pol, 4, constant_intervals(speeds))
        vm = sim.run(max_pushes=iters_budget * 4)
        if need is None:
            vt = None
        else:
            # rescale: threaded run applied iters*4 updates; map the
            # update fraction onto the simulator's update trajectory
            frac = need / (iters * 4)
            vt = vm.time_to_updates(int(frac * vm.applied_updates))
        rows.append(
            f"tableI_{name},{us:.0f},"
            f"vtime_to_{target}={'%.2f' % vt if vt else 'n/a'}"
            f";updates_needed={need};final_loss={final:.4f}"
            f";vthroughput={vm.throughput:.3f}"
            f";max_stale={m.max_staleness}")


def finite_budget_updates(rows: List[str]) -> None:
    """Beyond-paper: with finite per-worker budgets (the paper's fixed
    epoch count), DSSP front-loads the fast workers' updates — virtual
    time to reach N total updates beats SSP(s_L) in a skewed cluster."""
    from repro.ps.simulator import PSSimulator, constant_intervals
    speeds = [1.0, 1.0, 1.0, 4.0]
    budget = 250 * 4
    targets = {}
    for name, kw in (("bsp", {}), ("ssp", {"staleness": 3}),
                     ("dssp", {"s_lower": 3, "s_upper": 15}),
                     ("asp", {})):
        pol = make_policy(name, n_workers=4, **kw)
        sim = PSSimulator(pol, 4, constant_intervals(speeds))
        m = sim.run(max_pushes=budget)
        t_half = m.time_to_updates(budget // 2)
        targets[name] = t_half
        rows.append(f"finite_budget_{name},0,"
                    f"vtime_to_half_updates={t_half:.2f}"
                    f";vtime_all={m.total_time:.2f}"
                    f";wait={m.total_wait:.1f}")


def transient_straggler(rows: List[str]) -> None:
    """Beyond-paper: a worker degrades 4x for a while then recovers (the
    paper's 'unstable environment' future work).  DSSP's controller
    adapts the threshold through the transient; SSP(s_L) pays the wait."""
    from repro.ps.simulator import PSSimulator, phase_shift_intervals

    def intervals():
        return phase_shift_intervals([1.0, 1.0, 1.0, 1.0],
                                     slow_after=100, factor=4.0, worker=3)

    for name, kw in (("ssp", {"staleness": 3}),
                     ("dssp", {"s_lower": 3, "s_upper": 15}),
                     ("bsp", {})):
        pol = make_policy(name, n_workers=4, **kw)
        sim = PSSimulator(pol, 4, intervals())
        m = sim.run(max_pushes=2000)
        rows.append(f"transient_{name},0,"
                    f"vthroughput={m.throughput:.3f}"
                    f";wait={m.total_wait:.1f}"
                    f";mean_stale={m.mean_staleness:.2f}"
                    f";max_stale={m.max_staleness}")


def threshold_sweep(rows: List[str]) -> None:
    """Fig. 3b analogue in virtual time: SSP s grid vs DSSP range."""
    intervals = [1.0, 1.1, 1.3, 2.5]
    for s in (3, 6, 9, 15):
        m = run_policy(make_policy("ssp", staleness=s), intervals,
                       max_pushes=4000)
        rows.append(f"fig3b_ssp_s{s},0,"
                    f"vthroughput={m.throughput:.3f}"
                    f";wait={m.total_wait:.1f}"
                    f";mean_stale={m.mean_staleness:.2f}")
    m = run_policy(make_policy("dssp", s_lower=3, s_upper=15), intervals,
                   max_pushes=4000)
    rows.append(f"fig3b_dssp_3_15,0,"
                f"vthroughput={m.throughput:.3f};wait={m.total_wait:.1f}"
                f";mean_stale={m.mean_staleness:.2f}"
                f";credits={m.credit_releases}")


def wait_time_accounting(rows: List[str]) -> None:
    """§V.C: wait fraction under growing heterogeneity (virtual time)."""
    for skew in (1.0, 2.0, 4.0, 8.0):
        intervals = [1.0, 1.0, 1.0, skew]
        for name, kw in (("bsp", {}), ("ssp", {"staleness": 3}),
                         ("dssp", {"s_lower": 3, "s_upper": 15}),
                         ("backup", {"n_workers": 4, "backups": 1})):
            m = run_policy(make_policy(name, **kw), intervals,
                           max_pushes=3000)
            rows.append(
                f"waitfrac_{name}_skew{skew:g},0,"
                f"wait_frac={m.wait_fraction():.4f}"
                f";vthroughput={m.throughput:.3f}"
                f";dropped={m.dropped_updates}")
