"""Benchmark harness — one bench per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV.  Virtual-time rows (simulator)
report us_per_call=0; threaded-PS rows report wall time per worker
iteration (built through ``repro.api.build_session`` — see
``paper_tables._run_ps``).  Roofline rows are derived from the dry-run
reports (reports/dryrun_*.json, produced by repro.launch.dryrun).
"""

from __future__ import annotations

import sys
import time
from typing import List


def main() -> None:
    t0 = time.monotonic()
    rows: List[str] = []
    from benchmarks import paper_tables, roofline_table, sharded_ps

    paper_tables.threshold_sweep(rows)          # Fig. 3b (virtual time)
    paper_tables.wait_time_accounting(rows)     # §V.C     (virtual time)
    paper_tables.finite_budget_updates(rows)    # Table I systems term
    paper_tables.transient_straggler(rows)      # §VI future-work scenario
    sharded_table = sharded_ps.sharded_comparison(rows)  # shards 1/4/16
    sharded_ps.hot_shard_sweep(rows)            # skewed shard load
    paper_tables.paradigm_convergence(rows)     # Fig. 3a  (threaded PS)
    paper_tables.hetero_time_to_target(rows)    # Table I  (composed)
    roofline_table.csv_rows(rows)               # §Roofline (dry-run)

    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    print("# sharded_ps comparison (RunMetrics.compare):")
    for line in sharded_table.splitlines():
        print(f"# {line}")
    print(f"# total_bench_wall_s={time.monotonic() - t0:.1f}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
