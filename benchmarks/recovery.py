"""Recovery-path microbenchmark: what fault tolerance actually costs.

Measurements over a real sharded server (registry smoke model,
packed fused store), emitted as ``BENCH_recovery.json``:

  1. **snapshot** — per-shard pause imposed by an async snapshot: the
     time each shard's lock is HELD for capture (the window a push
     would queue behind), max and mean over ``--rounds`` snapshots,
     plus the end-to-end capture span.  The design contract is that
     the pause is per-shard and bounded — there is no global
     stop-the-world — so the gate checks ``pause_per_shard_us_max``.
  2. **resume** — wall time of ``restore_latest`` (read the newest
     on-disk snapshot, rebuild packed buffers + trackers + policy +
     metrics) into a fresh server: the dominant term in failover MTTR
     after process respawn.
  3. **reconnect** — wall time for ``--workers`` tcp clients to
     detect a dead listener, back off, and re-HELLO against a
     rebound one on the same port (mean tries per client recorded).
  4. **reshard** (``--reshard``) — live-migration cost S -> S' under
     concurrent pushes: per-shard pause (the copy-out lock hold, from
     ``reshard_shard`` spans), end-to-end migration wall time, and
     the zero-loss ledger (every parked push replayed, every sent
     push applied — the gate requires ``lost == 0``).

Run: ``PYTHONPATH=src python benchmarks/recovery.py [--smoke]
[--reshard]``.  Gate: ``perf_gate.py --recovery BENCH_recovery.json
[--recovery-previous <prior>]``.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import tempfile
import time

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.policies import make_policy_factory
from repro.ft.backoff import BackoffPolicy, retry
from repro.ft.snapshot import (
    ServerSnapshotter,
    restore_latest,
    snapshot_server,
)
from repro.models import registry
from repro.obs.trace import TRACE
from repro.ps.server import ServerOptimizer
from repro.ps.sharded import ShardedParameterServer
from repro.transport import PSServerEndpoint, connect
from repro.transport.tcp import TcpTransport

SCHEMA = "recovery/v1"


def build_server(arch: str, n_shards: int, n_workers: int):
    params = registry.init_params(get_smoke_config(arch),
                                  jax.random.PRNGKey(0))
    return ShardedParameterServer(
        params,
        make_policy_factory("asp", n_workers=n_workers),
        lambda: ServerOptimizer(lr=0.05),
        n_workers, n_shards, apply_mode="fused")


def bench_snapshot(server, rounds: int) -> dict:
    """Per-shard lock-hold pause + full capture span, from obs spans."""
    TRACE.enable(source="bench")
    pauses, spans = [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        snapshot_server(server)
        spans.append(time.perf_counter() - t0)
        for e in TRACE.drain():
            if e.get("name") == "snapshot_shard":
                pauses.append(e["dur"])
    TRACE.disable()
    return {
        "rounds": rounds,
        "shards": len(server.shards),
        "pause_per_shard_us_max": max(pauses) * 1e6,
        "pause_per_shard_us_mean": statistics.fmean(pauses) * 1e6,
        "capture_span_ms_mean": statistics.fmean(spans) * 1e3,
    }


def bench_resume(server, arch: str, ckpt_dir: str) -> dict:
    """restore_latest wall time into a fresh server (disk -> packed)."""
    mgr = CheckpointManager(ckpt_dir, keep=2)
    ServerSnapshotter(server, mgr, every_s=3600.0).save_now()
    mgr.wait()
    fresh = build_server(arch, len(server.shards), 1)
    t0 = time.perf_counter()
    step = restore_latest(fresh, CheckpointManager(ckpt_dir, keep=2))
    restore_s = time.perf_counter() - t0
    return {"restore_ms": restore_s * 1e3, "ok": step == server.version}


def bench_reconnect(server, n_workers: int) -> dict:
    """Dead-listener detection + backoff + re-HELLO on a rebound port."""
    endpoint = PSServerEndpoint(server)
    t1 = TcpTransport("127.0.0.1", 0)
    t1.serve(endpoint)
    addr = t1.address()
    clients = [connect(addr, w) for w in range(n_workers)]
    for c in clients:
        c.hello()
    t1.shutdown()
    # Drop the dead channels so the server-side sockets leave
    # FIN_WAIT_2 (which blocks the rebind even with SO_REUSEADDR) for
    # TIME_WAIT (which does not).  In a real failover the workers do
    # this themselves the moment a request fails.
    for c in clients:
        try:
            c.channel.close()
        except OSError:
            pass

    def rebind():
        t = TcpTransport("127.0.0.1", addr[2])
        t.serve(endpoint)
        return t

    t2 = retry(rebind, BackoffPolicy(base_s=0.05, factor=2.0, max_s=0.5,
                                     max_tries=10))
    pol = BackoffPolicy(base_s=0.02, factor=2.0, max_s=0.2, max_tries=10)
    t0 = time.perf_counter()
    for c in clients:
        c.reconnect(pol, seed=c.worker_id)
    total_s = time.perf_counter() - t0
    for c in clients:
        c.close()
    t2.shutdown()
    return {"workers": n_workers, "total_reconnect_ms": total_s * 1e3,
            "mean_reconnects": statistics.fmean(
                c.reconnects for c in clients)}


def bench_reshard(arch: str, n_shards: int, to_shards: int,
                  n_workers: int, rounds: int) -> dict:
    """Live-migration cost under load: ``--workers`` threads keep
    pushing while the server reshards S -> S'.  Pushes racing the
    migration park-and-replay; the ledger must balance exactly."""
    import threading

    import jax.numpy as jnp
    import numpy as np

    from repro.perfcount import WIRE

    server = build_server(arch, n_shards, n_workers)
    g_tree = jax.tree_util.tree_map(
        lambda p: jnp.ones_like(p), server.params)
    wires: dict = {}
    sent = [0] * n_workers
    start = threading.Barrier(n_workers + 1)

    def pusher(w: int) -> None:
        start.wait()
        for _ in range(rounds):
            # re-grab the live plan each round: pushes packed under the
            # retired plan are inferred by shape and translated
            plan = server.plan
            wire = wires.get(id(plan))
            if wire is None:
                wires[id(plan)] = wire = plan.pack(g_tree)
            server.push_packed(w, wire)
            sent[w] += 1

    TRACE.enable(source="bench")
    WIRE.reset()
    threads = [threading.Thread(target=pusher, args=(w,), daemon=True)
               for w in range(n_workers)]
    for t in threads:
        t.start()
    start.wait()
    time.sleep(0.05)                 # let the push load build up
    t0 = time.perf_counter()
    assert server.reshard(to_shards)
    migration_s = time.perf_counter() - t0
    for t in threads:
        t.join(timeout=300.0)
    pauses = [e["dur"] for e in TRACE.drain()
              if e.get("name") == "reshard_shard"]
    TRACE.disable()
    ev = WIRE.snapshot()
    applied = server.metrics.total_pushes
    version_sum = server.version
    server.stop()
    return {
        "from_shards": n_shards,
        "to_shards": to_shards,
        "workers": n_workers,
        "migration_ms": migration_s * 1e3,
        "pause_per_shard_us_max": max(pauses) * 1e6,
        "pause_per_shard_us_mean": statistics.fmean(pauses) * 1e6,
        "parked": ev["reshard_parked"],
        "replayed": ev["reshard_replayed"],
        "translated": ev["reshard_translated"],
        # both ledgers must read zero: every parked region replayed,
        # every push a worker sent accounted in the server's metrics
        "lost": (ev["reshard_parked"] - ev["reshard_replayed"])
        + (sum(sent) - applied),
        "pushes_sent": sum(sent),
        "pushes_applied": applied,
        "version_sum": int(version_sum),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing: fewer snapshot rounds")
    ap.add_argument("--reshard", action="store_true",
                    help="also measure the live S -> S' migration "
                         "under concurrent pushes")
    ap.add_argument("--reshard-to", type=int, default=0,
                    help="target arity (default: shards + 2)")
    ap.add_argument("--out", default="BENCH_recovery.json")
    args = ap.parse_args()
    if args.smoke:
        args.rounds = 5

    server = build_server(args.arch, args.shards, args.workers)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        report = {
            "schema": SCHEMA,
            "arch": args.arch,
            "snapshot": bench_snapshot(server, args.rounds),
            "resume": bench_resume(server, args.arch, ckpt_dir),
            "reconnect": bench_reconnect(server, args.workers),
        }
    server.stop()
    if args.reshard:
        report["reshard"] = bench_reshard(
            args.arch, args.shards,
            args.reshard_to or args.shards + 2, args.workers,
            rounds=max(4, args.rounds))

    print(json.dumps(report, indent=2, sort_keys=True))
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"\nwrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
