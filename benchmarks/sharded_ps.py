"""Sharded-PS comparison: shard counts {1, 4, 16} x policies {BSP, SSP,
DSSP} in virtual time (ShardedPSSimulator), the paper's heterogeneous
4-worker profile.

Emits the standard CSV rows plus the ``RunMetrics.compare`` table (as
``#``-prefixed comment lines, one aggregate row per (policy, S) cell) so
the Table-I ordering can be read per shard count.  A second sweep prices
skewed shard load — one hot shard with non-zero service time — a
scenario the paper's monolithic server cannot express.
"""

from __future__ import annotations

from typing import List

from repro.api import SyncSpec
from repro.ps.metrics import RunMetrics, compare
from repro.ps.sharded import hot_shard_service, run_sharded_policy

SPEEDS = [1.0, 1.0, 1.0, 4.0]
SHARD_COUNTS = (1, 4, 16)
#: spec-level paradigm grid (the virtual-time face of the same
#: ``SyncSpec`` the sessions build policies from)
POLICIES = (SyncSpec(mode="bsp"),
            SyncSpec(mode="ssp", staleness=3),
            SyncSpec(mode="dssp", s_lower=3, s_upper=15))


def sharded_comparison(rows: List[str], max_pushes: int = 2000) -> str:
    """CSV rows + compare() table for the shards x policies grid."""
    aggregates: List[RunMetrics] = []
    for sync in POLICIES:
        for s in SHARD_COUNTS:
            sim = run_sharded_policy(
                sync.policy_factory(len(SPEEDS)),
                SPEEDS, s, max_pushes=max_pushes)
            m = sim.metrics
            aggregates.append(m)
            per_shard_max = max(sim.max_staleness_per_shard())
            rows.append(
                f"sharded_ps_{sync.mode}_S{s},0,"
                f"vthroughput={m.throughput:.3f}"
                f";wait={m.total_wait:.1f}"
                f";mean_stale={m.mean_staleness:.2f}"
                f";max_stale_any_shard={per_shard_max}")
    return compare(aggregates)


def hot_shard_sweep(rows: List[str], max_pushes: int = 1000) -> None:
    """Skewed shard load: shard 0 costs 0.2 virtual seconds per visit."""
    for sync in POLICIES:
        for s in (4, 16):
            sim = run_sharded_policy(
                sync.policy_factory(len(SPEEDS)),
                SPEEDS, s, max_pushes=max_pushes,
                shard_service_fn=hot_shard_service(0, 0.2))
            m = sim.metrics
            rows.append(
                f"sharded_ps_hot0_{sync.mode}_S{s},0,"
                f"vthroughput={m.throughput:.3f}"
                f";wait={m.total_wait:.1f}"
                f";max_stale_any_shard={max(sim.max_staleness_per_shard())}")
