"""Transport throughput: pushes/sec and bytes/sec per backend x paradigm.

Each cell runs W workers against one fused-mode sharded server behind a
``PSServerEndpoint``:

  * backend   in {inproc, tcp, shmem} — inproc runs the worker loops on
    threads (full frame codec, no OS transport: the serialization
    baseline); tcp/shmem SPAWN real worker processes,
  * paradigm  in {bsp, ssp, dssp} — the sync policy gating every push,
  * compress  in {none, int8} — frame-level wire compression (the
    transport axis; server-side error-feedback compression is the
    ``push_pull_latency`` benchmark's axis).

Workers rendezvous on a ready-event after HELLO so spawn/import time is
excluded; each worker times its own pull+push loop and the cell's wall
time is the slowest worker (the barrier semantics make that the honest
number).  Bytes/sec comes from the parent-side ``repro.perfcount``
TRANSPORT counters — server rx (push frames in) + tx (pull replies
out), so every backend is counted at the same boundary.

Emits machine-readable ``BENCH_transport.json`` plus the standard
``name,us_per_call,derived`` CSV on stdout.  ``--smoke`` (CI) runs the
tcp + shmem backends with a tiny model and few pushes.

Keep this module import-light: spawned workers re-import it as
``__main__``, and they only need numpy + the frame codec (jax stays a
server-side import inside ``main``).
"""

from __future__ import annotations

import argparse
import json
import multiprocessing
import queue as queue_mod
import threading
import time
from typing import Dict, List

import numpy as np

from repro.api import (ModelSpec, OptimizerSpec, RunSpec, ServerSpec,
                       SyncSpec, TransportSpec, WireSpec, build_session)
from repro.perfcount import TRANSPORT
from repro.transport import connect
from repro.wireformat import HEADER_SIZE, WIRE_LANES


def _bench_worker(address, worker_id: int, n_pushes: int, rows: int,
                  compress: str, ready, queue) -> None:
    """One worker's pull+push loop (runs in a thread or a spawned
    process — jax-free either way).  Reports ("ready", w) once its
    connection is live, then waits for the start event so spawn/import
    time stays out of the measured loop."""
    try:
        client = connect(address, worker_id, compress=compress)
        client.hello()
        rng = np.random.RandomState(1000 + worker_id)
        grads = rng.randn(rows, WIRE_LANES).astype(np.float32)
        queue.put(("ready", worker_id, 0, 0.0, None))
        ready.wait(timeout=120.0)
        t0 = time.monotonic()
        done = 0
        for _ in range(n_pushes):
            if client.pull_packed(copy=False) is None:
                break
            if not client.push_packed(grads):
                done += 1
                break
            done += 1
        elapsed = time.monotonic() - t0
        client.bye()
        client.close()
        queue.put(("done", worker_id, done, elapsed, None))
    except BaseException as e:  # surfaced by the parent
        queue.put(("done", worker_id, 0, 0.0, repr(e)))


def _make_session(params, backend: str, paradigm: str, n_workers: int,
                  n_shards: int):
    """Server + endpoint + transport, declaratively: the bench drives
    its own clients, so the session is built external-workers."""
    spec = RunSpec(
        model=ModelSpec(arch="custom"),
        optimizer=OptimizerSpec(name="momentum", lr=0.01, momentum=0.9),
        sync=SyncSpec(mode=paradigm, staleness=2, s_lower=1, s_upper=3),
        ps=ServerSpec(kind="sharded", shards=n_shards,
                      workers=n_workers, apply="fused"),
        wire=WireSpec(format="packed"),
        transport=TransportSpec(kind=backend, endpoint=True))
    return build_session(spec, params=params,
                         external_workers=True).start()


def bench_cell(params, backend: str, paradigm: str, compress: str,
               n_workers: int, n_pushes: int,
               n_shards: int) -> Dict[str, object]:
    session = _make_session(params, backend, paradigm, n_workers,
                            n_shards)
    server = session.server
    rows = server.plan.wire_layout().total_rows

    if backend == "inproc":
        ready = threading.Event()
        queue = queue_mod.Queue()
        runners = [threading.Thread(
            target=_bench_worker,
            args=(session.address(), w, n_pushes, rows, compress,
                  ready, queue),
            daemon=True) for w in range(n_workers)]
    else:
        ctx = multiprocessing.get_context("spawn")
        ready = ctx.Event()
        queue = ctx.Queue()
        runners = [ctx.Process(
            target=_bench_worker,
            args=(session.address(), w, n_pushes, rows, compress,
                  ready, queue),
            daemon=True) for w in range(n_workers)]

    before = TRANSPORT.snapshot()
    for r in runners:
        r.start()
    # Rendezvous: every worker sends exactly one pre-start message —
    # "ready", or "done"-with-error if it died before the start line —
    # so this loop terminates either way and the real error surfaces
    # below instead of deadlocking the ready.wait.
    results, n_ready = [], 0
    while n_ready + len(results) < n_workers:
        tag, w, done, elapsed, err = queue.get(timeout=300.0)
        if tag == "ready":
            n_ready += 1
        else:
            results.append((w, done, elapsed, err))
    ready.set()
    while len(results) < n_workers:
        tag, w, done, elapsed, err = queue.get(timeout=300.0)
        if tag == "done":
            results.append((w, done, elapsed, err))
    for r in runners:
        r.join(timeout=30.0)
    session.close()
    delta = TRANSPORT.delta(before)

    errors = [e for _, _, _, e in results if e]
    if errors:
        raise RuntimeError(f"{backend}/{paradigm}: worker failed: "
                           f"{errors[0]}")
    pushes = sum(d for _, d, _, _ in results)
    wall = max(t for _, _, t, _ in results)
    payload = rows * WIRE_LANES * (1 if compress == "int8" else 4)
    # For tcp/shmem the clients live in child processes, so the parent's
    # counters see exactly the server boundary: one rx per request, one
    # tx per reply.  inproc clients share the parent's process-global
    # counters, double-counting every frame (client encode + server
    # decode, server encode + client decode) — halve to keep the
    # backends comparable at the same boundary.
    total_bytes = delta["bytes_rx"] + delta["bytes_tx"]
    frames_rx = delta["frames_rx"]
    if backend == "inproc":
        total_bytes //= 2
        frames_rx //= 2
    return {
        "backend": backend, "paradigm": paradigm, "compress": compress,
        "n_workers": n_workers, "n_pushes": pushes, "wire_rows": rows,
        "push_frame_bytes": HEADER_SIZE + payload,
        "wall_s": wall,
        "pushes_per_sec": pushes / wall if wall else 0.0,
        "server_bytes_per_sec": total_bytes / wall if wall else 0.0,
        "server_frames": frames_rx,
        "header_rejects": delta["header_rejects"],
    }


def _bench_tree(scale: int):
    """Small tail-heavy tree (a couple of matrices + small leaves) —
    enough rows that frame size matters, small enough for CI."""
    import jax.numpy as jnp

    rng = np.random.RandomState(0)
    tree = {}
    for i in range(2):
        tree[f"w{i}"] = jnp.asarray(
            rng.randn(64 * scale, 128).astype(np.float32))
    for i in range(6 * scale):
        tree[f"b{i}"] = jnp.asarray(rng.randn(64).astype(np.float32))
    return tree


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tcp+shmem, tiny model, few pushes (CI)")
    ap.add_argument("--backends", nargs="*", default=None,
                    choices=["inproc", "tcp", "shmem"])
    ap.add_argument("--paradigms", nargs="*", default=None,
                    choices=["bsp", "ssp", "dssp"])
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--pushes", type=int, default=None)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--out", default="BENCH_transport.json")
    args = ap.parse_args()

    backends = args.backends or (["tcp", "shmem"] if args.smoke
                                 else ["inproc", "tcp", "shmem"])
    paradigms = args.paradigms or ["bsp", "ssp", "dssp"]
    n_workers = args.workers or (2 if args.smoke else 4)
    n_pushes = args.pushes or (6 if args.smoke else 40)
    params = _bench_tree(1 if args.smoke else 4)

    rows: List[Dict[str, object]] = []
    for backend in backends:
        for paradigm in paradigms:
            for compress in ("none", "int8"):
                rows.append(bench_cell(params, backend, paradigm, compress,
                                       n_workers, n_pushes, args.shards))

    def _cell(backend, paradigm, compress):
        for r in rows:
            if (r["backend"], r["paradigm"],
                    r["compress"]) == (backend, paradigm, compress):
                return r
        return None

    derived: Dict[str, object] = {}
    base = _cell(backends[0], "dssp", "none")
    comp = _cell(backends[0], "dssp", "int8")
    if base and comp:
        # int8 frames are 4x smaller; pushed frames/sec should not pay
        # 4x for it — the compression axis the paper's DCN hop needs.
        derived["int8_frame_shrink"] = (base["push_frame_bytes"]
                                        / comp["push_frame_bytes"])
    if _cell("shmem", "dssp", "none") and _cell("tcp", "dssp", "none"):
        derived["shmem_vs_tcp_push_rate"] = (
            _cell("shmem", "dssp", "none")["pushes_per_sec"]
            / max(_cell("tcp", "dssp", "none")["pushes_per_sec"], 1e-9))

    report = {
        "bench": "transport_throughput",
        "smoke": args.smoke,
        "n_workers": n_workers,
        "n_pushes_per_worker": n_pushes,
        "rows": rows,
        "derived": derived,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, default=float, allow_nan=False)

    print("name,us_per_call,derived")
    for r in rows:
        name = f"transport_{r['backend']}_{r['paradigm']}_{r['compress']}"
        us = (1e6 * r["wall_s"] / r["n_pushes"]) if r["n_pushes"] else 0.0
        print(f"{name},{us:.0f},"
              f"pushes_per_sec={r['pushes_per_sec']:.1f}"
              f";mb_per_sec={r['server_bytes_per_sec'] / 1e6:.2f}")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
