"""Perf-trajectory gate over the push/pull wire-format benchmark.

CI calls this with the fresh ``BENCH_push_pull.json`` and (when the
download step found one) the previous run's artifact.  Wall time on
shared runners is noise, so the gate is on the *event counts* — the
backend-independent per-push repack/launch numbers the packed format
exists to eliminate:

  1. zero-repack contract (absolute, always checked): the ``packed``
     path performs 0 host-side repack events per push at every shard
     count, and the derived ``target_met`` flag is true;
  2. coalescing contract (absolute): every ``coalesced_W*`` row does
     at most ``shards`` batched-apply launches per round — launch
     count scales with shards, never shards x workers;
  3. delta contract (absolute): every ``delta_W*`` row that advanced
     < 100% of shards ships fewer delta bytes than a full snapshot;
  4. trajectory (only with ``--previous``): for every (path, shards)
     row present in both reports, no gated metric —
     ``repack_events_per_push``, ``pallas_calls_per_push``,
     ``launches_per_round``, ``delta_bytes_per_pull`` — may increase;
     a PR may make the hot path cheaper, never quietly more chatty;
  5. observability (only with ``--obs``, see ``check_obs``): tracing
     off records 0 events and leaves perfcount hot-path deltas
     bitwise-identical to tracing on; the disabled-call cost may not
     regress versus ``--obs-previous``;
  6. serving (only with ``--serving``, see ``check_serving``): zero
     staleness-bound violations and a request stream that was actually
     served from advancing versions; latency/throughput may not blow
     up versus ``--serving-previous``;
  7. kernels (only with ``--kernels``, see ``check_kernels``): every
     registry (op, variant) pair is present, matches its ``ref.py``
     oracle absolutely, and its achieved step time may not blow up
     versus ``--kernels-previous``.

Exit code 1 on any violation (the CI job fails), 0 otherwise.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple

#: Counting events, so exact equality is meaningful; the epsilon only
#: forgives float formatting, not a real extra event.
EPS = 1e-6

#: Rows carry the metrics that apply to their mode; absent ones skip.
GATED_METRICS = ("repack_events_per_push", "pallas_calls_per_push",
                 "launches_per_round", "delta_bytes_per_pull")


def _rows_by_key(report: dict) -> Dict[Tuple[str, int], dict]:
    return {(r["path"], int(r["shards"])): r for r in report["rows"]}


def check(current: dict, previous: dict | None) -> list:
    failures = []
    rows = _rows_by_key(current)
    for (path, shards), row in sorted(rows.items()):
        if path.startswith("packed") and \
                row["repack_events_per_push"] > EPS:
            failures.append(
                f"zero-repack contract broken: {path} at S={shards} does "
                f"{row['repack_events_per_push']:.2f} repack events/push "
                f"(expected 0)")
        if path.startswith("coalesced") and \
                row["launches_per_round"] > shards + EPS:
            failures.append(
                f"coalescing contract broken: {path} at S={shards} does "
                f"{row['launches_per_round']:.2f} apply launches/round "
                f"(expected <= {shards} — one batched launch per shard)")
        if path.startswith("delta") and \
                row.get("advanced_fraction", 1.0) < 1.0 - EPS and \
                row["delta_bytes_per_pull"] >= row["full_bytes_per_pull"]:
            failures.append(
                f"delta contract broken: {path} at S={shards} ships "
                f"{row['delta_bytes_per_pull']:.0f} bytes/pull with only "
                f"{row['advanced_fraction']:.0%} of shards advanced "
                f"(full snapshot is {row['full_bytes_per_pull']:.0f})")
    if not current.get("derived", {}).get("target_met", False):
        failures.append("derived.target_met is false "
                        "(packed vs tree_fused repack target missed)")
    if previous is not None:
        prev_rows = _rows_by_key(previous)
        for key in sorted(set(rows) & set(prev_rows)):
            for metric in GATED_METRICS:
                now = rows[key].get(metric)
                before = prev_rows[key].get(metric)
                if now is None or before is None:
                    continue    # metric does not apply to this mode
                if now > before + EPS:
                    failures.append(
                        f"{key[0]} at S={key[1]}: {metric} regressed "
                        f"{before:.2f} -> {now:.2f}")
    return failures


def check_obs(current: dict, previous: dict | None) -> list:
    """Gate over ``BENCH_obs.json`` (``benchmarks/obs_overhead.py``).

    Absolute: tracing off must record 0 events and leave the hot-path
    perfcount deltas bitwise-identical to the traced run (the recorder
    never adds counted work).  Trajectory: the disabled-call cost may
    not blow up versus the previous artifact (generous bound — shared
    runners are noisy, but a 5x/+200ns jump means someone put real work
    ahead of the early-return).
    """
    failures = []
    if current.get("events_recorded_off", 0) != 0:
        failures.append(
            f"obs contract broken: {current['events_recorded_off']} "
            "events recorded with tracing disabled (expected 0)")
    hot = current.get("hotpath", {})
    if not hot.get("identical", False):
        failures.append(
            "obs contract broken: perfcount hot-path deltas differ "
            "between tracing-off and tracing-on runs "
            f"(off={hot.get('off')} on={hot.get('on')})")
    if previous is not None:
        now = current.get("disabled_ns_per_call")
        before = previous.get("disabled_ns_per_call")
        if now is not None and before is not None \
                and now > max(before * 5.0, before + 200.0):
            failures.append(
                f"disabled TRACE call cost regressed "
                f"{before:.0f}ns -> {now:.0f}ns per call")
        for group in ("wire", "transport"):
            cur_off = hot.get("off", {}).get(group, {})
            prev_off = (previous.get("hotpath", {})
                        .get("off", {}).get(group, {}))
            for k in sorted(set(cur_off) & set(prev_off)):
                if cur_off[k] > prev_off[k] + EPS:
                    failures.append(
                        f"tracing-off hot path got chattier: "
                        f"{group}.{k} {prev_off[k]} -> {cur_off[k]}")
    return failures


def check_recovery(current: dict, previous: dict | None) -> list:
    """Gate over ``BENCH_recovery.json`` (``benchmarks/recovery.py``).

    Absolute: the per-shard snapshot pause is bounded (no global
    stop-the-world hides in the capture path), the on-disk restore
    round-trips (``resume.ok``), and every client reconnected in one
    re-HELLO against an idle rebound listener.  When the report carries
    a ``reshard`` section (``recovery.py --reshard``): ZERO pushes lost
    or double-applied across the live migration, and the per-shard
    copy-out pause stays under the 0.5s acceptance bound.  Trajectory:
    the pause, restore time and migration time may not blow up versus
    the previous artifact (generous bounds — shared runners are noisy,
    but a 5x jump means the capture started holding locks across real
    work).
    """
    failures = []
    snap = current.get("snapshot", {})
    pause = snap.get("pause_per_shard_us_max")
    if pause is None:
        failures.append("recovery report carries no "
                        "snapshot.pause_per_shard_us_max")
    elif pause > 50_000.0:
        failures.append(
            f"snapshot pause contract broken: a shard's lock was held "
            f"{pause:.0f}us for capture (bound 50ms — the per-shard "
            "pause must stay bounded; is capture doing work under the "
            "lock?)")
    if not current.get("resume", {}).get("ok", False):
        failures.append("resume contract broken: restore_latest did not "
                        "round-trip the snapshotted server version")
    mean_rc = current.get("reconnect", {}).get("mean_reconnects")
    if mean_rc is not None and mean_rc > 1.0 + EPS:
        failures.append(
            f"reconnect contract broken: {mean_rc:.2f} reconnects/client "
            "against an idle rebound listener (expected exactly 1)")
    reshard = current.get("reshard")
    if reshard is not None:
        lost = reshard.get("lost")
        if lost is None:
            failures.append("reshard report carries no loss ledger")
        elif lost != 0:
            failures.append(
                f"reshard zero-loss contract broken: ledger reads "
                f"{lost} (parked={reshard.get('parked')} "
                f"replayed={reshard.get('replayed')} "
                f"sent={reshard.get('pushes_sent')} "
                f"applied={reshard.get('pushes_applied')}) — a push "
                "racing the migration was lost or double-applied")
        pause = reshard.get("pause_per_shard_us_max", 0.0)
        if pause > 500_000.0:
            failures.append(
                f"reshard pause contract broken: a shard's lock was "
                f"held {pause:.0f}us for copy-out (bound 0.5s — the "
                "migration must not stop the world)")
    if previous is not None:
        for path_, label in ((("snapshot", "pause_per_shard_us_max"),
                              "per-shard snapshot pause (us)"),
                             (("resume", "restore_ms"),
                              "restore wall time (ms)"),
                             (("reshard", "migration_ms"),
                              "live-reshard migration time (ms)")):
            sec, key = path_
            now = current.get(sec, {}).get(key)
            before = previous.get(sec, {}).get(key)
            if now is not None and before is not None \
                    and now > max(before * 5.0, before + 1000.0):
                failures.append(
                    f"{label} regressed {before:.1f} -> {now:.1f}")
    return failures


def check_serving(current: dict, previous: dict | None) -> list:
    """Gate over ``BENCH_serving.json`` (``benchmarks/serving.py``).

    Absolute: the freshness contract held — ZERO admissions above
    ``serve.staleness_bound`` (a single violation means the gate served
    stale weights), every closed-loop request was served, and the
    replicas decoded against a LIVE store (served versions advanced
    while the workers trained).  Trajectory: decode latency and
    throughput may not blow up versus the previous artifact (generous
    bounds — shared runners are noisy, but a 5x p99 jump means real
    work landed on the admission/decode path).
    """
    failures = []
    serve = current.get("serve", {})
    violations = serve.get("violations")
    if violations is None:
        failures.append("serving report carries no serve.violations")
    elif violations > 0:
        failures.append(
            f"freshness contract broken: {violations} admissions above "
            f"staleness_bound={current.get('staleness_bound')} — the "
            "admission gate served stale weights")
    if serve.get("requests", 0) <= 0:
        failures.append("serving contract broken: no requests were "
                        "served (replicas never came up?)")
    if serve.get("version_max", -1) <= 0:
        failures.append(
            "serving contract broken: served versions never advanced — "
            "replicas decoded a dead store while training ran")
    if serve.get("p99_ms") is None:
        failures.append("serving report carries no serve.p99_ms")
    if previous is not None:
        now_p99 = serve.get("p99_ms")
        before_p99 = previous.get("serve", {}).get("p99_ms")
        if now_p99 is not None and before_p99 is not None \
                and now_p99 > max(before_p99 * 5.0, before_p99 + 1000.0):
            failures.append(
                f"decode p99 latency regressed "
                f"{before_p99:.1f}ms -> {now_p99:.1f}ms")
        now_rps = serve.get("requests_per_s")
        before_rps = previous.get("serve", {}).get("requests_per_s")
        if now_rps is not None and before_rps is not None \
                and before_rps > 0 and now_rps < before_rps / 5.0:
            failures.append(
                f"serving throughput regressed "
                f"{before_rps:.1f} -> {now_rps:.1f} requests/s")
    return failures


#: every (op, variant) pair BENCH_kernels.json must cover — the full
#: registry surface minus ssm_scan's extra associative variant (which is
#: gated too when present, just not required).
REQUIRED_KERNEL_ROWS = tuple(
    (op, variant)
    for op in ("attention", "rmsnorm", "residual_rmsnorm", "ssm_scan")
    for variant in ("pallas", "xla"))

#: oracle parity bound for the f32 benchmark shapes (absolute max |err|).
KERNEL_PARITY_TOL = 5e-3


def check_kernels(current: dict, previous: dict | None) -> list:
    """Gate over ``BENCH_kernels.json`` (``roofline_table.py --kernels``).

    Absolute: every required (op, variant) row is present and its output
    matches the ``kernels/ref.py`` oracle to ``KERNEL_PARITY_TOL`` — a
    registry variant that drifts from the oracle is a wrong answer, not
    a perf problem.  Trajectory: a row's achieved step time may not blow
    up versus the previous artifact (generous bound — CPU interpret-mode
    timings on shared runners are noisy, but a 5x/+1s jump means real
    work landed on the dispatch path); rows or metrics missing from
    either side are skipped, never failed.
    """
    failures = []
    rows = {(r["op"], r["variant"]): r for r in current.get("rows", [])}
    for op, variant in REQUIRED_KERNEL_ROWS:
        if (op, variant) not in rows:
            failures.append(
                f"kernel coverage broken: no ({op}, {variant}) row in "
                "the benchmark report — the registry grid shrank")
    for (op, variant), row in sorted(rows.items()):
        err = row.get("parity_max_err")
        if err is None:
            failures.append(f"kernel row ({op}, {variant}) carries no "
                            "parity_max_err")
        elif err > KERNEL_PARITY_TOL:
            failures.append(
                f"kernel parity broken: {op}={variant} differs from its "
                f"ref.py oracle by {err:.2e} (tol {KERNEL_PARITY_TOL})")
        if row.get("achieved_ms") is None \
                or row.get("predicted_ms") is None:
            failures.append(
                f"kernel row ({op}, {variant}) misses achieved_ms/"
                "predicted_ms (achieved-vs-predicted contract)")
    if not current.get("derived", {}).get("parity_ok", False):
        failures.append("derived.parity_ok is false")
    if previous is not None:
        prev_rows = {(r["op"], r["variant"]): r
                     for r in previous.get("rows", [])}
        for key in sorted(set(rows) & set(prev_rows)):
            now = rows[key].get("achieved_ms")
            before = prev_rows[key].get("achieved_ms")
            if now is not None and before is not None \
                    and now > max(before * 5.0, before + 1000.0):
                failures.append(
                    f"{key[0]}={key[1]}: achieved step time regressed "
                    f"{before:.3f}ms -> {now:.3f}ms")
    return failures


def _load(path: str | None, label: str) -> dict | None:
    if not path:
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"perf-gate: no usable {label} artifact ({e}); "
              "checking absolute contract only")
        return None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?", default=None,
                    help="fresh BENCH_push_pull.json (optional when only "
                         "--recovery is gated, as in the chaos CI job)")
    ap.add_argument("--previous", default=None,
                    help="prior run's artifact (omit on first run)")
    ap.add_argument("--obs", default=None,
                    help="fresh BENCH_obs.json (adds the observability "
                         "overhead gate)")
    ap.add_argument("--obs-previous", default=None,
                    help="prior run's BENCH_obs.json artifact")
    ap.add_argument("--recovery", default=None,
                    help="fresh BENCH_recovery.json (adds the fault-"
                         "tolerance recovery gate)")
    ap.add_argument("--recovery-previous", default=None,
                    help="prior run's BENCH_recovery.json artifact")
    ap.add_argument("--serving", default=None,
                    help="fresh BENCH_serving.json (adds the online-"
                         "serving freshness gate)")
    ap.add_argument("--serving-previous", default=None,
                    help="prior run's BENCH_serving.json artifact")
    ap.add_argument("--kernels", default=None,
                    help="fresh BENCH_kernels.json (adds the kernel-"
                         "registry parity + step-time gate)")
    ap.add_argument("--kernels-previous", default=None,
                    help="prior run's BENCH_kernels.json artifact")
    args = ap.parse_args()
    if args.current is None and args.recovery is None \
            and args.serving is None and args.kernels is None:
        ap.error("nothing to gate: pass BENCH_push_pull.json and/or "
                 "--recovery and/or --serving and/or --kernels")

    failures = []
    previous = None
    if args.current is not None:
        with open(args.current) as f:
            current = json.load(f)
        previous = _load(args.previous, "previous")

        rows = _rows_by_key(current)
        prev_rows = _rows_by_key(previous) if previous else {}
        print(f"{'path':>18} {'S':>3}  gated metrics")
        for (path, shards), row in sorted(rows.items()):
            marks = []
            for metric in GATED_METRICS:
                now = row.get(metric)
                if now is None:
                    continue
                before = prev_rows.get((path, shards), {}).get(metric)
                marks.append(f"{metric}={now:.2f}"
                             + (f" (was {before:.2f})"
                                if before is not None else ""))
            print(f"{path:>18} {shards:>3}  {' '.join(marks)}")
        failures += check(current, previous)

    recovery = _load(args.recovery, "recovery")
    if recovery is not None:
        recovery_prev = _load(args.recovery_previous, "recovery-previous")
        snap = recovery.get("snapshot", {})
        print(f"\nrecovery: pause_max="
              f"{snap.get('pause_per_shard_us_max', 0):.0f}us "
              f"restore={recovery.get('resume', {}).get('restore_ms', 0):.1f}ms "
              f"reconnects/client="
              f"{recovery.get('reconnect', {}).get('mean_reconnects')}")
        rs = recovery.get("reshard")
        if rs is not None:
            print(f"reshard: {rs.get('from_shards')} -> "
                  f"{rs.get('to_shards')} shards "
                  f"migration={rs.get('migration_ms', 0):.1f}ms "
                  f"pause_max={rs.get('pause_per_shard_us_max', 0):.0f}us "
                  f"parked={rs.get('parked')} "
                  f"replayed={rs.get('replayed')} "
                  f"lost={rs.get('lost')}")
        failures += check_recovery(recovery, recovery_prev)
    serving = _load(args.serving, "serving")
    if serving is not None:
        serving_prev = _load(args.serving_previous, "serving-previous")
        sv = serving.get("serve", {})
        print(f"\nserving: requests={sv.get('requests')} "
              f"violations={sv.get('violations')} "
              f"p99={sv.get('p99_ms', 0):.1f}ms "
              f"rps={sv.get('requests_per_s', 0):.1f} "
              f"versions=[{sv.get('version_min')}, "
              f"{sv.get('version_max')}]")
        failures += check_serving(serving, serving_prev)
    kernels = _load(args.kernels, "kernels")
    if kernels is not None:
        kernels_prev = _load(args.kernels_previous, "kernels-previous")
        print(f"\nkernels ({kernels.get('backend')}):")
        for r in kernels.get("rows", []):
            print(f"  {r['op']:>18} {r['variant']:>16}  "
                  f"achieved {r.get('achieved_ms', 0):8.3f}ms  "
                  f"predicted {r.get('predicted_ms', 0):8.4f}ms  "
                  f"parity {r.get('parity_max_err', float('nan')):.2e}")
        failures += check_kernels(kernels, kernels_prev)
    obs = _load(args.obs, "obs")
    if obs is not None:
        obs_prev = _load(args.obs_previous, "obs-previous")
        print(f"\nobs: disabled_instant="
              f"{obs.get('disabled_ns_per_call', 0):.0f}ns/call "
              f"events_off={obs.get('events_recorded_off')} "
              f"hotpath_identical={obs.get('hotpath', {}).get('identical')}")
        failures += check_obs(obs, obs_prev)
    if failures:
        print("\nPERF GATE FAILED:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print("\nperf gate ok"
          + (" (vs previous artifact)" if previous else
             " (no previous artifact; absolute contract only)"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
