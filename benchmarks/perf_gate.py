"""Perf-trajectory gate over the push/pull wire-format benchmark.

CI calls this with the fresh ``BENCH_push_pull.json`` and (when the
download step found one) the previous run's artifact.  Wall time on
shared runners is noise, so the gate is on the *event counts* — the
backend-independent per-push repack/launch numbers the packed format
exists to eliminate:

  1. zero-repack contract (absolute, always checked): the ``packed``
     path performs 0 host-side repack events per push at every shard
     count, and the derived ``target_met`` flag is true;
  2. coalescing contract (absolute): every ``coalesced_W*`` row does
     at most ``shards`` batched-apply launches per round — launch
     count scales with shards, never shards x workers;
  3. delta contract (absolute): every ``delta_W*`` row that advanced
     < 100% of shards ships fewer delta bytes than a full snapshot;
  4. trajectory (only with ``--previous``): for every (path, shards)
     row present in both reports, no gated metric —
     ``repack_events_per_push``, ``pallas_calls_per_push``,
     ``launches_per_round``, ``delta_bytes_per_pull`` — may increase;
     a PR may make the hot path cheaper, never quietly more chatty.

Exit code 1 on any violation (the CI job fails), 0 otherwise.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple

#: Counting events, so exact equality is meaningful; the epsilon only
#: forgives float formatting, not a real extra event.
EPS = 1e-6

#: Rows carry the metrics that apply to their mode; absent ones skip.
GATED_METRICS = ("repack_events_per_push", "pallas_calls_per_push",
                 "launches_per_round", "delta_bytes_per_pull")


def _rows_by_key(report: dict) -> Dict[Tuple[str, int], dict]:
    return {(r["path"], int(r["shards"])): r for r in report["rows"]}


def check(current: dict, previous: dict | None) -> list:
    failures = []
    rows = _rows_by_key(current)
    for (path, shards), row in sorted(rows.items()):
        if path.startswith("packed") and \
                row["repack_events_per_push"] > EPS:
            failures.append(
                f"zero-repack contract broken: {path} at S={shards} does "
                f"{row['repack_events_per_push']:.2f} repack events/push "
                f"(expected 0)")
        if path.startswith("coalesced") and \
                row["launches_per_round"] > shards + EPS:
            failures.append(
                f"coalescing contract broken: {path} at S={shards} does "
                f"{row['launches_per_round']:.2f} apply launches/round "
                f"(expected <= {shards} — one batched launch per shard)")
        if path.startswith("delta") and \
                row.get("advanced_fraction", 1.0) < 1.0 - EPS and \
                row["delta_bytes_per_pull"] >= row["full_bytes_per_pull"]:
            failures.append(
                f"delta contract broken: {path} at S={shards} ships "
                f"{row['delta_bytes_per_pull']:.0f} bytes/pull with only "
                f"{row['advanced_fraction']:.0%} of shards advanced "
                f"(full snapshot is {row['full_bytes_per_pull']:.0f})")
    if not current.get("derived", {}).get("target_met", False):
        failures.append("derived.target_met is false "
                        "(packed vs tree_fused repack target missed)")
    if previous is not None:
        prev_rows = _rows_by_key(previous)
        for key in sorted(set(rows) & set(prev_rows)):
            for metric in GATED_METRICS:
                now = rows[key].get(metric)
                before = prev_rows[key].get(metric)
                if now is None or before is None:
                    continue    # metric does not apply to this mode
                if now > before + EPS:
                    failures.append(
                        f"{key[0]} at S={key[1]}: {metric} regressed "
                        f"{before:.2f} -> {now:.2f}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh BENCH_push_pull.json")
    ap.add_argument("--previous", default=None,
                    help="prior run's artifact (omit on first run)")
    args = ap.parse_args()

    with open(args.current) as f:
        current = json.load(f)
    previous = None
    if args.previous:
        try:
            with open(args.previous) as f:
                previous = json.load(f)
        except (OSError, ValueError) as e:
            print(f"perf-gate: no usable previous artifact ({e}); "
                  "checking absolute contract only")

    rows = _rows_by_key(current)
    prev_rows = _rows_by_key(previous) if previous else {}
    print(f"{'path':>18} {'S':>3}  gated metrics")
    for (path, shards), row in sorted(rows.items()):
        marks = []
        for metric in GATED_METRICS:
            now = row.get(metric)
            if now is None:
                continue
            before = prev_rows.get((path, shards), {}).get(metric)
            marks.append(f"{metric}={now:.2f}"
                         + (f" (was {before:.2f})" if before is not None
                            else ""))
        print(f"{path:>18} {shards:>3}  {' '.join(marks)}")

    failures = check(current, previous)
    if failures:
        print("\nPERF GATE FAILED:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print("\nperf gate ok"
          + (" (vs previous artifact)" if previous else
             " (no previous artifact; absolute contract only)"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
