"""Regenerate the roofline table block inside EXPERIMENTS.md from the
dry-run JSON reports (single-pod terms + multi-pod compile status)."""

from __future__ import annotations

import os
import re

from benchmarks.roofline_table import REPORT_MULTI, load, \
    markdown_table

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC = os.path.join(ROOT, "EXPERIMENTS.md")
START = "<!-- ROOFLINE_TABLE_START -->"
END = "<!-- ROOFLINE_TABLE_END -->"


def multi_pod_summary() -> str:
    rows = load(REPORT_MULTI)
    if not rows:
        return "_multi-pod sweep not yet recorded_"
    ok = sum(1 for r in rows if r["status"] == "ok")
    sk = sum(1 for r in rows if r["status"] == "skipped")
    err = [f"{r['arch']}×{r['shape']}" for r in rows
           if r["status"] == "error"]
    out = [f"**Multi-pod (2×16×16 = 512 chips): {ok} cells compile, "
           f"{sk} skipped by design, {len(err)} failed.**"]
    if err:
        out.append("Failed: " + ", ".join(err))
    return "\n".join(out)


def main() -> None:
    table = markdown_table()
    block = (f"{START}\n\n### Single-pod (16×16) roofline terms\n\n"
             f"{table}\n\n{multi_pod_summary()}\n\n{END}")
    doc = open(DOC).read()
    pattern = re.compile(re.escape(START) + ".*?" + re.escape(END),
                         re.DOTALL)
    assert pattern.search(doc), "markers missing in EXPERIMENTS.md"
    open(DOC, "w").write(pattern.sub(block, doc))
    print(f"EXPERIMENTS.md roofline block updated "
          f"({len(table.splitlines())} rows)")


if __name__ == "__main__":
    main()
