"""Serving-tier benchmark: what the online replicas actually deliver.

One spec-driven run — ``--workers`` tcp training workers push at a
live sharded server while ``--replicas`` serving replicas subscribe,
refresh via version-delta pulls, and decode continuously-batched
Markov prompts behind the ``staleness_bound`` admission gate — emitted
as ``BENCH_serving.json``:

  * **serve** — the consumer-side contract: requests served, decode
    throughput (``requests_per_s``), latency percentiles (p50/p99 ms,
    enqueue -> tokens), admission-staleness histogram/max, and the two
    hard invariants the gate checks: ``violations`` (served staleness
    above the bound — must be 0) and versions that actually advance
    while training runs.
  * **train** — the producer side of the same run (pushes, applied
    updates, final loss): serving must not be measured against an idle
    server.

Run: ``PYTHONPATH=src python benchmarks/serving.py [--smoke]``.
Gate: ``perf_gate.py --serving BENCH_serving.json
[--serving-previous <prior>]``.
"""

from __future__ import annotations

import argparse
import json
import os

SCHEMA = "serving/v1"


def build_spec(args):
    from repro.api import (
        DataSpec,
        ModelSpec,
        RunSpec,
        ServeSpec,
        ServerSpec,
        SyncSpec,
        TransportSpec,
        WireSpec,
    )
    return RunSpec(
        model=ModelSpec(arch=args.arch, smoke=True),
        data=DataSpec(seq_len=args.seq_len, global_batch=args.batch),
        ps=ServerSpec(kind="sharded", shards=args.shards,
                      workers=args.workers, apply="fused"),
        sync=SyncSpec(mode="dssp", s_lower=1, s_upper=4),
        wire=WireSpec(format="packed", delta_pull=True),
        transport=TransportSpec(kind="tcp", endpoint=True),
        serve=ServeSpec(replicas=args.replicas,
                        requests=args.requests,
                        request_every_ms=args.request_every_ms,
                        start_at_version=1,
                        staleness_bound=args.staleness_bound,
                        max_batch=args.max_batch,
                        prompt_len=args.prompt_len,
                        max_new=args.max_new))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--requests", type=int, default=24,
                    help="closed-loop requests per replica")
    ap.add_argument("--request-every-ms", type=float, default=60.0)
    ap.add_argument("--staleness-bound", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing: shorter run, fewer requests")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    if args.smoke:
        args.steps = 30
        args.requests = 8
        args.request_every_ms = 100.0

    from repro.api import build_session
    with build_session(build_spec(args)) as session:
        metrics = session.run(steps=args.steps)

    serve = metrics["serve"]
    report = {
        "schema": SCHEMA,
        "arch": args.arch,
        "workers": args.workers,
        "replicas": args.replicas,
        "staleness_bound": args.staleness_bound,
        "serve": serve,
        "train": {
            "steps": args.steps,
            "pushes": metrics["pushes"],
            "applied_updates": metrics["applied_updates"],
            "final_loss": metrics["final_loss"],
        },
    }

    print(json.dumps(report, indent=2, sort_keys=True))
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"\nwrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
