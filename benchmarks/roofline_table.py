"""Roofline table renderer: reads the dry-run JSON reports and emits the
EXPERIMENTS.md §Roofline table + CSV rows for benchmarks.run.

``--kernels`` (or ``--smoke``) switches to the kernel-registry
benchmark: time every (op, variant) pair the registry dispatches
(``repro.kernels.registry``) against a roofline *prediction* from its
flop/byte counts, check each variant's output against the
``kernels/ref.py`` oracle, and write the ``BENCH_kernels.json`` report
``perf_gate.py --kernels`` gates in CI."""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

REPORT = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                      "reports", "dryrun_single.json")
REPORT_MULTI = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                            "reports", "dryrun_multi.json")


def load(path: str = REPORT) -> List[Dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def csv_rows(rows: List[str]) -> None:
    for r in load():
        if r["status"] != "ok":
            rows.append(f"roofline_{r['arch']}_{r['shape']},0,"
                        f"status={r['status']}")
            continue
        rows.append(
            f"roofline_{r['arch']}_{r['shape']},0,"
            f"dominant={r['dominant']}"
            f";t_comp_ms={r['t_compute'] * 1e3:.2f}"
            f";t_mem_ms={r['t_memory'] * 1e3:.2f}"
            f";t_coll_ms={r['t_collective'] * 1e3:.2f}"
            f";useful={r['useful_flops_ratio']:.3f}"
            f";roofline={r['roofline_fraction']:.4f}"
            f";arg_gib={r['argument_gib_per_chip']:.2f}"
            f";fits={r['fits_hbm']}")


def markdown_table(results: Optional[List[Dict]] = None) -> str:
    results = results if results is not None else load()
    hdr = ("| arch | shape | mesh | t_comp ms | t_mem ms | t_coll ms | "
           "dominant | useful | roofline | arg GiB/chip | fits |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in results:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | skipped (quadratic @500k) | — | — "
                         f"| — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute'] * 1e3:.1f} | {r['t_memory'] * 1e3:.1f} "
            f"| {r['t_collective'] * 1e3:.1f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.4f} "
            f"| {r['argument_gib_per_chip']:.2f} "
            f"| {'yes' if r.get('fits_hbm') else 'NO'} |")
    return "\n".join(lines)


# ===================================================== kernel registry bench
#: order-of-magnitude (flops/s, bytes/s) peaks per backend — the
#: *prediction* side of achieved-vs-predicted.  CPU numbers are
#: deliberately conservative: the point is a stable yardstick for the
#: trajectory gate, not an absolute hardware claim.
_PEAKS = {"tpu": (197e12, 819e9), "cpu": (5e10, 2e10)}


def _kernel_cases(smoke: bool):
    """(op, variants, make_inputs, flops, bytes) per registry op.

    Flop counts are the textbook per-op numbers (2 flops per MAC);
    byte counts assume every operand and result moves HBM<->compute
    exactly once — the roofline lower bound a fused kernel targets.
    """
    import jax
    import jax.numpy as jnp

    if smoke:
        b, l, hq, hkv, d = 1, 128, 4, 2, 64
        rows, dm = 256, 512
        sb, sl, di, ds = 1, 64, 8, 16
    else:
        b, l, hq, hkv, d = 2, 1024, 8, 4, 128
        rows, dm = 4096, 2048
        sb, sl, di, ds = 2, 512, 32, 32

    def attn_inputs(key):
        ks = jax.random.split(key, 3)
        return (jax.random.normal(ks[0], (b, l, hq, d)),
                jax.random.normal(ks[1], (b, l, hkv, d)),
                jax.random.normal(ks[2], (b, l, hkv, d)))

    def norm_inputs(key):
        ks = jax.random.split(key, 3)
        return (jax.random.normal(ks[0], (rows, dm)),
                jax.random.normal(ks[1], (rows, dm)),
                jax.random.normal(ks[2], (dm,)))

    def ssm_inputs(key):
        ks = jax.random.split(key, 5)
        return (jax.random.normal(ks[0], (sb, sl, di)),
                jax.nn.softplus(jax.random.normal(ks[1], (sb, sl, di))),
                -jax.nn.softplus(jax.random.normal(ks[2], (di, ds))),
                jax.random.normal(ks[3], (sb, sl, ds)),
                jax.random.normal(ks[4], (sb, sl, ds)),
                jnp.zeros((sb, di, ds), jnp.float32))

    f32 = 4
    return [
        ("attention", ("pallas", "xla"), attn_inputs,
         4.0 * b * l * l * hq * d,
         f32 * (b * l * hq * d * 2 + b * l * hkv * d * 2)),
        ("rmsnorm", ("pallas", "xla"), norm_inputs,
         3.0 * rows * dm,
         f32 * (2 * rows * dm + dm)),
        ("residual_rmsnorm", ("pallas", "xla"), norm_inputs,
         4.0 * rows * dm,
         f32 * (4 * rows * dm + dm)),
        ("ssm_scan", ("pallas", "xla", "xla_associative"), ssm_inputs,
         8.0 * sb * sl * di * ds,
         f32 * (sb * sl * (2 * di + 2 * ds + di) + di * ds
                + 2 * sb * di * ds)),
    ]


def _time_best_ms(fn, args, iters: int) -> float:
    import jax
    out = jax.block_until_ready(fn(*args))     # compile outside the clock
    del out
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def kernel_bench(smoke: bool = True, iters: int = 3) -> Dict:
    """Achieved vs roofline-predicted step time per (op, variant), plus
    oracle parity — the ``BENCH_kernels.json`` payload."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ref, registry

    backend = jax.default_backend()
    peak_flops, peak_bw = _PEAKS.get(backend, _PEAKS["cpu"])
    oracle = {"attention": lambda *a: ref.flash_attention_ref(*a),
              "rmsnorm": lambda x, r, w: ref.rmsnorm_ref(x, w),
              "residual_rmsnorm":
                  lambda x, r, w: ref.residual_rmsnorm_ref(x, r, w),
              "ssm_scan": lambda *a: ref.ssm_scan_ref(*a)}
    call = {"attention":
                lambda spec: lambda q, k, v: registry.attention(
                    q, k, v, causal=True, kernels=spec),
            "rmsnorm":
                lambda spec: lambda x, r, w: registry.rmsnorm(
                    x, w, kernels=spec),
            "residual_rmsnorm":
                lambda spec: lambda x, r, w: registry.residual_rmsnorm(
                    x, r, w, kernels=spec),
            "ssm_scan":
                lambda spec: lambda *a: registry.ssm_scan(
                    *a, chunk=32, kernels=spec)}

    rows = []
    key = jax.random.PRNGKey(0)
    for op, variants, make_inputs, flops, bytes_ in _kernel_cases(smoke):
        args = make_inputs(key)
        want = jax.tree_util.tree_leaves(oracle[op](*args))
        predicted_ms = max(flops / peak_flops, bytes_ / peak_bw) * 1e3
        for variant in variants:
            fn = jax.jit(call[op](f"{op}={variant}"))
            got = jax.tree_util.tree_leaves(fn(*args))
            err = max(float(jnp.max(jnp.abs(
                g.astype(jnp.float32) - w.astype(jnp.float32))))
                for g, w in zip(got, want))
            achieved = _time_best_ms(fn, args, iters)
            rows.append({
                "op": op, "variant": variant,
                "achieved_ms": achieved,
                "predicted_ms": predicted_ms,
                "roofline_fraction": predicted_ms / max(achieved, 1e-9),
                "flops": flops, "bytes": bytes_,
                "parity_max_err": err,
                "resolved_auto":
                    registry.resolved(op).name.lower() == variant,
            })
            print(f"{op:>18} {variant:>16}  achieved {achieved:8.3f}ms  "
                  f"predicted {predicted_ms:8.4f}ms  parity {err:.2e}")
    return {"backend": backend, "smoke": smoke, "iters": iters,
            "peak_flops": peak_flops, "peak_bytes_per_s": peak_bw,
            "rows": rows,
            "derived": {"parity_ok":
                        all(r["parity_max_err"] <= 5e-3 for r in rows)}}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--kernels", action="store_true",
                    help="run the kernel-registry benchmark instead of "
                         "rendering the dry-run roofline table")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few iters (CI; implies --kernels)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default="BENCH_kernels.json",
                    help="kernel benchmark report path")
    args = ap.parse_args()
    if args.kernels or args.smoke:
        report = kernel_bench(smoke=args.smoke, iters=args.iters)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"wrote {args.out} ({len(report['rows'])} rows, "
              f"backend={report['backend']})")
        return
    print(markdown_table())


if __name__ == "__main__":
    main()
