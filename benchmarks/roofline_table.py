"""Roofline table renderer: reads the dry-run JSON reports and emits the
EXPERIMENTS.md §Roofline table + CSV rows for benchmarks.run."""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

REPORT = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                      "reports", "dryrun_single.json")
REPORT_MULTI = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                            "reports", "dryrun_multi.json")


def load(path: str = REPORT) -> List[Dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def csv_rows(rows: List[str]) -> None:
    for r in load():
        if r["status"] != "ok":
            rows.append(f"roofline_{r['arch']}_{r['shape']},0,"
                        f"status={r['status']}")
            continue
        rows.append(
            f"roofline_{r['arch']}_{r['shape']},0,"
            f"dominant={r['dominant']}"
            f";t_comp_ms={r['t_compute'] * 1e3:.2f}"
            f";t_mem_ms={r['t_memory'] * 1e3:.2f}"
            f";t_coll_ms={r['t_collective'] * 1e3:.2f}"
            f";useful={r['useful_flops_ratio']:.3f}"
            f";roofline={r['roofline_fraction']:.4f}"
            f";arg_gib={r['argument_gib_per_chip']:.2f}"
            f";fits={r['fits_hbm']}")


def markdown_table(results: Optional[List[Dict]] = None) -> str:
    results = results if results is not None else load()
    hdr = ("| arch | shape | mesh | t_comp ms | t_mem ms | t_coll ms | "
           "dominant | useful | roofline | arg GiB/chip | fits |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in results:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | skipped (quadratic @500k) | — | — "
                         f"| — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute'] * 1e3:.1f} | {r['t_memory'] * 1e3:.1f} "
            f"| {r['t_collective'] * 1e3:.1f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.4f} "
            f"| {r['argument_gib_per_chip']:.2f} "
            f"| {'yes' if r.get('fits_hbm') else 'NO'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
