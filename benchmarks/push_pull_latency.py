"""Push/pull hot-path microbenchmark: tree wire format vs packed.

Measures the SERVER-side cost of one push / one pull through the
sharded parameter server, per wire format:

  * ``tree``        apply_mode=tree   — per-leaf optimizer step,
  * ``tree_fused``  apply_mode=fused  — one kernel launch per shard but
                    a ``pack_shard`` (concat) per shard per push,
  * ``packed``      push_packed       — the zero-repack path: the wire
                    buffer is sliced into per-shard views, no packing,
  * ``*+int8``      the same with wire compression (per-leaf tree_map
                    dispatches vs ONE fused launch per shard),
  * ``coalesced_W{N}``  N concurrent workers pushing into a coalescing
                    window of N: one ``fused_update_batched`` launch
                    per shard per ROUND instead of per push —
                    ``launches_per_round`` is the gated contract,
  * ``delta_W{N}``  N workers each advancing one shard, then one
                    version-delta pull: ``delta_bytes_per_pull`` vs
                    ``full_bytes_per_pull`` (bytes ∝ change).

Wall time on this container is interpret-mode dominated and mostly
meaningless; the *event counts* (``repro.perfcount``) are
backend-independent and are what the packed format eliminates:
``repack_events`` = packs + unpacks + per-leaf concats per push.  The
acceptance target (>= 2x lower per-push overhead at S=16 on the tail of
small leaves) is checked on that metric.

Emits machine-readable ``BENCH_push_pull.json`` plus the standard
``name,us_per_call,derived`` CSV on stdout.  ``--smoke`` runs a tiny
model + few pushes for the tier-1 CI workflow.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (ModelSpec, OptimizerSpec, RunSpec, ServerSpec,
                       SyncSpec, WireSpec, build_session)
from repro.perfcount import WIRE


def tail_heavy_tree(scale: int = 1) -> Dict[str, jax.Array]:
    """A few big matrices + a long tail of small leaves (biases, norms,
    per-layer scalars) — the shape profile where per-leaf dispatch
    overhead dominates the update phase."""
    rng = np.random.RandomState(0)
    tree: Dict[str, jax.Array] = {}
    for i in range(2 * scale):
        tree[f"w{i}"] = jnp.asarray(
            rng.randn(256 * scale, 128).astype(np.float32))
    for i in range(24 * scale):           # the tail
        tree[f"b{i}"] = jnp.asarray(rng.randn(64).astype(np.float32))
        tree[f"g{i}"] = jnp.asarray(rng.randn(8, 8).astype(np.float32))
        tree[f"s{i}"] = jnp.float32(rng.randn())
    return tree


def _grads_like(tree, seed: int):
    rng = np.random.RandomState(seed)
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.randn(*p.shape).astype(np.float32))
        if p.shape else jnp.float32(rng.randn()), tree)


def _session(params, n_shards: int, apply_mode: str,
             wire_format: str = "tree", compression: str = "none",
             workers: int = 1, coalesce: int = 1,
             coalesce_wait_ms=None, delta_pull: bool = False):
    """One externally-driven session per measured path: the spec picks
    the wire/apply/compression combination, the bench pushes payloads
    at the session's server directly."""
    spec = RunSpec(
        model=ModelSpec(arch="custom"),
        optimizer=OptimizerSpec(name="momentum", lr=0.01, momentum=0.9),
        sync=SyncSpec(mode="asp"),
        ps=ServerSpec(kind="sharded", shards=n_shards, workers=workers,
                      apply=apply_mode, coalesce=coalesce,
                      coalesce_wait_ms=coalesce_wait_ms),
        wire=WireSpec(format=wire_format, compression=compression,
                      delta_pull=delta_pull))
    return build_session(spec, params=params,
                         external_workers=True).start()


def _block_tree(tree):
    jax.block_until_ready(jax.tree_util.tree_leaves(tree))


def bench_path(params, grads_seq, n_shards: int, path: str,
               n_pushes: int) -> Dict[str, object]:
    compress = path.endswith("+int8")
    base = path[:-5] if compress else path
    if base == "packed":
        session = _session(params, n_shards, "fused",
                           wire_format="packed",
                           compression="int8" if compress else "none")
        server = session.server
        payloads = [server.plan.pack(g) for g in grads_seq]
    else:
        session = _session(params, n_shards,
                           "fused" if base == "tree_fused" else "tree",
                           compression="int8" if compress else "none")
        server = session.server
        payloads = list(grads_seq)
    push = server.push_packed if base == "packed" else server.push
    pull = (server.pull_packed if base == "packed" else server.pull)

    def block_server():
        # Drain device work without touching the counted wire APIs.
        for st in server.shards:
            jax.block_until_ready(st._pieces if st._pieces is not None
                                  else st._packed_p)

    push(0, payloads[0])                      # warm up compile caches
    pull(0)
    block_server()

    WIRE.reset()
    t0 = time.monotonic()
    for i in range(n_pushes):
        push(0, payloads[(i + 1) % len(payloads)])
    block_server()
    push_wall = time.monotonic() - t0
    push_events = WIRE.snapshot()

    pull_wall = 0.0
    pull_events = {k: 0 for k in push_events}
    for i in range(n_pushes):
        push(0, payloads[i % len(payloads)])  # invalidate snapshot caches
        block_server()
        before = WIRE.snapshot()
        t0 = time.monotonic()
        out = pull(0)
        _block_tree(out)
        pull_wall += time.monotonic() - t0
        for k, v in WIRE.delta(before).items():
            pull_events[k] += v

    def per(ev):
        return {k: v / n_pushes for k, v in ev.items()}

    pe, le = per(push_events), per(pull_events)
    repack = pe["packs"] + pe["unpacks"] + pe["leaf_concats"]
    session.close()
    return {
        "path": path, "shards": n_shards, "n_pushes": n_pushes,
        "push_ms": 1e3 * push_wall / n_pushes,
        "pull_ms": 1e3 * pull_wall / n_pushes,
        "per_push": pe,
        "per_pull": le,
        "repack_events_per_push": repack,
        "pallas_calls_per_push": pe["pallas_calls"],
    }


def bench_coalesced(params, grads_seq, n_shards: int, workers: int,
                    n_rounds: int) -> Dict[str, object]:
    """W concurrent pushers into a coalescing window of W: the gated
    contract is ``launches_per_round == n_shards`` (one batched launch
    per shard per round, not per push)."""
    import threading

    # A generous linger makes the round deterministic on loaded CI
    # runners: the flusher waits for all W contributors (they are all
    # pushing concurrently) instead of racing the scheduler.
    session = _session(params, n_shards, "fused", wire_format="packed",
                       workers=workers, coalesce=workers,
                       coalesce_wait_ms=5000.0 if workers > 1 else 0.0)
    server = session.server
    wires = [server.plan.pack(g) for g in grads_seq]

    def round_once(measure_idx: int):
        threads = [threading.Thread(
            target=server.push_packed,
            args=(w, wires[(measure_idx + w) % len(wires)]))
            for w in range(workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    round_once(0)                           # warm up compile caches
    for st in server.shards:
        jax.block_until_ready(st._packed_p)
    WIRE.reset()
    t0 = time.monotonic()
    for i in range(n_rounds):
        round_once(i + 1)
    for st in server.shards:
        jax.block_until_ready(st._packed_p)
    wall = time.monotonic() - t0
    ev = WIRE.snapshot()
    session.close()
    return {
        "path": f"coalesced_W{workers}", "shards": n_shards,
        "workers": workers, "n_rounds": n_rounds,
        "round_ms": 1e3 * wall / n_rounds,
        "launches_per_round": ev["pallas_calls"] / n_rounds,
        "launches_saved_per_round": ev["apply_launches_saved"] / n_rounds,
        "uncoalesced_launches_per_round": n_shards * workers,
    }


def bench_delta(params, grads_seq, n_shards: int, workers: int,
                n_pulls: int) -> Dict[str, object]:
    """W workers each advance one shard (w mod S), then one
    version-delta pull: bytes shipped vs the full snapshot."""
    session = _session(params, n_shards, "fused", wire_format="packed",
                       workers=workers, delta_pull=True)
    server = session.server
    layout = server.plan.wire_layout()
    itemsize = jnp.dtype(layout.dtype).itemsize
    full_bytes = layout.total_rows * 512 * itemsize
    shard_wires = [server.plan.shard_wires(server.plan.pack(g))
                   for g in grads_seq]
    touched = sorted({w % n_shards for w in range(workers)})

    d = server.pull_delta(0, None)          # bootstrap: full fallback
    versions = d.versions
    WIRE.reset()
    t0 = time.monotonic()
    for i in range(n_pulls):
        for w in range(workers):
            j = w % n_shards
            server.push_packed_shard(w, j,
                                     shard_wires[i % len(shard_wires)][j])
        d = server.pull_delta(0, versions)
        versions = d.versions
    wall = time.monotonic() - t0
    ev = WIRE.snapshot()
    session.close()
    delta_bytes = ev["delta_bytes_tx"] / n_pulls
    return {
        "path": f"delta_W{workers}", "shards": n_shards,
        "workers": workers, "n_pulls": n_pulls,
        "pull_ms": 1e3 * wall / n_pulls,
        "delta_bytes_per_pull": delta_bytes,
        "full_bytes_per_pull": full_bytes,
        "advanced_fraction": len(touched) / n_shards,
        "bytes_fraction": delta_bytes / full_bytes,
        "full_pull_bytes_avoided_per_pull":
            ev["full_pull_bytes_avoided"] / n_pulls,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny tree + few pushes (CI tier-1)")
    ap.add_argument("--shards", type=int, nargs="*", default=None)
    ap.add_argument("--pushes", type=int, default=None)
    ap.add_argument("--workers", type=int, nargs="*", default=None,
                    help="worker counts for the coalesced/delta modes")
    ap.add_argument("--out", default="BENCH_push_pull.json")
    args = ap.parse_args()

    scale = 1 if args.smoke else 2
    shard_counts = args.shards or ([1, 4] if args.smoke else [1, 4, 16])
    worker_counts = args.workers or ([1, 4] if args.smoke else [1, 4, 8])
    n_pushes = args.pushes or (3 if args.smoke else 10)
    params = tail_heavy_tree(scale)
    n_leaves = len(jax.tree_util.tree_leaves(params))
    grads_seq = [_grads_like(params, s) for s in range(2)]

    paths = ["tree", "tree_fused", "packed", "tree_fused+int8",
             "packed+int8"]
    rows: List[Dict[str, object]] = []
    for s in shard_counts:
        for path in paths:
            rows.append(bench_path(params, grads_seq, s, path, n_pushes))

    # Coalesced-apply + version-delta modes: fixed shard count, swept
    # worker count (the axes the tentpole moves).
    cd_shards = min(4, max(shard_counts))
    for w in worker_counts:
        rows.append(bench_coalesced(params, grads_seq, cd_shards, w,
                                    n_pushes))
        rows.append(bench_delta(params, grads_seq, cd_shards, w,
                                n_pushes))

    # Derived acceptance metric: packed vs tree_fused repack overhead at
    # the largest shard count.
    s_max = max(shard_counts)
    by = {r["path"]: r for r in rows if r["shards"] == s_max}
    fused_ov = by["tree_fused"]["repack_events_per_push"]
    packed_ov = by["packed"]["repack_events_per_push"]
    ratio = fused_ov / max(packed_ov, 1e-9)
    co_rows = [r for r in rows if r["path"].startswith("coalesced")]
    de_rows = [r for r in rows if r["path"].startswith("delta")]
    coalesced_ok = all(r["launches_per_round"] <= r["shards"] + 1e-6
                       for r in co_rows)
    delta_ok = all(r["delta_bytes_per_pull"] < r["full_bytes_per_pull"]
                   for r in de_rows if r["advanced_fraction"] < 1.0)
    report = {
        "bench": "push_pull_latency",
        "smoke": args.smoke,
        "n_leaves": n_leaves,
        "total_params": int(sum(
            x.size for x in jax.tree_util.tree_leaves(params))),
        "shard_counts": shard_counts,
        "rows": rows,
        "derived": {
            "s_max": s_max,
            "repack_events_per_push_tree_fused": fused_ov,
            "repack_events_per_push_packed": packed_ov,
            # null = packed path did zero repacks (ratio undefined/infinite);
            # kept strict-JSON-parseable for downstream consumers.
            "repack_overhead_ratio": (ratio if packed_ov > 0 else None),
            "target_met": packed_ov == 0 or ratio >= 2.0,
            # coalescing contract: batched-apply launches per round
            # scale with shards, not shards x workers
            "coalesced_target_met": coalesced_ok,
            # delta contract: pull bytes < full snapshot when < 100%
            # of shards advanced
            "delta_target_met": delta_ok,
        },
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, default=float, allow_nan=False)

    print("name,us_per_call,derived")
    for r in rows:
        if r["path"].startswith("coalesced"):
            print(f"push_pull_{r['path']}_S{r['shards']},"
                  f"{1e3 * r['round_ms']:.0f},"
                  f"launches_per_round={r['launches_per_round']:.1f}"
                  f";uncoalesced={r['uncoalesced_launches_per_round']}")
        elif r["path"].startswith("delta"):
            print(f"push_pull_{r['path']}_S{r['shards']},"
                  f"{1e3 * r['pull_ms']:.0f},"
                  f"delta_bytes={r['delta_bytes_per_pull']:.0f}"
                  f";full_bytes={r['full_bytes_per_pull']}"
                  f";fraction={r['bytes_fraction']:.2f}")
        else:
            print(f"push_pull_{r['path']}_S{r['shards']},"
                  f"{1e3 * r['push_ms']:.0f},"
                  f"repack={r['repack_events_per_push']:.1f}"
                  f";launches={r['pallas_calls_per_push']:.1f}")
    print(f"# packed repack events/push at S={s_max}: {packed_ov:.1f} "
          f"(tree_fused: {fused_ov:.1f}, ratio "
          f"{'inf' if packed_ov == 0 else f'{ratio:.1f}'}x, "
          f"target >=2x: {report['derived']['target_met']})")
    print(f"# coalesced launches/round <= shards: {coalesced_ok}; "
          f"delta bytes < full on partial advance: {delta_ok}")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
