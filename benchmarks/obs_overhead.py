"""Observability overhead: the price of the ``repro.obs`` recorder.

Three measurements, emitted as ``BENCH_obs.json``:

  1. **disabled_ns_per_call** — cost of one ``TRACE.instant`` call with
     the recorder disabled.  This is the number every instrumented hot
     path (push/pull/apply/frame codec) pays per event site when
     tracing is off; the contract is "a branch and a return".
  2. **events_per_sec_drained** — sustained record+drain throughput
     with the recorder enabled (ring capacity bounds memory, so this is
     the rate at which a traced run can emit before dropping).
  3. **hotpath off/on** — the same externally-driven pull+push loop
     against a packed mono server over the inproc endpoint, once with
     tracing off and once with it on; reports both ``perfcount``
     deltas.  The gate (``perf_gate.py --obs``) fails if the deltas
     differ — instrumentation must never add counted hot-path work —
     or if ``events_recorded_off`` is non-zero.

Run: ``PYTHONPATH=src python benchmarks/obs_overhead.py [--smoke]``.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.api import (ModelSpec, OptimizerSpec, RunSpec, ServerSpec,
                       SyncSpec, TransportSpec, WireSpec, build_session)
from repro.obs.trace import TRACE
from repro.perfcount import TRANSPORT, WIRE, snapshot_all
from repro.wireformat import WIRE_LANES

SCHEMA = "obs_overhead/v1"


def bench_disabled(n_calls: int) -> float:
    """ns per TRACE.instant call with the recorder disabled."""
    TRACE.disable()
    # Warm the attribute lookups once so the loop measures the call.
    TRACE.instant("push", worker=0)
    t0 = time.perf_counter()
    for _ in range(n_calls):
        TRACE.instant("push", worker=0, clock=1)
    dt = time.perf_counter() - t0
    return dt / n_calls * 1e9


def bench_enabled_drain(n_events: int) -> float:
    """Events/sec through record+drain with the recorder enabled."""
    TRACE.enable(source="bench")
    t0 = time.perf_counter()
    for i in range(n_events):
        TRACE.instant("push", worker=0, clock=i)
        if (i + 1) % 4096 == 0:
            TRACE.drain()
    TRACE.drain()
    dt = time.perf_counter() - t0
    TRACE.disable()
    return n_events / dt


def _hotpath_once(n_rounds: int) -> dict:
    """Drive pull+push rounds against a packed mono server over the
    inproc endpoint; return the perfcount deltas for the loop."""
    params = {"w": np.arange(2048, dtype=np.float32),
              "b": np.ones(256, dtype=np.float32)}
    spec = RunSpec(
        model=ModelSpec(arch="custom"),
        optimizer=OptimizerSpec(lr=0.01),
        sync=SyncSpec(mode="asp"),
        ps=ServerSpec(kind="mono", shards=0, workers=1, apply="packed"),
        wire=WireSpec(format="packed"),
        transport=TransportSpec(kind="inproc", endpoint=True))
    session = build_session(spec, params=params,
                            external_workers=True).start()
    try:
        client = session.transport.connect(0)
        rows = client.hello()
        grads = np.random.RandomState(0).randn(
            rows, WIRE_LANES).astype(np.float32)
        # Warm-up round: first apply compiles the fused kernel.
        client.pull_packed(copy=False)
        client.push_packed(grads)
        before = snapshot_all()
        for _ in range(n_rounds):
            client.pull_packed(copy=False)
            client.push_packed(grads)
        after = snapshot_all()
        client.bye()
        client.close()
    finally:
        session.close()
    return {group: {k: after[group][k] - before[group][k]
                    for k in after[group]}
            for group in after}


def bench_hotpath(n_rounds: int) -> dict:
    """The off/on comparison the perf gate checks."""
    WIRE.reset()
    TRANSPORT.reset()
    TRACE.disable()
    off = _hotpath_once(n_rounds)
    events_off = len(TRACE)

    TRACE.enable(source="bench")
    on = _hotpath_once(n_rounds)
    events_on = len(TRACE.drain())
    TRACE.disable()
    return {"off": off, "on": on, "identical": off == on,
            "events_off": events_off, "events_on": events_on}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing: fewer calls/rounds")
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args()

    n_calls = 50_000 if args.smoke else 200_000
    n_events = 50_000 if args.smoke else 200_000
    n_rounds = 16 if args.smoke else 64

    disabled_ns = bench_disabled(n_calls)
    drained_per_s = bench_enabled_drain(n_events)
    hotpath = bench_hotpath(n_rounds)

    report = {
        "schema": SCHEMA,
        "disabled_ns_per_call": disabled_ns,
        "events_per_sec_drained": drained_per_s,
        "events_recorded_off": hotpath.pop("events_off"),
        "events_recorded_on": hotpath.pop("events_on"),
        "hotpath": hotpath,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)

    print("name,value,unit")
    print(f"disabled_instant,{disabled_ns:.1f},ns/call")
    print(f"enabled_drain,{drained_per_s:.0f},events/s")
    print(f"hotpath_identical,{int(hotpath['identical'])},bool")
    print(f"events_recorded_off,{report['events_recorded_off']},events")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
