"""Serving demo: batched prefill + greedy decode with a KV cache.

A small dense LM is trained briefly on the synthetic Markov stream, then
serves a batch of prompts: one prefill computes last-token logits AND the
packed KV cache (exactly what the decode_32k / long_500k dry-run cells
lower at scale), and the decode loop appends tokens with the ring cache.
The model should continue prompts more plausibly than chance (it learned
the chain's transitions).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import DataConfig, MarkovLM
from repro.launch.train import Trainer
from repro.models import transformer
from repro.models.config import ModelConfig


def main() -> None:
    cfg = ModelConfig(name="serve-demo", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                      vocab_size=512, dtype="float32", remat="none")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=48,
                          global_batch=8)
    print("training a tiny LM for 120 steps ...")
    trainer = Trainer(cfg, data_cfg, sync="dssp", lr=5e-3, s_lower=1,
                      s_upper=2, optimizer="adamw")
    log = trainer.train(120, verbose=False)
    print(f"  loss {log.losses[0]:.3f} -> {log.losses[-1]:.3f}")
    params = trainer.params

    # ---- build prompts from the same chain (the model knows it)
    chain = MarkovLM(data_cfg)
    rows = chain.sample_rows(step=10_000, rows=np.arange(4))
    prompt_len, max_new = 16, 16
    prompts = jnp.asarray(rows[:, :prompt_len])

    # ---- prefill: last-token logits + packed KV cache
    prefill = jax.jit(lambda p, t: transformer.forward_prefill(cfg, p, t))
    logits, cache = prefill(params, prompts)
    total = prompt_len + max_new
    cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, total - prompt_len),
                            (0, 0), (0, 0))) for k, v in cache.items()}
    next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]

    # ---- decode loop
    decode = jax.jit(lambda p, t, c, i: transformer.forward_decode(
        cfg, p, t, c, i))
    out_tokens = [next_tok]
    for step in range(max_new - 1):
        logits, cache = decode(params, next_tok, cache,
                               jnp.int32(prompt_len + step))
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out_tokens.append(next_tok)
    generated = np.asarray(jnp.concatenate(out_tokens, axis=1))

    # ---- evaluate: is each generated token a LEGAL chain successor?
    legal = 0
    for b in range(generated.shape[0]):
        prev = int(prompts[b, -1])
        for t in range(generated.shape[1]):
            tok = int(generated[b, t])
            if tok in set(chain.successors[prev]):
                legal += 1
            prev = tok
    frac = legal / generated.size
    chance = data_cfg.branching / data_cfg.vocab_size
    print(f"prompts {prompts.shape} -> generated {generated.shape}")
    print(f"legal-successor rate {frac:.2f} vs chance {chance:.3f}")
    print("sample:", generated[0][:12].tolist())
    assert frac > 10 * chance, "model failed to learn the chain"
    print("OK: serving path (prefill -> ring-cache decode) works.")


if __name__ == "__main__":
    main()
