"""Serving demo: train and serve the SAME parameters, live.

One ``RunSpec`` drives the whole thing — two worker processes train a
small dense LM over tcp (DSSP gating, packed wire, version-delta
pulls) while two serving replicas subscribe to the SAME parameter
server, keep a resident packed buffer fresh via delta pulls, and
decode continuously-batched Markov prompts behind the
``serve.staleness_bound`` admission gate.  No checkpoint sits between
training and serving: a decode is served from parameters at most
``staleness_bound`` applied updates behind the trainer.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import json


def main() -> None:
    from repro.api import (
        DataSpec,
        ModelSpec,
        RunSpec,
        ServeSpec,
        ServerSpec,
        SyncSpec,
        TransportSpec,
        WireSpec,
        build_session,
    )

    spec = RunSpec(
        model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
        data=DataSpec(seq_len=32, global_batch=4),
        ps=ServerSpec(kind="sharded", shards=2, workers=2,
                      apply="fused"),
        sync=SyncSpec(mode="dssp", s_lower=1, s_upper=4),
        wire=WireSpec(format="packed", delta_pull=True),
        transport=TransportSpec(kind="tcp", endpoint=True),
        serve=ServeSpec(replicas=2, requests=12, request_every_ms=150.0,
                        start_at_version=1, prompt_len=8, max_new=4,
                        max_batch=4, staleness_bound=4))

    print("training 2 tcp workers while 2 replicas serve ...")
    with build_session(spec) as session:
        metrics = session.run(steps=40)

    serve = metrics["serve"]
    print(f"train: pushes={metrics['pushes']} "
          f"applied_updates={metrics['applied_updates']} "
          f"loss {metrics['first_loss']:.3f} -> "
          f"{metrics['final_loss']:.3f}")
    print("serve:", json.dumps(serve, indent=2, sort_keys=True))

    # The freshness contract: every admission stayed within the bound.
    assert serve["violations"] == 0, "staleness-bound violations"
    assert serve["requests"] == 2 * 12, "not every request was served"
    # Replicas decoded against a LIVE store: the versions they served
    # from advanced as the trainers pushed.
    assert serve["version_max"] > 0, "served versions never advanced"
    # Language probe (soft): the smoke model only trains for a few
    # steps here, so report the legal-successor rate rather than
    # gating on it — `python -m repro.launch.serve --steps 400` shows
    # it climbing toward 1.0 as the served parameters improve.
    print(f"legal-successor rate {serve['legal_fraction']:.3f} "
          f"(chance ~{32 / 256:.3f})")
    print("OK: train-and-serve over one live parameter server works.")


if __name__ == "__main__":
    main()
