"""The paper's headline experiment: a heterogeneous cluster.

Four workers train one shared model through the parameter server; worker
3 is 4x slower (the paper's GTX1060 next to GTX1080Ti).  Each paradigm
runs the same jitted SGD steps — only the synchronization policy
differs.  Reported: updates applied, waiting time, staleness profile,
final loss, plus the virtual-time Table-I composition.

Run:  PYTHONPATH=src python examples/heterogeneous_ps.py
      PYTHONPATH=src python examples/heterogeneous_ps.py --ps-shards 4

With ``--ps-shards N > 1`` the same experiment runs through the
partitioned ``ShardedParameterServer``: per-shard locks/versions and
per-shard DSSP gating, so pushes to different shards proceed
concurrently (ps/sharded/).
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import make_policy, make_policy_factory
from repro.ps.metrics import compare
from repro.ps.server import ParameterServer, ServerOptimizer
from repro.ps.sharded import ShardedParameterServer, run_sharded_policy
from repro.ps.simulator import run_policy
from repro.ps.worker import PSWorker, run_cluster


# one grid for the threaded AND virtual-time views — keep in lockstep
POLICIES = (("bsp", {}), ("asp", {}),
            ("ssp", dict(staleness=3)),
            ("dssp", dict(s_lower=3, s_upper=15)))


def make_problem(seed=0, dim=16, n=2048, classes=4):
    rng = np.random.RandomState(seed)
    w = rng.randn(dim, classes).astype(np.float32)
    x = rng.randn(n, dim).astype(np.float32)
    y = np.argmax(x @ w + rng.gumbel(size=(n, classes)), -1).astype(np.int32)
    return x, y, classes


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ps-shards", type=int, default=1, metavar="N",
                    help="partition the weights across N server shards "
                         "(1 = the monolithic server)")
    ap.add_argument("--ps-apply", default="tree",
                    choices=["tree", "fused"])
    args = ap.parse_args()
    n_shards = max(1, args.ps_shards)

    x, y, classes = make_problem()

    def loss_fn(params, batch):
        bx, by = batch
        logp = jax.nn.log_softmax(bx @ params["w"] + params["b"])
        return -jnp.mean(jnp.take_along_axis(logp, by[:, None], 1))

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return grads, {"loss": loss}

    def batches(w, n_workers=4, bs=64):
        sx, sy = x[w::n_workers], y[w::n_workers]
        rng = np.random.RandomState(w)
        while True:
            i = rng.randint(0, len(sx), bs)
            yield sx[i], sy[i]

    speeds = [1.0, 1.0, 1.0, 4.0]
    print(f"4 workers, speed factors {speeds}, 80 iterations each, "
          f"{n_shards} server shard(s)\n")
    runs = []
    shard_runs = []
    for name, kw in POLICIES:
        params = {"w": jnp.zeros((x.shape[1], classes)),
                  "b": jnp.zeros((classes,))}
        if n_shards > 1:
            server = ShardedParameterServer(
                params, make_policy_factory(name, n_workers=4, **kw),
                lambda: ServerOptimizer(lr=0.3), 4, n_shards,
                apply_mode=args.ps_apply)
        else:
            server = ParameterServer(
                params, make_policy(name, n_workers=4, **kw),
                ServerOptimizer(lr=0.3), 4)
        workers = [PSWorker(w, server, step, batches(w), 80,
                            speed_factor=speeds[w])
                   for w in range(4)]
        run_cluster(server, workers, timeout=300.0)
        logits = x @ np.asarray(server.params["w"]) + np.asarray(
            server.params["b"])
        acc = float((np.argmax(logits, -1) == y).mean())
        server.metrics.policy += f"  acc={acc:.3f}"
        runs.append(server.metrics)
        if n_shards > 1:
            shard_runs.append((name, server.shard_metrics()))
    print(compare(runs))
    if shard_runs:
        print("\nPer-shard view (threaded):")
        for name, sms in shard_runs:
            print(compare(sms))

    print("\nVirtual-time view (same speeds, 2000 pushes):")
    if n_shards > 1:
        vruns = [run_sharded_policy(
                     make_policy_factory(n, n_workers=4, **kw), speeds,
                     n_shards, max_pushes=2000).metrics
                 for n, kw in POLICIES]
    else:
        vruns = [run_policy(make_policy(n, n_workers=4, **kw), speeds,
                            max_pushes=2000)
                 for n, kw in POLICIES]
    print(compare(vruns))
    print("\nReading: with a PERSISTENT straggler the steady-state rate "
          "of every bounded\nscheme converges to the straggler's (BSP ~ "
          "SSP ~ DSSP here) — DSSP's edge is\nless waiting per sync and "
          "front-loaded updates under finite budgets or\ntransient skew "
          "(see benchmarks: finite_budget_*, transient_*, tableI_*),\n"
          "while keeping staleness bounded (<= s_U) unlike ASP.")


if __name__ == "__main__":
    main()
