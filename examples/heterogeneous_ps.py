"""The paper's headline experiment: a heterogeneous cluster.

Four workers train one shared model through the parameter server; worker
3 is 4x slower (the paper's GTX1060 next to GTX1080Ti).  Each paradigm
runs the same jitted SGD steps — only the synchronization policy
differs, and with ``repro.api`` a paradigm (or the server kind) is one
spec field: the example builds every run through
``build_session(RunSpec(...))`` with a custom toy problem injected as
build-time overrides.  Reported: updates applied, waiting time,
staleness profile, final loss, plus the virtual-time Table-I
composition.

Run:  PYTHONPATH=src python examples/heterogeneous_ps.py
      PYTHONPATH=src python examples/heterogeneous_ps.py --ps-shards 4

With ``--ps-shards N > 1`` the same experiment runs through the
partitioned ``ShardedParameterServer``: per-shard locks/versions and
per-shard DSSP gating, so pushes to different shards proceed
concurrently (ps/sharded/).
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (ModelSpec, OptimizerSpec, RunSpec, ServerSpec,
                       SyncSpec, build_session)
from repro.ps.metrics import compare
from repro.ps.sharded import run_sharded_policy
from repro.ps.simulator import run_policy


# one grid for the threaded AND virtual-time views — keep in lockstep
POLICIES = (("bsp", {}), ("asp", {}),
            ("ssp", dict(staleness=3)),
            ("dssp", dict(s_lower=3, s_upper=15)))


def make_problem(seed=0, dim=16, n=2048, classes=4):
    rng = np.random.RandomState(seed)
    w = rng.randn(dim, classes).astype(np.float32)
    x = rng.randn(n, dim).astype(np.float32)
    y = np.argmax(x @ w + rng.gumbel(size=(n, classes)), -1).astype(np.int32)
    return x, y, classes


def sync_spec(name: str, kw: dict) -> SyncSpec:
    return SyncSpec(mode=name, staleness=kw.get("staleness", 1),
                    s_lower=kw.get("s_lower", 0),
                    s_upper=kw.get("s_upper", 3))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ps-shards", type=int, default=1, metavar="N",
                    help="partition the weights across N server shards "
                         "(1 = the monolithic server)")
    ap.add_argument("--ps-apply", default="tree",
                    choices=["tree", "fused"])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer iterations/pushes)")
    args = ap.parse_args()
    n_shards = max(1, args.ps_shards)
    iters = 10 if args.smoke else 80
    vpushes = 300 if args.smoke else 2000

    x, y, classes = make_problem()

    def loss_fn(params, batch):
        bx, by = batch
        logp = jax.nn.log_softmax(bx @ params["w"] + params["b"])
        return -jnp.mean(jnp.take_along_axis(logp, by[:, None], 1))

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return grads, {"loss": loss}

    def batches(w, n_workers=4, bs=64):
        sx, sy = x[w::n_workers], y[w::n_workers]
        rng = np.random.RandomState(w)
        while True:
            i = rng.randint(0, len(sx), bs)
            yield sx[i], sy[i]

    speeds = [1.0, 1.0, 1.0, 4.0]
    print(f"4 workers, speed factors {speeds}, {iters} iterations each, "
          f"{n_shards} server shard(s)\n")
    if n_shards > 1:
        server_spec = ServerSpec(kind="sharded", shards=n_shards,
                                 workers=4, apply=args.ps_apply)
    else:
        server_spec = ServerSpec(kind="mono", shards=1, workers=4)
    runs = []
    shard_runs = []
    for name, kw in POLICIES:
        params = {"w": jnp.zeros((x.shape[1], classes)),
                  "b": jnp.zeros((classes,))}
        spec = RunSpec(model=ModelSpec(arch="custom"),
                       optimizer=OptimizerSpec(lr=0.3),
                       sync=sync_spec(name, kw),
                       ps=server_spec)
        with build_session(spec, params=params, step_fn=step,
                           batches=batches,
                           speed_factors=speeds) as session:
            session.run(iters * 4)
            server = session.server
            logits = x @ np.asarray(server.params["w"]) + np.asarray(
                server.params["b"])
            acc = float((np.argmax(logits, -1) == y).mean())
            server.metrics.policy += f"  acc={acc:.3f}"
            runs.append(server.metrics)
            if n_shards > 1:
                shard_runs.append((name, server.shard_metrics()))
    print(compare(runs))
    if shard_runs:
        print("\nPer-shard view (threaded):")
        for name, sms in shard_runs:
            print(compare(sms))

    print(f"\nVirtual-time view (same speeds, {vpushes} pushes):")
    if n_shards > 1:
        vruns = [run_sharded_policy(
                     sync_spec(n, kw).policy_factory(4), speeds,
                     n_shards, max_pushes=vpushes).metrics
                 for n, kw in POLICIES]
    else:
        vruns = [run_policy(sync_spec(n, kw).policy_factory(4)(), speeds,
                            max_pushes=vpushes)
                 for n, kw in POLICIES]
    print(compare(vruns))
    print("\nReading: with a PERSISTENT straggler the steady-state rate "
          "of every bounded\nscheme converges to the straggler's (BSP ~ "
          "SSP ~ DSSP here) — DSSP's edge is\nless waiting per sync and "
          "front-loaded updates under finite budgets or\ntransient skew "
          "(see benchmarks: finite_budget_*, transient_*, tableI_*),\n"
          "while keeping staleness bounded (<= s_U) unlike ASP.")


if __name__ == "__main__":
    main()
