"""Quickstart: the DSSP idea in 60 seconds.

1. Virtual-time cluster: watch DSSP grant extra iterations to fast
   workers and beat SSP's waiting time.
2. Real training: a tiny LM trained with the DSSP delayed-gradient
   pipeline (the SPMD adaptation) — same loss trajectory as BSP, with
   the gradient collective moved off the critical path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.policies import make_policy
from repro.ps.metrics import compare
from repro.ps.simulator import run_policy


def virtual_cluster():
    print("=" * 70)
    print("1. Virtual 4-worker cluster, one 3x straggler, 2000 pushes")
    print("=" * 70)
    intervals = [1.0, 1.1, 1.2, 3.0]
    runs = []
    for name, kw in (("bsp", {}), ("asp", {}),
                     ("ssp", dict(staleness=3)),
                     ("dssp", dict(s_lower=3, s_upper=15))):
        runs.append(run_policy(make_policy(name, n_workers=4, **kw),
                               intervals, max_pushes=2000))
    print(compare(runs))
    print("\nDSSP: less waiting than SSP(s_L), bounded staleness "
          "(unlike ASP).\n")


def tiny_training():
    print("=" * 70)
    print("2. DSSP-SPMD delayed-gradient training (tiny LM, 60 steps)")
    print("=" * 70)
    from repro.configs import get_smoke_config
    from repro.data.synthetic import DataConfig, loss_floor
    from repro.launch.train import Trainer

    cfg = get_smoke_config("h2o-danube-1.8b")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8)
    for sync in ("bsp", "dssp"):
        t = Trainer(cfg, data_cfg, sync=sync, lr=5e-3, s_lower=1,
                    s_upper=3)
        log = t.train(60, verbose=False)
        print(f"  sync={sync:<5} loss {log.losses[0]:.3f} -> "
              f"{log.losses[-1]:.3f}  (floor ~{loss_floor(data_cfg):.3f},"
              f" mean delay {sum(log.delays) / len(log.delays):.1f})")
    print("\nDelayed gradients (bounded staleness) converge like BSP;")
    print("on a pod the delay hides the gradient all-reduce.")


if __name__ == "__main__":
    virtual_cluster()
    tiny_training()
