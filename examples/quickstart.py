"""Quickstart: the DSSP idea in 60 seconds.

1. Virtual-time cluster: watch DSSP grant extra iterations to fast
   workers and beat SSP's waiting time.
2. Real training: a tiny LM trained with the DSSP delayed-gradient
   pipeline (the SPMD adaptation), wired declaratively through
   ``repro.api`` — a ``RunSpec`` in, a ``TrainingSession`` out; the
   paradigm is one field, not a rewiring.

Run:  PYTHONPATH=src python examples/quickstart.py [--smoke]
"""

import argparse

from repro.api import (DataSpec, ModelSpec, OptimizerSpec, RunSpec,
                       SyncSpec, build_session)
from repro.core.policies import make_policy
from repro.ps.metrics import compare
from repro.ps.simulator import run_policy


def virtual_cluster(max_pushes: int = 2000) -> None:
    print("=" * 70)
    print(f"1. Virtual 4-worker cluster, one 3x straggler, "
          f"{max_pushes} pushes")
    print("=" * 70)
    intervals = [1.0, 1.1, 1.2, 3.0]
    runs = []
    for name, kw in (("bsp", {}), ("asp", {}),
                     ("ssp", dict(staleness=3)),
                     ("dssp", dict(s_lower=3, s_upper=15))):
        runs.append(run_policy(make_policy(name, n_workers=4, **kw),
                               intervals, max_pushes=max_pushes))
    print(compare(runs))
    print("\nDSSP: less waiting than SSP(s_L), bounded staleness "
          "(unlike ASP).\n")


def tiny_training(steps: int = 60) -> None:
    print("=" * 70)
    print(f"2. DSSP-SPMD delayed-gradient training (tiny LM, "
          f"{steps} steps)")
    print("=" * 70)
    from repro.data.synthetic import DataConfig, loss_floor

    data = DataSpec(seq_len=32, global_batch=8)
    floor = None
    for sync in ("bsp", "dssp"):
        spec = RunSpec(model=ModelSpec(arch="h2o-danube-1.8b"),
                       data=data,
                       optimizer=OptimizerSpec(lr=5e-3),
                       sync=SyncSpec(mode=sync, s_lower=1, s_upper=3))
        with build_session(spec) as session:
            m = session.run(steps)
            if floor is None:
                cfg = session.trainer.cfg
                floor = loss_floor(DataConfig(
                    vocab_size=cfg.vocab_size, seq_len=data.seq_len,
                    global_batch=data.global_batch))
        print(f"  sync={sync:<5} loss {m['first_loss']:.3f} -> "
              f"{m['final_loss']:.3f}  (floor ~{floor:.3f},"
              f" mean delay {m['mean_delay']:.1f})")
    print("\nDelayed gradients (bounded staleness) converge like BSP;")
    print("on a pod the delay hides the gradient all-reduce.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer pushes/steps)")
    args = ap.parse_args()
    virtual_cluster(max_pushes=300 if args.smoke else 2000)
    tiny_training(steps=12 if args.smoke else 60)
