"""End-to-end LM training driver with DSSP, checkpoints and restart.

Presets:
  tiny   ~0.5M params — seconds on this CPU container (default)
  20m    ~20M params  — minutes
  100m   ~100M params — the brief's reference workload (few hundred
         steps; practical on accelerators, hours on 1 CPU core)

Demonstrates: synthetic data pipeline, DSSP delayed-gradient pipeline
with the run-time controller, async atomic checkpoints, and
crash-restart (--resume continues bit-exact w.r.t. the data stream).

Run:  PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 150
      PYTHONPATH=src python examples/train_lm.py --preset tiny --resume
"""

import argparse

import numpy as np

from repro.api import (DataSpec, ModelSpec, OptimizerSpec, RunSpec,
                       SyncSpec, build_session)
from repro.data.synthetic import DataConfig, loss_floor
from repro.models.config import ModelConfig

PRESETS = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                 d_ff=384, vocab_size=512, seq=64, batch=8),
    "20m": dict(n_layers=6, d_model=384, n_heads=8, n_kv_heads=4,
                d_ff=1152, vocab_size=8192, seq=128, batch=8),
    "100m": dict(n_layers=12, d_model=512, n_heads=8, n_kv_heads=8,
                 d_ff=2048, vocab_size=32000, seq=256, batch=8),
}


def build_config(preset: str) -> ModelConfig:
    p = dict(PRESETS[preset])
    p.pop("seq"), p.pop("batch")
    return ModelConfig(name=f"lm-{preset}", family="dense",
                       dtype="float32", remat="none", **p)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--sync", default="dssp",
                    choices=["bsp", "ssp", "dssp"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = build_config(args.preset)
    preset = PRESETS[args.preset]
    data_cfg = DataConfig(vocab_size=cfg.vocab_size,
                          seq_len=preset["seq"],
                          global_batch=preset["batch"])
    # The spec describes the run; the hand-built ModelConfig rides in as
    # a build-time override (spec archs name the registry).
    spec = RunSpec(model=ModelSpec(arch="custom"),
                   data=DataSpec(seq_len=preset["seq"],
                                 global_batch=preset["batch"]),
                   optimizer=OptimizerSpec(name="adamw", lr=args.lr),
                   sync=SyncSpec(mode=args.sync, s_lower=1, s_upper=3))
    with build_session(spec, model_config=cfg, verbose=True,
                       checkpoint_dir=args.checkpoint_dir,
                       save_every=50, resume=args.resume) as session:
        session.start()
        if args.resume and session.resumed:
            print(f"resumed from step {session.trainer.step_idx}")
        from repro.models.registry import count_params
        print(f"model {cfg.name}: {count_params(cfg):,} params; "
              f"data floor ~{loss_floor(data_cfg):.3f} nats")
        session.run(args.steps)
        log = session.trainer.log
    print(f"done: loss {log.losses[0]:.3f} -> {log.losses[-1]:.3f}, "
          f"mean step {np.mean(log.step_times[1:]) * 1e3:.0f} ms, "
          f"mean DSSP delay {np.mean(log.delays):.2f}")
    print(f"checkpoints in {args.checkpoint_dir}: rerun with --resume")


if __name__ == "__main__":
    main()
