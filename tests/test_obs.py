"""Run-wide tracing & telemetry (``repro.obs``) + the metric fixes
that rode along.

Covers the observability acceptance surface:

* recorder: ring bound, drain semantics, wall-clock anchoring,
  disabled calls are no-ops that record nothing;
* zero-overhead contract: perfcount deltas on the packed frame codec
  are bitwise identical with tracing off and on;
* collector: (src, seq) dedup makes frame + spill double-delivery
  idempotent; ``by_worker_clock`` ordering is stable under arrival
  order; truncated spill files (killed worker) recover cleanly;
* export: Chrome trace_event JSON loads as valid JSON and round-trips
  every native field; JSONL round-trips;
* e2e over tcp AND shmem: spawned workers' ``compute_step`` spans
  arrive at the server-side collector, the DSSP decision timeline is
  present, and ``summarize`` agrees with ``session.metrics()``;
* killed-worker path: spill files written with no collector attached
  are recovered by ``ingest_spill_dir``;
* DSSP: threshold-extension trace events == the policy's
  credit-release count;
* ``ps.metrics``: ``hist_percentile`` is bit-identical to the old
  ``statistics.quantiles`` materialization and O(distinct values);
  trajectories stay bounded with endpoints preserved;
* ``perfcount.snapshot_all`` feeds both session.metrics and the
  sampler from one base-class implementation.
"""

from __future__ import annotations

import json
import statistics
import time

import numpy as np
import pytest

from repro.obs import (
    MetricsSampler,
    TraceCollector,
    read_jsonl,
    read_trace,
    summarize,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.trace import TRACE, TraceRecorder


@pytest.fixture(autouse=True)
def _trace_off():
    """Every test starts and ends with the global recorder disabled."""
    TRACE.disable()
    yield
    TRACE.disable()


# ================================================================ recorder
def test_recorder_basic_span_and_instant():
    r = TraceRecorder()
    r.enable(source="t0")
    t0 = r.now()
    time.sleep(0.002)
    r.span("compute_step", t0, worker=3, clock=7, args={"loss": 1.5})
    r.instant("dssp_decision", worker=1, args={"reason": "free"})
    events = r.drain()
    assert [e["name"] for e in events] == ["compute_step", "dssp_decision"]
    span, inst = events
    assert span["worker"] == 3 and span["clock"] == 7
    assert span["dur"] >= 0.002 and span["args"] == {"loss": 1.5}
    assert inst["dur"] == 0.0 and inst["args"]["reason"] == "free"
    assert span["src"] == inst["src"] == "t0"
    assert inst["seq"] > span["seq"]
    # ts is anchored to wall clock, not the raw perf_counter basis
    assert abs(span["ts"] - time.time()) < 60.0
    assert r.drain() == []


def test_recorder_ring_is_bounded():
    r = TraceRecorder()
    r.enable(source="t", capacity=64)
    for i in range(1000):
        r.instant("push", clock=i)
    events = r.drain()
    assert len(events) == 64
    # oldest dropped, newest kept
    assert [e["clock"] for e in events] == list(range(936, 1000))


def test_disabled_recorder_records_nothing():
    r = TraceRecorder()
    r.instant("push")
    r.span("pull", r.now())
    assert len(r) == 0 and r.drain() == []
    r.enable(source="t")
    r.disable()
    r.instant("push")
    assert r.drain() == []


def test_enable_resets_seq_and_ring():
    r = TraceRecorder()
    r.enable(source="a")
    r.instant("push")
    r.enable(source="b")
    r.instant("push")
    (e,) = r.drain()
    assert e["seq"] == 0 and e["src"] == "b"


# ============================================= zero-overhead contract
def test_tracing_off_perfcount_deltas_bitwise_identical():
    """The packed frame codec must count exactly the same work whether
    the recorder is enabled or not (the instrumentation is read-only
    observation, never counted hot-path events)."""
    from repro.perfcount import snapshot_all
    from repro.wireformat import MSG_PUSH, Frame, decode_frame, encode_frame

    payload = np.random.RandomState(0).randn(8, 512).astype(np.float32)

    def run_once():
        before = snapshot_all()
        for clock in range(20):
            data = encode_frame(Frame(kind=MSG_PUSH, worker=1,
                                      clock=clock, payload=payload))
            decode_frame(data)
        after = snapshot_all()
        return {g: {k: after[g][k] - before[g][k] for k in after[g]}
                for g in after}

    TRACE.disable()
    off = run_once()
    assert len(TRACE) == 0  # nothing recorded while disabled
    TRACE.enable(source="test")
    on = run_once()
    assert len(TRACE.drain()) > 0  # the same path DID trace when armed
    TRACE.disable()
    assert off == on


def test_trace_frames_not_self_counted():
    """MSG_TRACE frames must not emit frame_tx/frame_rx events — a
    flush that traced itself would amplify forever."""
    from repro.wireformat import MSG_PUSH, MSG_TRACE, Frame, decode_frame, \
        encode_frame

    TRACE.enable(source="test")
    blob = json.dumps([{"seq": 0, "name": "push", "ts": 0.0}]).encode()
    decode_frame(encode_frame(Frame(kind=MSG_TRACE, worker=0, blob=blob)))
    names = {e["name"] for e in TRACE.drain()}
    assert "frame_tx" not in names and "frame_rx" not in names
    payload = np.zeros((2, 512), dtype=np.float32)
    decode_frame(encode_frame(Frame(kind=MSG_PUSH, worker=0,
                                    payload=payload)))
    names = [e["name"] for e in TRACE.drain()]
    assert names.count("frame_tx") == 1 and names.count("frame_rx") == 1


# ================================================================ collector
def _evt(seq, name="push", *, src=None, worker=-1, clock=-1, ts=0.0,
         **args):
    e = {"seq": seq, "ts": ts, "dur": 0.0, "name": name,
         "worker": worker, "shard": -1, "clock": clock}
    if src is not None:
        e["src"] = src
    if args:
        e["args"] = args
    return e


def test_collector_dedups_by_src_seq():
    c = TraceCollector()
    batch = [_evt(0, src="w0"), _evt(1, src="w0")]
    assert c.ingest("w0", batch) == 2
    # same events again (spill + frame double delivery)
    assert c.ingest("w0", [dict(e) for e in batch]) == 0
    # same seq, different src is a different event
    assert c.ingest("w1", [_evt(0, src="w1")]) == 1
    assert len(c) == 3


def test_collector_drops_malformed_and_stamps_source():
    c = TraceCollector()
    added = c.ingest("w2", [{"seq": 0, "ts": 1.0, "name": "push"},
                            "not-a-dict", {"seq": 1, "ts": 2.0}, None])
    assert added == 1
    (e,) = c.events()
    assert e["src"] == "w2"


def test_collector_by_worker_clock_stable_under_arrival_order():
    a = [_evt(0, "compute_step", src="w1", worker=1, clock=0, ts=5.0),
         _evt(1, "compute_step", src="w1", worker=1, clock=1, ts=6.0)]
    b = [_evt(0, "compute_step", src="w0", worker=0, clock=0, ts=5.5),
         _evt(1, "compute_step", src="w0", worker=0, clock=1, ts=6.5)]
    srv = [_evt(0, "apply", src="server", worker=0, clock=0, ts=5.6)]

    c1, c2 = TraceCollector(), TraceCollector()
    for batch in (a, b, srv):
        c1.ingest("x", [dict(e) for e in batch])
    for batch in (srv, b, a):
        c2.ingest("x", [dict(e) for e in batch])
    key = [(e["worker"], e["clock"], e["ts"], e["src"], e["seq"])
           for e in c1.by_worker_clock()]
    assert key == [(e["worker"], e["clock"], e["ts"], e["src"], e["seq"])
                   for e in c2.by_worker_clock()]
    assert key == sorted(key)


def test_spill_recovery_tolerates_truncated_line(tmp_path):
    """A killed worker leaves a half-written final JSONL line; recovery
    must keep every complete line and dedup against frame delivery."""
    spill = tmp_path / "spill"
    spill.mkdir()
    lines = [json.dumps(_evt(i, src="w0", worker=0, clock=i))
             for i in range(3)]
    (spill / "w0.jsonl").write_text(
        "\n".join(lines) + "\n" + lines[0][: len(lines[0]) // 2])
    c = TraceCollector()
    # events 0-1 already arrived over a TRACE frame before the kill
    c.ingest("w0", [_evt(0, src="w0", worker=0, clock=0),
                    _evt(1, src="w0", worker=0, clock=1)])
    assert c.ingest_spill_dir(spill) == 1  # only clock=2 is new
    clocks = sorted(e["clock"] for e in c.events())
    assert clocks == [0, 1, 2]


def test_metrics_sampler_samples_and_stops():
    r = TraceRecorder()
    r.enable(source="srv")
    calls = []
    s = MetricsSampler(r, lambda: calls.append(1) or {"n": len(calls)},
                       every=0.01)
    s.start()
    time.sleep(0.08)
    s.stop()
    assert not s.is_alive()
    snaps = [e for e in r.drain() if e["name"] == "metrics_snapshot"]
    assert len(snaps) >= 2  # several periodic + the final one
    assert snaps[-1]["args"]["n"] == len(calls)
    with pytest.raises(ValueError):
        MetricsSampler(r, dict, every=0.0)


# ================================================================== export
def test_chrome_trace_roundtrip(tmp_path):
    events = [
        _evt(0, "compute_step", src="w0", worker=0, clock=2, ts=10.0,
             loss=2.5),
        _evt(1, "dssp_decision", src="server", worker=1, clock=3,
             ts=10.5, reason="grant"),
    ]
    events[0]["dur"] = 0.25
    events[1]["shard"] = 1
    path = tmp_path / "trace.json"
    write_chrome_trace(events, path)

    doc = json.loads(path.read_text())
    assert isinstance(doc["traceEvents"], list)
    phases = {r["ph"] for r in doc["traceEvents"]}
    assert {"M", "X", "i"} <= phases  # metadata + span + instant

    back = read_trace(path)
    by_seq = {e["seq"]: e for e in back}
    assert by_seq[0]["name"] == "compute_step"
    assert by_seq[0]["worker"] == 0 and by_seq[0]["clock"] == 2
    assert abs(by_seq[0]["ts"] - 10.0) < 1e-6
    assert abs(by_seq[0]["dur"] - 0.25) < 1e-6
    assert by_seq[0]["args"]["loss"] == 2.5
    assert by_seq[1]["src"] == "server" and by_seq[1]["shard"] == 1


def test_jsonl_roundtrip_and_sniffing(tmp_path):
    events = [_evt(i, src="w0", ts=float(i)) for i in range(5)]
    path = tmp_path / "trace.jsonl"
    assert write_jsonl(events, path) == 5
    assert read_jsonl(path) == events
    assert read_trace(path) == events  # sniffed as JSONL
    assert read_jsonl(tmp_path / "missing.jsonl") == []


def test_summarize_empty_and_basic():
    assert summarize([])["events"] == 0
    ev = [_evt(0, "compute_step", src="w0", worker=0, ts=0.0),
          _evt(1, "gate_wait", src="w0", worker=0, ts=1.0)]
    ev[0]["dur"] = 1.0
    ev[1]["dur"] = 0.5
    s = summarize(ev)
    assert s["workers"] == [0]
    assert s["busy_s"] == 1.0 and s["wait_s"] == 0.5
    assert s["wall_s"] == pytest.approx(1.5)
    assert s["wait_fraction"] == pytest.approx(0.5 / 1.5)


def test_summarize_dedups_extensions_across_shards():
    """One push through S shards emits S decision events with the same
    (worker, clock); RunMetrics counts the push once, so must we."""
    ev = []
    for shard_seq in range(2):  # two shards, same push
        ev.append(_evt(shard_seq, "dssp_decision", src="server",
                       worker=0, clock=5, reason="grant", threshold=3))
    ev.append(_evt(2, "dssp_decision", src="server", worker=1, clock=5,
                   reason="block", threshold=1))
    d = summarize(ev)["dssp"]
    assert d["decisions"] == 3
    assert d["threshold_extensions"] == 1


# ===================================================== DSSP decision events
def test_dssp_extension_events_match_credit_releases():
    """Drive the Algorithm-1/2 policy directly: the number of traced
    grant/credit_spend decisions equals the number of pushes released
    with ``credit_used=True`` (what RunMetrics counts)."""
    from repro.core.policies import make_policy_factory
    from repro.core.staleness import StalenessTracker

    policy = make_policy_factory("dssp", n_workers=2, staleness=1,
                                 s_lower=1, s_upper=4)()
    tracker = StalenessTracker(range(2))
    TRACE.enable(source="server")
    credit_releases = 0
    # Warm Algorithm 2's estimator first: the controller returns 0 until
    # both the fast and the slow worker have a measured push interval
    # (two pushes each), so worker 1 (slow, 10s/iter) goes first ...
    for t in (0.0, 10.0):
        tracker.record_push(1, t)
        dec = policy.on_push(tracker, 1, t)
        credit_releases += bool(dec.credit_used)
    # ... then worker 0 sprints at 1s/iter: free passes while
    # gap <= s_L, a controller grant (slow interval is 10x the fast
    # one, so r* > 0) with credit spends up to the hard bound s_U,
    # then blocks once the credits run out.
    t = 10.0
    for _ in range(10):
        t += 1.0
        tracker.record_push(0, t)
        dec = policy.on_push(tracker, 0, t)
        credit_releases += bool(dec.credit_used)
    events = TRACE.drain()
    decisions = [e for e in events if e["name"] == "dssp_decision"]
    extensions = [e for e in decisions
                  if e["args"]["reason"] in ("grant", "credit_spend")]
    assert decisions, "DSSP gate emitted no decision events"
    assert credit_releases > 0, "pattern produced no extensions"
    assert len(extensions) == credit_releases
    for e in decisions:
        a = e["args"]
        assert a["s_lower"] == 1 and a["s_upper"] == 4
        assert a["threshold"] >= a["s_lower"]
        assert e["worker"] in (0, 1) and e["clock"] >= 1


# ====================================================== e2e over transports
def _traced_spec(transport: str, trace_path: str, workers: int = 2):
    from repro import api

    return api.RunSpec(
        model=api.ModelSpec(arch="xlstm-125m"),
        data=api.DataSpec(seq_len=16, global_batch=4),
        sync=api.SyncSpec(mode="dssp", staleness=1, s_lower=1, s_upper=3),
        ps=api.ServerSpec(kind="sharded", shards=2, workers=workers,
                          apply="fused", straggler=2.0),
        wire=api.WireSpec(format="packed"),
        transport=api.TransportSpec(kind=transport),
        obs=api.ObsSpec(trace=True, trace_path=trace_path))


@pytest.mark.parametrize("transport", ["tcp", "shmem"])
def test_traced_run_collects_all_workers(transport, tmp_path):
    from repro import api

    trace_path = str(tmp_path / "run.json")
    spec = _traced_spec(transport, trace_path)
    with api.build_session(spec) as session:
        m = session.run(6)
    obs = m["obs"]
    # every worker's compute spans crossed the process boundary
    assert obs["workers"] == [0, 1]
    assert obs["event_counts"].get("compute_step", 0) >= 6
    assert obs["event_counts"].get("push", 0) >= 6
    assert obs["event_counts"].get("dssp_decision", 0) >= 1
    assert obs["dssp"]["threshold_extensions"] == m["credit_releases"]
    # session metrics carry the satellite enrichments
    assert "wait_fraction" in m and "perfcount" in m
    assert set(m["perfcount"]) == {"wire", "transport"}

    # the exported file is valid Chrome JSON and summarizes identically
    with open(trace_path) as f:
        doc = json.load(f)
    assert doc["traceEvents"]
    back = summarize(read_trace(trace_path))
    assert back["event_counts"] == obs["event_counts"]
    assert back["dssp"]["threshold_extensions"] == \
        obs["dssp"]["threshold_extensions"]

    # merge ordering contract: worker events arrive in clock order
    events = read_trace(trace_path)
    for w in (0, 1):
        clocks = [e["clock"] for e in sorted(
            events, key=lambda e: (e.get("worker", -1),
                                   e.get("clock", -1),
                                   e.get("ts", 0.0)))
            if e.get("name") == "compute_step" and e.get("worker") == w]
        assert clocks == sorted(clocks)


def test_traced_threaded_run_and_disabled_run(tmp_path):
    """ps-threads engine: in-heap workers trace through the same global
    recorder; with obs.trace=false nothing is recorded at all."""
    from repro import api

    trace_path = str(tmp_path / "threads.jsonl")
    spec = api.RunSpec(
        model=api.ModelSpec(arch="xlstm-125m"),
        data=api.DataSpec(seq_len=16, global_batch=4),
        sync=api.SyncSpec(mode="dssp", staleness=1, s_lower=1, s_upper=3),
        ps=api.ServerSpec(kind="mono", shards=0, workers=2,
                          apply="packed"),
        wire=api.WireSpec(format="packed"),
        obs=api.ObsSpec(trace=True, trace_path=trace_path))
    with api.build_session(spec) as session:
        m = session.run(6)
    obs = m["obs"]
    assert obs["event_counts"].get("compute_step", 0) >= 6
    assert obs["dssp"]["threshold_extensions"] == m["credit_releases"]
    assert read_jsonl(trace_path)  # .jsonl path exports JSONL

    # tracing off: same run shape, no recorder, no obs key
    spec_off = api.RunSpec(
        model=spec.model, data=spec.data, sync=spec.sync, ps=spec.ps,
        wire=spec.wire)
    with api.build_session(spec_off) as session:
        m_off = session.run(6)
    assert "obs" not in m_off
    assert len(TRACE) == 0


def test_killed_worker_spill_recovered_without_collector(tmp_path):
    """Workers flushing every iteration against an endpoint with NO
    collector (frames acknowledged and dropped): the JSONL spill is the
    only surviving copy, and ``ingest_spill_dir`` recovers it — the
    abnormal-exit path, minus the nondeterministic kill."""
    from repro import api
    from repro.launch.proc_pool import (ProcessWorkerPool, WorkerTask,
                                        raise_on_failure)

    spec = _traced_spec("tcp", "", workers=2)
    session = api.build_session(spec, external_workers=True).start()
    assert session.endpoint.collector is not None
    session.endpoint.collector = None  # simulate a collector-less server
    spill = str(tmp_path / "spill")
    try:
        task = WorkerTask.from_spec(spec, 3, trace_spill=spill,
                                    trace_flush_every=1)
        pool = ProcessWorkerPool(session.transport.address(), task, 2)
        pool.start()
        results = pool.join(timeout=600.0, endpoint=session.endpoint)
        raise_on_failure(results)
    finally:
        session.close()

    c = TraceCollector()
    assert c.ingest_spill_dir(spill) > 0
    by_worker = {}
    for e in c.events():
        if e["name"] == "compute_step":
            by_worker.setdefault(e["worker"], []).append(e["clock"])
    assert sorted(by_worker) == [0, 1]
    for clocks in by_worker.values():
        assert sorted(clocks) == list(range(3))


# ======================================================= ps.metrics fixes
def test_hist_percentile_matches_statistics_reference():
    """Bit-identical to the old materialize-then-statistics.quantiles
    path, across random histograms and the old index-clamping rule."""
    from repro.ps.metrics import hist_percentile

    rng = np.random.RandomState(42)
    for _ in range(200):
        n_vals = rng.randint(1, 8)
        hist = {int(v): int(c) for v, c in zip(
            rng.choice(50, size=n_vals, replace=False),
            rng.randint(1, 30, size=n_vals))}
        xs = sorted(s for s, c in hist.items() for _ in range(c))
        for q in (0.0, 0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
            if len(xs) == 1:
                expected = float(xs[0])
            else:
                qq = statistics.quantiles(xs, n=100)
                expected = qq[min(98, max(0, int(q * 100) - 1))]
            got = hist_percentile(hist, q)
            assert got == expected, (hist, q, got, expected)


def test_hist_percentile_degenerate_and_large_counts():
    from repro.ps.metrics import hist_percentile

    assert hist_percentile({}, 0.5) == 0.0
    assert hist_percentile({7: 1}, 0.99) == 7.0
    assert hist_percentile({3: 0, 7: 1}, 0.5) == 7.0

    # tens of millions of observations: must be O(distinct values),
    # never one list entry per observation
    hist = {s: 10_000_000 for s in range(5)}
    t0 = time.perf_counter()
    p99 = hist_percentile(hist, 0.99)
    elapsed = time.perf_counter() - t0
    assert p99 == 4.0
    assert elapsed < 0.01, f"took {elapsed * 1e3:.1f}ms — materializing?"


def test_staleness_percentile_over_runmetrics():
    from repro.ps.metrics import RunMetrics, staleness_percentile

    m = RunMetrics(policy="x", n_workers=2)
    for s in (0, 0, 1, 1, 1, 2, 5):
        m.record_push(0, s, applied=True, credit=False, time=0.0)
    xs = sorted([0, 0, 1, 1, 1, 2, 5])
    qq = statistics.quantiles(xs, n=100)
    assert staleness_percentile(m, 0.5) == qq[49]
    assert staleness_percentile(m, 0.99) == qq[98]


def test_trajectories_bounded_with_endpoints_preserved():
    from repro.ps.metrics import TRAJECTORY_CAP, RunMetrics

    m = RunMetrics(policy="x", n_workers=1)
    n = TRAJECTORY_CAP * 4
    for i in range(n):
        m.record_push(0, 0, applied=True, credit=False, time=float(i))
        m.record_loss_point(float(i), i, 100.0 - i * 0.001)
    assert len(m.update_trajectory) < TRAJECTORY_CAP
    assert len(m.loss_trajectory) < TRAJECTORY_CAP
    # endpoints survive decimation (readers use [0] and [-1])
    assert m.update_trajectory[0] == (0.0, 1)
    assert m.update_trajectory[-1] == (float(n - 1), n)
    assert m.loss_trajectory[0][2] == 100.0
    assert m.loss_trajectory[-1][2] == pytest.approx(100.0 - (n - 1) * 0.001)
    # time_to_* remain exact at the recorded resolution
    assert m.time_to_updates(n) == float(n - 1)
    assert m.time_to_loss(100.0 - (n - 1) * 0.001) == float(n - 1)
    assert m.time_to_updates(n + 1) is None


def test_perfcount_snapshot_all_and_base_class():
    from repro.perfcount import TRANSPORT, WIRE, snapshot_all

    WIRE.reset()
    TRANSPORT.reset()
    snap = snapshot_all()
    assert set(snap) == {"wire", "transport"}
    assert snap["wire"]["pallas_calls"] == 0
    WIRE.pallas_calls += 3
    TRANSPORT.frames_tx += 2
    before = snapshot_all()
    WIRE.pallas_calls += 1
    d = WIRE.delta(before["wire"])
    assert d["pallas_calls"] == 1
    assert all(v == 0 for k, v in d.items() if k != "pallas_calls")
    assert snapshot_all()["transport"]["frames_tx"] == 2


# ============================================================== CLI
def test_obs_cli_summarize(tmp_path, capsys):
    from repro.obs.__main__ import main as obs_main

    ev = [_evt(0, "compute_step", src="w0", worker=0, clock=0, ts=1.0)]
    ev[0]["dur"] = 0.5
    path = str(tmp_path / "t.jsonl")
    write_jsonl(ev, path)
    assert obs_main(["summarize", path]) == 0
    out = capsys.readouterr().out
    assert "events" in out and "wall time" in out
    assert obs_main(["summarize", path, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["events"] == 1
    assert obs_main(["summarize", str(tmp_path / "missing.json")]) != 0
