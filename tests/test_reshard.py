"""Live resharding (``repro.ft.reshard`` + the server protocol).

Covers the acceptance surface:

* the migration map is a partition: every real element of the old
  layout is covered exactly once, destinations never overlap, and
  ``migrate`` is a bitwise, invertible permutation of the packed
  parameter/momentum buffers;
* gradient translation through the map equals packing the same tree
  under the new plan directly;
* an in-heap fused server reshards S -> S' (up and down) with params
  bitwise-preserved, ``server.version`` continuous, and training after
  the swap matching a never-resharded reference;
* a push racing the migration parks on the retired shard and replays
  exactly once (``WIRE.reshard_parked == WIRE.reshard_replayed``), a
  stale-epoch push is translated, an evicted/unknown epoch bounces
  with the retryable "resync" error, and a gate waiter stranded on an
  abandoned old shard is released;
* a tcp client observes the epoch bump, falls back to a full pull,
  and its old-layout pushes keep landing;
* the headline e2e: a 2-worker DSSP tcp run through ``repro.api``
  reshards S=4 -> S'=6 mid-run (``ft.reshard_round`` trigger) with a
  serving replica attached — every iteration completes, zero pushes
  lost or double-applied, zero staleness violations.
"""

from __future__ import annotations

import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policies import make_policy_factory
from repro.ft.reshard import (
    MigrationMap,
    build_migration,
    equalized_counts,
    live_reshard,
    spread_versions,
)
from repro.perfcount import WIRE
from repro.ps.server import ServerOptimizer
from repro.ps.sharded.plan import build_shard_plan
from repro.ps.sharded.server import ShardedParameterServer
from repro.wireformat import WIRE_LANES, FrameError

warnings.filterwarnings("ignore", category=DeprecationWarning)
pytestmark = pytest.mark.filterwarnings("ignore::UserWarning")


def make_params():
    rng = np.random.RandomState(0)
    return {
        "w0": jnp.asarray(rng.randn(24, 512).astype(np.float32)),
        "w1": jnp.asarray(rng.randn(16, 128).astype(np.float32)),
        "b": jnp.asarray(rng.randn(300).astype(np.float32)),
        "s": jnp.float32(rng.randn()),
    }


def grads_like(params, seed):
    rng = np.random.RandomState(seed)
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.randn(*p.shape).astype(np.float32))
        if p.shape else jnp.float32(rng.randn()), params)


def make_server(params, *, n_workers=2, n_shards=4, policy="asp",
                momentum=0.9, **pkw):
    return ShardedParameterServer(
        params, make_policy_factory(policy, n_workers=n_workers, **pkw),
        lambda: ServerOptimizer(lr=0.05, momentum=momentum),
        n_workers, n_shards, apply_mode="fused")


def max_leaf_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y))) if x.shape
               else abs(float(x) - float(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# ============================================================ map units
@pytest.mark.parametrize("s_old,s_new", [(4, 6), (6, 4), (1, 5), (3, 1)])
def test_migration_map_partitions_every_element(s_old, s_new):
    params = make_params()
    old = build_shard_plan(params, s_old)
    new = build_shard_plan(params, s_new)
    mig = build_migration(old, new)
    assert isinstance(mig, MigrationMap)
    total = old.wire_layout().total_elems
    assert sum(m.size for m in mig.moves) == total
    # destinations are disjoint: sort per new shard and check no overlap
    for k in range(s_new):
        spans = sorted((m.new_off, m.new_off + m.size)
                       for m in mig.moves if m.new_shard == k)
        for (_, hi), (lo2, _) in zip(spans, spans[1:]):
            assert hi <= lo2
    # sources are disjoint too (nothing copied twice)
    for j in range(s_old):
        spans = sorted((m.old_off, m.old_off + m.size)
                       for m in mig.moves if m.old_shard == j)
        for (_, hi), (lo2, _) in zip(spans, spans[1:]):
            assert hi <= lo2
    assert "->" in mig.describe()


def test_migrate_is_bitwise_and_invertible():
    params = make_params()
    old = build_shard_plan(params, 4)
    new = build_shard_plan(params, 6)
    fwd = build_migration(old, new)
    bwd = build_migration(new, old)
    bufs = old.shard_wires(old.pack(params))     # zero-padded regions
    there = fwd.migrate(bufs)
    # forward == packing the same tree under the new plan directly
    want = new.shard_wires(new.pack(params))
    for got, exp in zip(there, want):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    # and back again: a permutation, bitwise
    back = bwd.migrate(there)
    for got, exp in zip(back, bufs):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_migrate_grads_equals_new_plan_repack():
    params = make_params()
    g = grads_like(params, 3)
    old = build_shard_plan(params, 3)
    new = build_shard_plan(params, 5)
    mig = build_migration(old, new)
    translated = mig.migrate_grads(old.shard_wires(old.pack(g)))
    want = new.shard_wires(new.pack(g))
    for got, exp in zip(translated, want):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    # moves_from partitions the move list by source shard
    assert sorted(
        (m for j in range(3) for m in mig.moves_from(j)),
        key=lambda m: (m.old_shard, m.old_off)) == sorted(
        mig.moves, key=lambda m: (m.old_shard, m.old_off))


def test_build_migration_rejects_mismatched_trees():
    a = build_shard_plan(make_params(), 2)
    b = build_shard_plan({"x": jnp.zeros((7, 5))}, 2)
    with pytest.raises(ValueError, match="same tree"):
        build_migration(a, b)


def test_spread_versions_is_sum_preserving():
    for total, n in [(0, 3), (7, 3), (24, 4), (100, 6), (5, 8)]:
        out = spread_versions(total, n)
        assert sum(out) == total and len(out) == n
        assert max(out) - min(out) <= 1      # balanced


def test_equalized_counts_takes_crossshard_minimum():
    got = equalized_counts([{0: 5, 1: 3}, {0: 4, 1: 3}, {0: 9, 1: 2}])
    assert got == {0: 4, 1: 2}
    assert equalized_counts([]) == {}


# ======================================================= in-heap server
class TestLiveReshard:
    def test_reshard_preserves_params_and_version_sum(self):
        params = make_params()
        srv = make_server(params)
        ref = make_server(params)
        wires = [srv.plan.pack(grads_like(params, s)) for s in range(3)]
        for i, w in enumerate(wires):
            srv.push_packed(i % 2, w)
            ref.push_packed(i % 2, w)
        v_sum = srv.version
        before = srv.params

        assert live_reshard(srv, 6) is True
        assert srv.reshard_epoch == 1 and srv.n_shards == 6
        assert len(srv.shard_versions()) == 6
        assert srv.version == v_sum           # the logical clock held
        assert max_leaf_diff(before, srv.params) == 0.0

        # a same-arity call is a no-op (and does not bump the epoch)
        assert srv.reshard(6) is False
        assert srv.reshard_epoch == 1

        # down again: still bitwise vs the never-resharded reference
        assert srv.reshard(3) is True
        assert srv.reshard_epoch == 2 and srv.n_shards == 3
        assert max_leaf_diff(ref.params, srv.params) == 0.0
        srv.stop(), ref.stop()

    def test_training_after_reshard_matches_reference(self):
        params = make_params()
        srv = make_server(params)
        ref = make_server(params)
        g_pre = srv.plan.pack(grads_like(params, 1))
        srv.push_packed(0, g_pre)
        ref.push_packed(0, g_pre)
        srv.reshard(6)
        g_post = grads_like(params, 2)
        srv.push_packed(1, srv.plan.pack(g_post))   # new layout
        ref.push_packed(1, ref.plan.pack(g_post))
        assert max_leaf_diff(ref.params, srv.params) == 0.0
        srv.stop(), ref.stop()

    def test_stale_epoch_push_is_translated_not_lost(self):
        params = make_params()
        srv = make_server(params)
        ref = make_server(params)
        old_plan = srv.plan
        srv.reshard(6)
        WIRE.reset()
        g = grads_like(params, 5)
        # packed under the RETIRED plan, declared as epoch 0 — exactly
        # what a client that has not re-pulled yet sends
        srv.push_packed(0, old_plan.pack(g), epoch=0)
        ref.push_packed(0, ref.plan.pack(g))
        assert WIRE.snapshot()["reshard_translated"] == 1
        assert max_leaf_diff(ref.params, srv.params) == 0.0
        # shape inference maps an old-layout buffer onto its epoch even
        # without an explicit epoch (the in-heap caller path)
        g2 = grads_like(params, 6)
        srv.push_packed(1, old_plan.pack(g2))
        ref.push_packed(1, ref.plan.pack(g2))
        assert max_leaf_diff(ref.params, srv.params) == 0.0
        srv.stop(), ref.stop()

    def test_unknown_epoch_push_bounces_retryable(self):
        srv = make_server(make_params())
        wire = srv.plan.pack(grads_like(make_params(), 0))
        with pytest.raises(ValueError, match="resync"):
            srv.push_packed(0, wire, epoch=7)
        srv.stop()

    def test_push_racing_migration_parks_and_replays_exactly_once(self):
        params = make_params()
        srv = make_server(params)
        ref = make_server(params)
        g_pre = grads_like(params, 1)
        g_mid = grads_like(params, 2)
        g_post = grads_like(params, 3)
        srv.push_packed(0, srv.plan.pack(g_pre))
        mid_wire = srv.plan.pack(g_mid)
        WIRE.reset()
        fired = []

        def hook(shard_index: int) -> None:
            # After shard 1's state is copied out, shards 0-1 are
            # retired (their applies must PARK) while 2-3 are still
            # live — the push below straddles the migration.
            if shard_index == 1 and not fired:
                fired.append(True)
                srv.push_packed(1, mid_wire)

        assert srv.reshard(6, _mid_hook=hook) is True
        ev = WIRE.snapshot()
        assert fired, "mid-migration hook never fired"
        assert ev["reshard_parked"] == 2          # shards 0 and 1 parked
        assert ev["reshard_replayed"] == ev["reshard_parked"]
        srv.push_packed(0, srv.plan.pack(g_post))
        for w, g in ((0, g_pre), (1, g_mid), (0, g_post)):
            ref.push_packed(w, ref.plan.pack(g))
        # the replay folds momentum host-side over moved segments only;
        # same f32 arithmetic as the kernel, so the tolerance is tiny
        assert max_leaf_diff(ref.params, srv.params) < 1e-6
        srv.stop(), ref.stop()

    def test_gate_waiter_on_abandoned_shard_is_released(self):
        params = make_params()
        srv = make_server(params, n_workers=2, policy="bsp")
        wire = srv.plan.pack(grads_like(params, 0))
        done = threading.Event()

        def blocked_push():
            srv.push_packed(0, wire)   # BSP: blocks until worker 1 pushes
            done.set()

        t = threading.Thread(target=blocked_push, daemon=True)
        t.start()
        assert not done.wait(0.3), "BSP barrier did not block"
        srv.reshard(6)                 # abandons the old shards' barriers
        assert done.wait(30.0), "waiter stranded on an abandoned shard"
        t.join(timeout=10.0)
        # the NEW barriers are mutually consistent: a full round releases
        threads = [threading.Thread(
            target=srv.push_packed,
            args=(w, srv.plan.pack(grads_like(params, w))))
            for w in range(2)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=60.0)
        assert not any(th.is_alive() for th in threads)
        srv.stop()

    def test_delta_pull_carries_epoch_and_full_fallback(self):
        params = make_params()
        srv = make_server(params)
        d0 = srv.pull_delta(0, (-1,) * 4)
        assert d0.epoch == 0
        srv.push_packed(0, srv.plan.pack(grads_like(params, 1)))
        srv.reshard(6)
        d = srv.pull_delta(0, d0.versions)       # stale 4-vector
        assert d.full and d.epoch == 1
        assert len(d.versions) == 6
        layout = srv.plan.wire_layout()
        buf = np.zeros((layout.total_rows, WIRE_LANES), layout.dtype)
        for j, r in zip(d.shards, d.regions):
            s = layout.shard_row_start[j]
            buf[s:s + r.shape[0]] = r
        np.testing.assert_array_equal(buf, np.asarray(srv.pull_packed()))
        srv.stop()

    def test_reshard_rejects_tree_mode_and_bad_arity(self):
        srv = ShardedParameterServer(
            make_params(), make_policy_factory("asp", n_workers=1),
            lambda: ServerOptimizer(lr=0.1), 1, 2, apply_mode="tree")
        with pytest.raises(ValueError, match="fused"):
            srv.reshard(3)
        srv.stop()
        srv2 = make_server(make_params())
        with pytest.raises(ValueError, match="n_shards"):
            srv2.reshard(0)
        srv2.stop()


# =============================================================== tcp
def test_tcp_client_observes_epoch_and_stale_push_lands():
    from repro.transport import PSServerEndpoint, make_transport
    params = make_params()
    srv = make_server(params, n_workers=1, n_shards=2)
    old_plan = srv.plan
    ep = PSServerEndpoint(srv)
    tp = make_transport("tcp", n_workers=1)
    tp.serve(ep)
    try:
        c = tp.connect(0)
        c.hello()
        assert c.reshard_epoch == 0
        d0 = c.pull_delta((-1, -1))
        assert d0.epoch == 0

        srv.reshard(3)
        # the stale vector falls back to a full pull at the new epoch
        d = c.pull_delta(d0.versions)
        assert d.full and d.epoch == 1 and len(d.versions) == 3
        # a push still packed under the OLD layout (the client has not
        # rebuilt yet, so its frame carries epoch 0) is translated
        ref = make_server(params, n_workers=1, n_shards=2)
        g = grads_like(params, 9)
        assert c.push_packed(np.asarray(old_plan.pack(g))) is True
        ref.push_packed(0, ref.plan.pack(g))
        assert max_leaf_diff(ref.params, srv.params) == 0.0
        ref.stop()
        # adopting the new epoch, new-layout pushes flow normally
        c.reshard_epoch = 1
        assert c.push_packed(
            np.asarray(srv.plan.pack(grads_like(params, 10)))) is True
        # an epoch the server never issued bounces with the retryable
        # "resync" error a worker turns into a re-pull + retry
        c.reshard_epoch = 9
        with pytest.raises(FrameError, match="resync"):
            c.push_packed(np.asarray(srv.plan.pack(grads_like(params, 11))))
        c.reshard_epoch = 1
        c.bye()
        c.close()
    finally:
        srv.stop()
        tp.shutdown()


# ========================================================= session API
def test_session_manual_reshard_trigger(tmp_path):
    from repro.api import SpecError, build_session
    spec = {
        "model": {"arch": "xlstm-125m", "smoke": True},
        "ps": {"kind": "sharded", "shards": 2, "workers": 1,
               "apply": "fused"},
        "wire": {"format": "packed", "delta_pull": True},
        "sync": {"mode": "asp"},
        "transport": {"kind": "tcp"},
    }
    with build_session(spec, external_workers=True) as session:
        session.start()
        assert session.reshard(3) is True
        assert session.reshard(3) is False       # already there
        assert session.server.n_shards == 3
    mono = dict(spec, ps={"kind": "mono", "workers": 1,
                          "apply": "packed"})
    with build_session(mono, external_workers=True) as session:
        session.start()
        with pytest.raises(SpecError, match="sharded"):
            session.reshard(3)


# ===================================================== e2e acceptance
def test_e2e_dssp_tcp_live_reshard_with_replica():
    """Acceptance: 2-worker DSSP over tcp through ``repro.api``, the
    server live-reshards S=4 -> S'=6 at push round 6 while a serving
    replica stays subscribed — every iteration completes, the loss
    trajectory spans the migration, zero pushes are lost or
    double-applied (parked == replayed, push count conserved), and the
    replica sees zero staleness violations."""
    from repro.api import (DataSpec, ModelSpec, RunSpec, ServeSpec,
                           ServerSpec, SyncSpec, TransportSpec, WireSpec,
                           build_session)
    from repro.api import FtSpec

    spec = RunSpec(
        model=ModelSpec(arch="xlstm-125m", smoke=True),
        data=DataSpec(seq_len=32, global_batch=4),
        ps=ServerSpec(kind="sharded", shards=4, workers=2,
                      apply="fused"),
        sync=SyncSpec(mode="dssp", s_lower=0, s_upper=3),
        wire=WireSpec(format="packed", delta_pull=True),
        transport=TransportSpec(kind="tcp"),
        ft=FtSpec(reshard_shards=6, reshard_round=6),
        serve=ServeSpec(replicas=1, requests=4, request_every_ms=100.0,
                        start_at_version=1, prompt_len=8, max_new=4,
                        max_batch=4, staleness_bound=6))
    WIRE.reset()
    with build_session(spec) as session:
        m = session.run(steps=24)
        server = session.server
        assert server.n_shards == 6, "reshard trigger never fired"
        assert server.reshard_epoch == 1
    ev = WIRE.snapshot()
    # zero lost / double-applied: whatever parked replayed exactly once
    assert ev["reshard_parked"] == ev["reshard_replayed"]
    assert m["iterations_done"] == 24
    assert m["pushes"] == 24                  # every push accounted for
    assert m["final_loss"] is not None and np.isfinite(m["final_loss"])
    losses = [x for x in (m["first_loss"], m["final_loss"])
              if x is not None]
    assert all(np.isfinite(x) for x in losses)
    serve = m["serve"]
    assert serve["requests"] == 4
    assert serve["violations"] == 0
