"""Integration: threaded PS + real jitted JAX training under every paradigm."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policies import make_policy
from repro.ps.server import ParameterServer, ServerOptimizer
from repro.ps.worker import PSWorker, run_cluster


def _make_problem(seed=0, dim=8, n=512):
    rng = np.random.RandomState(seed)
    w_true = rng.randn(dim, 1).astype(np.float32)
    x = rng.randn(n, dim).astype(np.float32)
    y = x @ w_true + 0.01 * rng.randn(n, 1).astype(np.float32)
    return x, y


def _step_fn():
    def loss_fn(params, batch):
        x, y = batch
        pred = x @ params["w"] + params["b"]
        return jnp.mean((pred - y) ** 2)

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return grads, {"loss": loss}

    # step_fn must return (grads, aux)
    return step


def _batches(x, y, worker, n_workers, bs=32, seed=0):
    """Each worker iterates its own shard (data parallelism)."""
    shard_x = x[worker::n_workers]
    shard_y = y[worker::n_workers]
    rng = np.random.RandomState(seed + worker)
    while True:
        idx = rng.randint(0, len(shard_x), size=bs)
        yield shard_x[idx], shard_y[idx]


def _run(policy_name, n_workers=4, iters=30, speed_factors=None, **kw):
    x, y = _make_problem()
    params = {"w": jnp.zeros((x.shape[1], 1)), "b": jnp.zeros((1,))}
    policy = make_policy(policy_name, n_workers=n_workers, **kw)
    server = ParameterServer(params, policy,
                             ServerOptimizer(lr=0.05), n_workers)
    step = _step_fn()
    speed_factors = speed_factors or [1.0] * n_workers
    workers = [
        PSWorker(w, server, step,
                 _batches(x, y, w, n_workers), iters,
                 speed_factor=speed_factors[w],
                 loss_from_aux=lambda aux: float(aux["loss"]))
        for w in range(n_workers)
    ]
    run_cluster(server, workers, timeout=120.0)
    return server, x, y


def _final_loss(server, x, y):
    p = server.params
    pred = x @ p["w"] + p["b"]
    return float(jnp.mean((pred - y) ** 2))


@pytest.mark.parametrize("policy", ["bsp", "asp", "ssp", "dssp"])
def test_training_converges_under_all_paradigms(policy):
    server, x, y = _run(policy, s_lower=1, s_upper=5, staleness=2)
    initial = float(jnp.mean(y ** 2))
    final = _final_loss(server, x, y)
    assert final < 0.25 * initial, f"{policy}: {final} vs {initial}"
    assert server.version > 0
    assert server.metrics.total_pushes == 4 * 30


def test_dssp_bounded_staleness_threaded():
    server, *_ = _run("dssp", s_lower=1, s_upper=4, iters=40,
                      speed_factors=[1.0, 1.0, 1.0, 6.0])
    assert server.metrics.max_staleness <= 4 + 1


def test_heterogeneous_dssp_exploits_range():
    """Table I direction: with a straggler, DSSP runs ahead within its
    range instead of blocking at s_L.  (The *deterministic* wait-reduction
    claim is asserted in the simulator tests — wall-clock threads on one
    CPU core are too noisy for a strict inequality, so here we check the
    mechanism: credits were granted and staleness exceeded s_L, while the
    total wait stays in the same ballpark as SSP's.)"""
    sf = [1.0, 1.0, 1.0, 8.0]
    ssp_server, *_ = _run("ssp", staleness=1, iters=25, speed_factors=sf)
    dssp_server, *_ = _run("dssp", s_lower=1, s_upper=10, iters=25,
                           speed_factors=sf)
    assert dssp_server.metrics.credit_releases > 0
    assert (dssp_server.metrics.mean_staleness
            >= ssp_server.metrics.mean_staleness)
    assert (dssp_server.metrics.total_wait
            <= ssp_server.metrics.total_wait * 1.5 + 0.5)


def test_worker_failure_does_not_deadlock_bsp():
    """Fault tolerance: a worker dying mid-run leaves the barrier group."""
    x, y = _make_problem()
    params = {"w": jnp.zeros((x.shape[1], 1)), "b": jnp.zeros((1,))}
    server = ParameterServer(params, make_policy("bsp"),
                             ServerOptimizer(lr=0.05), 4)
    step = _step_fn()
    workers = [PSWorker(w, server, step, _batches(x, y, w, 4), 40)
               for w in range(4)]
    workers[3].abort()          # dies before its first pull
    run_cluster(server, workers, timeout=60.0)
    done = [w.iterations_done for w in workers]
    assert done[3] == 0
    assert all(d == 40 for d in done[:3])   # survivors completed


def test_elastic_worker_join():
    x, y = _make_problem()
    params = {"w": jnp.zeros((x.shape[1], 1)), "b": jnp.zeros((1,))}
    server = ParameterServer(params, make_policy("ssp", staleness=2),
                             ServerOptimizer(lr=0.05), 2)
    step = _step_fn()
    first = [PSWorker(w, server, step, _batches(x, y, w, 4), 15)
             for w in range(2)]
    run_cluster(server, first, timeout=60.0)
    server.stopped = False      # resume accepting work
    server.add_worker(2)        # joins at the slowest count: no stall
    late = PSWorker(2, server, step, _batches(x, y, 2, 4), 15)
    run_cluster(server, [late], timeout=60.0)
    assert late.iterations_done == 15
