"""Coalesced server apply + version-delta pulls (the PR-5 tentpole).

Covers the acceptance surface:

* ``fused_update_batched`` is bitwise-identical to K sequential
  ``fused_update`` launches for f32 state at every K (and for every
  dtype at K=1), and matches the jnp oracle;
* a coalescing window of 1, and a window fed strictly sequential
  pushes, match the uncoalesced packed path bitwise;
* W concurrent pushes into a window of W fold through ONE batched
  launch per shard (launches per round == shards, not shards x
  workers) with per-worker gating intact;
* ``pull_delta`` with a current vector is an empty delta (and the
  assembled buffer stays bitwise-equal to ``pull_packed``); partial
  advances ship only the advanced shards' bytes; vector mismatches
  fall back to a full snapshot;
* the ``PULL_DELTA``/``DELTA`` frame pair round-trips through the
  codec and across a real tcp process boundary;
* the ``pull_packed`` snapshot cache survives a concurrent push+pull
  hammer with its key always describing its contents (the PR-5
  race-window regression test);
* a 4-worker DSSP tcp run through ``repro.api`` with coalescing +
  delta pulls reaches the same final-loss tolerance as the plain
  packed threads run.
"""

from __future__ import annotations

import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import wireformat as wf
from repro.api.protocol import DeltaPull
from repro.core.policies import make_policy_factory
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.perfcount import WIRE

warnings.filterwarnings("ignore", category=DeprecationWarning)


def make_params():
    rng = np.random.RandomState(0)
    return {
        "w0": jnp.asarray(rng.randn(24, 512).astype(np.float32)),
        "w1": jnp.asarray(rng.randn(16, 128).astype(np.float32)),
        "b": jnp.asarray(rng.randn(300).astype(np.float32)),
        "s": jnp.float32(rng.randn()),
    }


def grads_like(params, seed):
    rng = np.random.RandomState(seed)
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.randn(*p.shape).astype(np.float32))
        if p.shape else jnp.float32(rng.randn()), params)


def make_sharded(params, *, n_workers=4, n_shards=2, policy="asp",
                 coalesce=1, coalesce_wait=None, momentum=0.9,
                 lr=0.05, damping=False):
    from repro.ps.server import ServerOptimizer
    from repro.ps.sharded.server import ShardedParameterServer
    return ShardedParameterServer(
        params, make_policy_factory(policy, n_workers=n_workers),
        lambda: ServerOptimizer(lr=lr, momentum=momentum,
                                staleness_damping=damping),
        n_workers=n_workers, n_shards=n_shards, apply_mode="fused",
        coalesce=coalesce, coalesce_wait=coalesce_wait)


# ================================================================ kernel
@pytest.mark.parametrize("k", [1, 2, 3, 5])
@pytest.mark.parametrize("shape", [(16, 512), (40, 512), (7, 13)])
def test_batched_kernel_bitwise_equals_sequential_launches(k, shape):
    rng = np.random.RandomState(k)
    p = jnp.asarray(rng.randn(*shape).astype(np.float32))
    m = jnp.asarray(rng.randn(*shape).astype(np.float32))
    gs = jnp.asarray(rng.randn(k, *shape).astype(np.float32))
    scales = [1.0 / (1 + j) for j in range(k)]
    po, mo = kops.fused_update_batched(p, m, gs, lr=0.01, beta=0.9,
                                       scales=scales)
    ps_, ms_ = p, m
    for j in range(k):
        ps_, ms_ = kops.fused_update(ps_, ms_, gs[j], lr=0.01, beta=0.9,
                                     scale=scales[j])
    assert jnp.array_equal(po, ps_) and jnp.array_equal(mo, ms_)
    # and the jnp oracle agrees to fp tolerance
    pr, mr = kref.fused_update_batched_ref(p, m, gs, lr=0.01, beta=0.9,
                                           scales=scales)
    np.testing.assert_allclose(np.asarray(po), np.asarray(pr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(mr),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_batched_kernel_k1_bitwise_every_dtype(dtype):
    rng = np.random.RandomState(1)
    p = jnp.asarray(rng.randn(16, 512), dtype)
    m = jnp.asarray(rng.randn(16, 512), dtype)
    g = jnp.asarray(rng.randn(1, 16, 512), dtype)
    po, mo = kops.fused_update_batched(p, m, g, lr=0.02, beta=0.9,
                                       scales=[0.5])
    p1, m1 = kops.fused_update(p, m, g[0], lr=0.02, beta=0.9, scale=0.5)
    assert jnp.array_equal(po, p1) and jnp.array_equal(mo, m1)


def test_batched_kernel_rejects_bad_shapes():
    p = jnp.zeros((8, 512))
    m = jnp.zeros((8, 512))
    with pytest.raises(ValueError, match="do not match"):
        kops.fused_update_batched(p, m, jnp.zeros((2, 8, 256)), lr=0.1)
    with pytest.raises(ValueError, match="scales"):
        kops.fused_update_batched(p, m, jnp.zeros((2, 8, 512)), lr=0.1,
                                  scales=[1.0])


# ==================================================== coalesced server
def test_window_of_one_is_bitwise_the_uncoalesced_path():
    params = make_params()
    base = make_sharded(params, coalesce=1)
    co = make_sharded(params, coalesce=4, coalesce_wait=0.0)
    wires = [base.plan.pack(grads_like(params, s)) for s in range(3)]
    for i, w in enumerate(wires):
        base.push_packed(i % 4, w)
        co.push_packed(i % 4, w)   # sequential -> every batch has K=1
    assert co.shard_versions() == base.shard_versions()
    assert jnp.array_equal(co.pull_packed(), base.pull_packed())
    base.stop(), co.stop()


def test_concurrent_window_one_launch_per_shard_per_round():
    params = make_params()
    W, S = 4, 2
    co = make_sharded(params, n_workers=W, n_shards=S, coalesce=W,
                      coalesce_wait=5.0)
    base = make_sharded(params, n_workers=W, n_shards=S, coalesce=1)
    # identical grads for every worker: the sequential in-kernel fold is
    # then order-independent, so the concurrent batch must be BITWISE
    # equal to W sequential pushes regardless of enqueue order.
    wire = base.plan.pack(grads_like(params, 7))
    for w in range(W):
        base.push_packed(w, wire)
    co.push_packed(0, wire)        # warm the compile caches
    base2 = make_sharded(params, n_workers=W, n_shards=S, coalesce=1)
    for w in range(W):
        base2.push_packed(w, wire)

    co2 = make_sharded(params, n_workers=W, n_shards=S, coalesce=W,
                       coalesce_wait=5.0)
    WIRE.reset()
    threads = [threading.Thread(target=co2.push_packed, args=(w, wire))
               for w in range(W)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ev = WIRE.snapshot()
    # ONE batched launch per shard for the whole 4-worker round
    assert ev["pallas_calls"] == S, ev
    assert ev["apply_launches_saved"] == S * (W - 1), ev
    assert co2.shard_versions() == [W] * S          # every push applied
    assert jnp.array_equal(co2.pull_packed(), base2.pull_packed())
    for srv in (co, base, base2, co2):
        srv.stop()


def test_coalesced_gating_still_blocks_per_worker():
    """BSP gating across a coalesced window: the barrier still releases
    per worker, so a full round completes and every push applies."""
    params = make_params()
    W = 3
    srv = make_sharded(params, n_workers=W, n_shards=2, policy="bsp",
                       coalesce=W, coalesce_wait=1.0)
    wire = srv.plan.pack(grads_like(params, 3))
    threads = [threading.Thread(target=srv.push_packed, args=(w, wire))
               for w in range(W)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
    assert not any(t.is_alive() for t in threads), "BSP round deadlocked"
    assert srv.shard_versions() == [W, W]
    srv.stop()


def test_mono_coalesced_matches_uncoalesced():
    from repro.core.policies import make_policy_factory as mpf
    from repro.ps.server import ParameterServer, ServerOptimizer
    params = make_params()
    def mk(c):
        return ParameterServer(
            params, mpf("asp", n_workers=2)(),
            ServerOptimizer(lr=0.05, momentum=0.9), 2,
            apply_mode="packed", coalesce=c, coalesce_wait=0.0)
    base, co = mk(1), mk(4)
    wires = [base.plan.pack(grads_like(params, s)) for s in range(3)]
    for i, w in enumerate(wires):
        base.push_packed(i % 2, w)
        co.push_packed(i % 2, w)
    assert co.version == base.version == 3
    assert jnp.array_equal(co.pull_packed(), base.pull_packed())
    base.stop(), co.stop()


def test_coalesce_rejects_tree_apply():
    from repro.core.policies import make_policy_factory as mpf
    from repro.ps.server import ParameterServer, ServerOptimizer
    with pytest.raises(ValueError, match="coalesce"):
        ParameterServer(make_params(), mpf("asp", n_workers=1)(),
                        ServerOptimizer(lr=0.1), 1, coalesce=2)
    from repro.ps.sharded.server import ShardedParameterServer
    with pytest.raises(ValueError, match="coalesce"):
        ShardedParameterServer(
            make_params(), mpf("asp", n_workers=1),
            lambda: ServerOptimizer(lr=0.1), 1, 2, coalesce=2)


# ======================================================== delta pulls
def test_empty_delta_is_bitwise_the_full_snapshot():
    params = make_params()
    srv = make_sharded(params, n_shards=3)
    wire = srv.plan.pack(grads_like(params, 1))
    srv.push_packed(0, wire)
    d = srv.pull_delta(0, (-1,) * 3)     # bootstrap: everything arrives
    assert not d.full and set(d.shards) == {0, 1, 2}
    layout = srv.plan.wire_layout()
    buf = jnp.zeros((layout.total_rows, wf.WIRE_LANES), layout.dtype)
    for j, r in zip(d.shards, d.regions):
        s = layout.shard_row_start[j]
        buf = buf.at[s:s + r.shape[0]].set(r)
    assert jnp.array_equal(buf, srv.pull_packed())
    d2 = srv.pull_delta(0, d.versions)   # current vector -> empty delta
    assert d2.empty and not d2.full and d2.versions == d.versions
    assert jnp.array_equal(buf, srv.pull_packed())   # nothing moved
    srv.stop()


def test_partial_delta_ships_only_advanced_shards_and_counts_bytes():
    params = make_params()
    srv = make_sharded(params, n_shards=4)
    layout = srv.plan.wire_layout()
    d0 = srv.pull_delta(0, (-1,) * 4)
    WIRE.reset()
    buf = jnp.ones((layout.shard_rows[1], wf.WIRE_LANES), layout.dtype)
    srv.push_packed_shard(0, 1, buf)
    d = srv.pull_delta(0, d0.versions)
    assert d.shards == (1,) and not d.full
    itemsize = jnp.dtype(layout.dtype).itemsize
    full_bytes = layout.total_rows * wf.WIRE_LANES * itemsize
    shipped = layout.shard_rows[1] * wf.WIRE_LANES * itemsize
    ev = WIRE.snapshot()
    assert ev["delta_bytes_tx"] == shipped
    assert ev["full_pull_bytes_avoided"] == full_bytes - shipped
    assert shipped < full_bytes
    # patched buffer == full pull
    wire = srv.pull_packed()
    s = layout.shard_row_start[1]
    assert jnp.array_equal(d.regions[0], wire[s:s + layout.shard_rows[1]])
    srv.stop()


def test_delta_vector_mismatch_falls_back_to_full():
    params = make_params()
    srv = make_sharded(params, n_shards=2)
    for bad in (None, (0,), (0, 0, 0), (99, 99)):
        d = srv.pull_delta(0, bad)
        assert d.full and set(d.shards) == {0, 1}, bad
    srv.stop()


def test_delta_arity_mismatch_after_live_reshard_full_fallback():
    """Regression for the PR-5 fallback x live reshard: a client whose
    version vector is S-long against a server that genuinely migrated
    to S' shards gets a FULL snapshot at the new epoch, and the
    reassembled buffer is bitwise the server's packed state."""
    params = make_params()
    srv = make_sharded(params, n_shards=2)
    d0 = srv.pull_delta(0, (-1, -1))
    assert d0.epoch == 0
    srv.push_packed(0, srv.plan.pack(grads_like(params, 4)))
    srv.reshard(3)                       # live migration, epoch 0 -> 1
    d = srv.pull_delta(0, d0.versions)   # stale 2-vector vs 3 shards
    assert d.full and d.epoch == 1
    assert len(d.versions) == 3 and set(d.shards) == {0, 1, 2}
    layout = srv.plan.wire_layout()
    buf = np.zeros((layout.total_rows, wf.WIRE_LANES), layout.dtype)
    for j, r in zip(d.shards, d.regions):
        s = layout.shard_row_start[j]
        buf[s:s + r.shape[0]] = r
    np.testing.assert_array_equal(buf, np.asarray(srv.pull_packed()))
    # the new vector is current: the next delta is empty, same epoch
    d2 = srv.pull_delta(0, d.versions)
    assert d2.empty and not d2.full and d2.epoch == 1
    srv.stop()


def test_mono_delta_paths():
    from repro.core.policies import make_policy_factory as mpf
    from repro.ps.server import ParameterServer, ServerOptimizer
    params = make_params()
    srv = ParameterServer(params, mpf("asp", n_workers=1)(),
                          ServerOptimizer(lr=0.1), 1, apply_mode="packed")
    d = srv.pull_delta(0, None)
    assert d.full and d.shards == (0,)
    d2 = srv.pull_delta(0, d.versions)
    assert d2.empty and not d2.full
    srv.push_packed(0, srv.plan.pack(grads_like(params, 2)))
    d3 = srv.pull_delta(0, d2.versions)
    assert d3.shards == (0,) and not d3.full
    assert jnp.array_equal(d3.regions[0], srv.pull_packed())
    srv.stop()


def test_tree_mode_server_rejects_pull_delta():
    from repro.ps.server import ServerOptimizer
    from repro.ps.sharded.server import ShardedParameterServer
    srv = ShardedParameterServer(
        make_params(), make_policy_factory("asp", n_workers=1),
        lambda: ServerOptimizer(lr=0.1), 1, 2, apply_mode="tree")
    with pytest.raises(ValueError, match="fused"):
        srv.pull_delta(0, (0, 0))
    srv.stop()


def test_worker_delta_pull_loop_matches_full_pulls():
    """A PSWorker with delta_pull=True trains bitwise-identically to
    one doing full packed pulls (single worker = deterministic)."""
    from repro.ps.worker import PSWorker, run_cluster

    def run(delta):
        params = make_params()
        srv = make_sharded(params, n_workers=1, n_shards=2,
                           policy="dssp")
        plan = srv.plan

        def step(wire_p, batch):
            return wire_p * 0 + 0.01, {"loss": 1.0}

        def batches():
            while True:
                yield None

        w = PSWorker(0, srv, step, batches(), 5, wire_format="packed",
                     delta_pull=delta)
        run_cluster(srv, [w], timeout=120.0)
        out = np.asarray(srv.pull_packed())
        srv.stop()
        return out

    np.testing.assert_array_equal(run(False), run(True))


# ========================================================= frame codec
def test_pull_delta_frame_roundtrip():
    f = wf.Frame(kind=wf.MSG_PULL_DELTA, worker=3,
                 versions=(0, -1, 7, 123456789))
    out = wf.decode_frame(wf.encode_frame(f))
    assert out.kind == wf.MSG_PULL_DELTA
    assert out.versions == (0, -1, 7, 123456789)
    assert out.worker == 3


@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_delta_frame_roundtrip(dtype):
    dt = wf.np_wire_dtype(dtype if isinstance(dtype, str)
                          else np.dtype(dtype).name)
    rng = np.random.RandomState(0)
    r1 = rng.randn(8, wf.WIRE_LANES).astype(dt)
    r2 = rng.randn(16, wf.WIRE_LANES).astype(dt)
    f = wf.Frame(kind=wf.MSG_DELTA, worker=1, flags=wf.FLAG_FULL,
                 versions=(4, 5, 6), delta=[(0, r1), (2, r2)])
    out = wf.decode_frame(wf.encode_frame(f))
    assert out.kind == wf.MSG_DELTA
    assert out.versions == (4, 5, 6)
    assert out.flags & wf.FLAG_FULL
    assert [s for s, _ in out.delta] == [0, 2]
    np.testing.assert_array_equal(out.delta[0][1], r1)
    np.testing.assert_array_equal(out.delta[1][1], r2)


def test_delta_frame_empty_and_malformed():
    f = wf.Frame(kind=wf.MSG_DELTA, versions=(1, 2), delta=[])
    out = wf.decode_frame(wf.encode_frame(f))
    assert out.versions == (1, 2) and list(out.delta) == []
    # truncated body -> FrameError, not garbage
    good = wf.encode_frame(wf.Frame(
        kind=wf.MSG_DELTA, versions=(3,),
        delta=[(0, np.zeros((8, wf.WIRE_LANES), np.float32))]))
    header, _ = wf.decode_header(good[:wf.HEADER_SIZE])
    with pytest.raises(wf.FrameError, match="DELTA"):
        wf.decode_body(header, good[wf.HEADER_SIZE:-16])
    # a PULL_DELTA body that is not an int64 vector is rejected at the
    # header (payload_len % 8 != 0)
    bad = bytearray(wf.encode_frame(wf.Frame(kind=wf.MSG_PULL_DELTA,
                                             versions=(1,))))
    bad_header = wf.HEADER.pack(wf.FRAME_MAGIC, wf.FRAME_VERSION,
                                wf.MSG_PULL_DELTA, 0, 0, -1, -1, 0, 0,
                                5, 0.0)
    with pytest.raises(wf.FrameError, match="PULL_DELTA"):
        wf.decode_header(bad_header)
    del bad


def test_delta_over_tcp_and_shard_routed_endpoint_rejects():
    from repro.transport import PSServerEndpoint, make_transport
    params = make_params()
    srv = make_sharded(params, n_workers=1, n_shards=2)
    layout = srv.plan.wire_layout()
    ep = PSServerEndpoint(srv)
    tp = make_transport("tcp", n_workers=1)
    tp.serve(ep)
    try:
        c = tp.connect(0)
        c.hello()
        d = c.pull_delta((-1, -1))
        assert isinstance(d, DeltaPull) and set(d.shards) == {0, 1}
        host = np.zeros((layout.total_rows, wf.WIRE_LANES), np.float32)
        for j, r in zip(d.shards, d.regions):
            s = layout.shard_row_start[j]
            host[s:s + r.shape[0]] = r
        np.testing.assert_array_equal(host, np.asarray(srv.pull_packed()))
        d2 = c.pull_delta(d.versions)
        assert d2.empty
        dbad = c.pull_delta((0,))           # wrong arity -> full fallback
        assert dbad.full and set(dbad.shards) == {0, 1}
        c.bye()
        c.close()
    finally:
        srv.stop()
        tp.shutdown()
    # shard-routed endpoints refuse delta pulls (the vector spans all
    # shards); exercised at the dispatch layer directly
    srv2 = make_sharded(params, n_workers=1, n_shards=2)
    ep2 = PSServerEndpoint(srv2, shards={0})
    reply = ep2.handle(wf.Frame(kind=wf.MSG_PULL_DELTA, worker=0,
                                versions=(0, 0)))
    assert reply.kind == wf.MSG_ERR and "full-store" in reply.error
    srv2.stop()


# =========================================== snapshot-cache regression
def test_snapshot_cache_key_always_matches_contents_under_hammer():
    """PR-5 satellite: hammer push+pull concurrently and assert the
    version-keyed snapshot cache never serves bytes that disagree with
    its key.

    lr=1, momentum=0, grads=-1 make every applied update add exactly
    +1.0 to each element of its shard, so shard j's region must read
    ``initial + key[j]`` whenever the cache claims version key[j].
    """
    params = {"a": jnp.zeros((64, 512), jnp.float32),
              "b": jnp.zeros((64, 512), jnp.float32)}
    srv = make_sharded(params, n_workers=4, n_shards=2, momentum=0.0,
                       lr=1.0, coalesce=2, coalesce_wait=0.0)
    layout = srv.plan.wire_layout()
    wire_g = srv.plan.pack(jax.tree_util.tree_map(
        lambda p: -jnp.ones_like(p), params))
    stop = threading.Event()
    errors = []

    def pusher(w):
        i = 0
        while not stop.is_set() and i < 25:
            srv.push_packed(w, wire_g)
            i += 1

    def puller():
        while not stop.is_set():
            srv.pull_packed(0)
            with srv._snap_lock:
                key, wire = srv._snap_key, srv._snap_wire
            if key is None:
                continue
            host = np.asarray(wire)
            for j in range(2):
                s = layout.shard_row_start[j]
                region = host[s:s + layout.shard_rows[j]]
                # the cache key leads with the reshard epoch; the
                # per-shard versions follow
                expect = float(key[1 + j])
                if not np.allclose(region, expect):
                    errors.append((key, j, float(region.flat[0])))
                    stop.set()
                    return

    threads = [threading.Thread(target=pusher, args=(w,))
               for w in range(4)] + [threading.Thread(target=puller)
                                     for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads[:4]:
        t.join(timeout=180.0)
    stop.set()
    for t in threads[4:]:
        t.join(timeout=10.0)
    srv.stop()
    assert not errors, f"cache key disagreed with contents: {errors[:3]}"
    assert srv.shard_versions() == [100, 100]   # nothing lost


def test_snapshot_cache_never_goes_backwards():
    """The dominance guard: installing an older per-shard snapshot over
    a newer cached one is refused even when another shard advanced."""
    params = make_params()
    srv = make_sharded(params, n_shards=2)
    srv.pull_packed(0)
    with srv._snap_lock:
        # keys lead with the reshard epoch; versions follow
        srv._snap_key = (0, 5, 5)    # pretend a fresher pull landed
        marker = srv._snap_wire
    # a would-be install with versions (6, 4) at the same epoch is
    # newer on shard 0 but older on shard 1 -> must NOT replace (5, 5)
    key = (0, 6, 4)
    with srv._snap_lock:
        cached = srv._snap_key
        if cached is None or key[0] > cached[0] or (
                key[0] == cached[0]
                and all(n >= c for n, c in zip(key[1:], cached[1:]))
                and any(n > c for n, c in zip(key[1:], cached[1:]))):
            srv._snap_key = key
    assert srv._snap_key == (0, 5, 5)
    assert srv._snap_wire is marker
    srv.stop()


# ===================================================== e2e acceptance
@pytest.mark.parametrize("transport", ["tcp"])
def test_e2e_dssp_coalesced_delta_matches_plain_packed(transport):
    """Acceptance: a 4-worker DSSP run through repro.api with
    ps.coalesce=4 + wire.delta_pull over a real process transport
    reaches the same final-loss tolerance as the plain packed threads
    path, while the server-side perfcount shows coalescing engaged
    (batched launches < one per push per shard)."""
    from repro.api import (DataSpec, ModelSpec, OptimizerSpec, RunSpec,
                           ServerSpec, SyncSpec, TransportSpec, WireSpec,
                           build_session)

    common = dict(
        model=ModelSpec(arch="xlstm-125m", smoke=True),
        data=DataSpec(seq_len=32, global_batch=4),
        optimizer=OptimizerSpec(lr=0.02),
        sync=SyncSpec(mode="dssp", s_lower=0, s_upper=3))
    baseline = RunSpec(
        ps=ServerSpec(kind="sharded", shards=2, workers=4,
                      apply="fused"),
        wire=WireSpec(format="packed"), **common)
    # a wide flusher linger so homogeneous workers' near-simultaneous
    # pushes reliably land in one window on a loaded CI runner (the
    # default 50 ms is tuned for latency, not determinism)
    tentpole = RunSpec(
        ps=ServerSpec(kind="sharded", shards=2, workers=4,
                      apply="fused", coalesce=4,
                      coalesce_wait_ms=500.0),
        wire=WireSpec(format="packed", delta_pull=True),
        transport=TransportSpec(kind=transport), **common)

    with build_session(baseline) as session:
        base = session.run(32)

    WIRE.reset()
    with build_session(tentpole) as session:
        got = session.run(32)
    ev = WIRE.snapshot()

    assert got["pushes"] >= 4 and got["applied_updates"] > 0
    assert np.isfinite(got["final_loss"])
    # same model/data/steps: final losses agree to the asynchrony
    # tolerance (same bound as the existing e2e transport test)
    assert abs(got["final_loss"] - base["final_loss"]) <= \
        max(0.15 * abs(base["final_loss"]), 0.15), (base, got)
    # coalescing engaged: the server did FEWER batched-apply launches
    # than one per shard per push (shards x pushes), because concurrent
    # workers folded into shared windows; and delta pulls shipped
    # fewer bytes than pushes x full snapshots would have.
    shards = 2
    assert ev["apply_launches_saved"] > 0, ev
    assert ev["pallas_calls"] + ev["apply_launches_saved"] >= \
        got["applied_updates"]
    assert ev["pallas_calls"] < shards * got["pushes"], ev
