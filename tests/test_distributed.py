"""Multi-device SPMD tests (subprocess: the main process locked 1 device).

Each test runs a python snippet under XLA_FLAGS=--xla_force_host_platform
_device_count=8 and asserts on its output, covering:
  * sharded train_step execution on a real (2, 4) mesh (not just compile),
  * DSSP delayed-grad equivalence sharded vs single-device,
  * elastic remesh 8 -> 4 devices,
  * cross-pod parameter averaging (shard_map manual over 'pod').
"""

import os
import subprocess
import sys
import textwrap


REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def run_snippet(body: str) -> str:
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_executes_and_matches_single_device():
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh
        from repro.models import registry
        from repro.models.params import spec_tree, sds_tree
        from repro.models.sharding import rules_for_mesh, use_rules
        from jax.sharding import NamedSharding

        cfg = get_smoke_config('h2o-danube-1.8b')
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab_size)
        batch = {'tokens': toks, 'labels': toks}
        lfn = registry.loss_fn(cfg)

        # single device reference
        ref_loss, ref_grads = jax.value_and_grad(
            lambda p: lfn(p, batch)[0])(params)

        mesh = make_mesh((2, 4), ('data', 'model'))
        rules = rules_for_mesh(mesh)
        specs = spec_tree(registry.param_defs(cfg), rules)
        sp = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: hasattr(x, '_normalized_spec') or
                              type(x).__name__ == 'PartitionSpec')
        params_sh = jax.device_put(params, sp)

        def loss_fn(p, b):
            with use_rules(rules):
                return lfn(p, b)[0]

        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params_sh, batch)
        np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-4)
        g1 = jax.tree_util.tree_leaves(ref_grads)
        g2 = jax.tree_util.tree_leaves(grads)
        worst = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(g1, g2))
        assert worst < 5e-3, worst
        print('SHARDED_OK', float(loss))
    """)
    assert "SHARDED_OK" in out


def test_elastic_remesh_preserves_values():
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.elastic import rescale_params
        from repro.models import registry

        cfg = get_smoke_config('h2o-danube-1.8b')
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        p8, mesh8 = rescale_params(cfg, params, 8, model_parallel=4)
        assert mesh8.devices.size == 8, mesh8
        p4, mesh4 = rescale_params(cfg, p8, 4, model_parallel=2)
        assert mesh4.devices.size == 4
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p4)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('ELASTIC_OK')
    """)
    assert "ELASTIC_OK" in out


def test_cross_pod_sync_averages_parameters():
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.dssp_spmd import cross_pod_sync
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        # params replicated within pod, DIFFERENT across pods: emulate by
        # a pod-indexed array then sync must average them
        x = jnp.stack([jnp.full((4, 4), 1.0), jnp.full((4, 4), 3.0)])
        sh = NamedSharding(mesh, P('pod', None, None))
        xs = jax.device_put(x, sh)

        def sync(t):
            return cross_pod_sync(t, mesh, P('pod', None, None))

        out = jax.jit(sync)(xs)
        np.testing.assert_allclose(np.asarray(out),
                                   np.full((2, 4, 4), 2.0))
        print('XPOD_OK')
    """)
    assert "XPOD_OK" in out


def test_dssp_multidevice_matches_single_device_semantics():
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import dssp_spmd
        from repro.launch.mesh import make_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = make_mesh((8,), ('data',))
        g_like = {'w': jnp.zeros((16, 8))}
        st = dssp_spmd.init_pipeline(g_like, depth=3)
        sh = NamedSharding(mesh, P(None, 'data', None))
        st = dssp_spmd.PipelineState(
            buffer=jax.tree_util.tree_map(
                lambda b: jax.device_put(b, sh), st.buffer),
            step=st.step)

        outs = []
        for t in range(4):
            g = {'w': jnp.full((16, 8), float(t + 1))}
            out, valid, st = dssp_spmd.push_pop(st, g, jnp.int32(2))
            outs.append((float(out['w'][0, 0]), float(valid)))
        assert outs[2] == (1.0, 1.0) and outs[3] == (2.0, 1.0), outs
        print('PIPE_OK')
    """)
    assert "PIPE_OK" in out
