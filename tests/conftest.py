"""Shared test configuration.

Collection guard: some test modules are property-based and import
``hypothesis`` at module scope.  On environments without hypothesis
(e.g. a bare container before ``pip install -r requirements-dev.txt``)
importing those modules aborts pytest during *collection*, before a
single test runs.  Detect the situation up front and skip exactly the
modules that need hypothesis, with an explicit reason in the header.
"""

from __future__ import annotations

import importlib.util
import pathlib

_HERE = pathlib.Path(__file__).parent

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


def _needs_hypothesis(path: pathlib.Path) -> bool:
    try:
        src = path.read_text()
    except OSError:
        return False
    return ("import hypothesis" in src) or ("from hypothesis" in src)


_SKIPPED = ([] if HAVE_HYPOTHESIS else
            sorted(p.name for p in _HERE.glob("test_*.py")
                   if _needs_hypothesis(p)))

# pytest reads this to drop the modules from collection entirely.
collect_ignore = list(_SKIPPED)


def pytest_report_header(config):
    if _SKIPPED:
        return ("hypothesis not installed — skipping property-based "
                f"modules: {', '.join(_SKIPPED)} "
                "(pip install -r requirements-dev.txt to run them)")
    return None
