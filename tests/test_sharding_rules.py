"""Size-aware logical-axis sharding rules + param spec derivation."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import registry
from repro.models.params import sds_tree, spec_tree
from repro.models.sharding import multi_pod_rules, \
    single_pod_rules
from repro.optim import make_optimizer
from repro.optim.optimizers import state_partition_specs

SIZES = {"data": 16, "model": 16}
SIZES3 = {"pod": 2, "data": 16, "model": 16}


def test_divisible_dims_shard():
    r = single_pod_rules(SIZES)
    assert r.spec(("batch", None, None), (256, 4096, 2560)) == P("data")
    assert r.spec((None, "fsdp", "model"), (24, 2560, 6912)) == \
        P(None, "data", "model")


def test_non_divisible_dims_drop():
    r = single_pod_rules(SIZES)
    # 40 heads don't divide 16 -> model mapping dropped
    assert r.spec((None, "fsdp", "model", None),
                  (64, 5120, 40, 128)) == P(None, "data")
    # batch=1 (long_500k) -> batch mapping dropped
    assert r.spec(("batch", None), (1, 524288)) == P()


def test_multi_axis_mapping_and_dedup():
    r = multi_pod_rules(SIZES3)
    # batch maps to (pod, data) jointly
    assert r.spec(("batch", None), (256, 4096)) == P(("pod", "data"))
    # cache_seq takes (data, model); a later 'fsdp' may not reuse 'data'
    s = r.spec((None, "cache_seq", "fsdp"), (8, 32768, 4096))
    assert s == P(None, ("data", "model"))


def test_partial_multi_axis_divisibility():
    r = multi_pod_rules(SIZES3)
    # batch 32 divides pod*data=32 exactly
    assert r.spec(("batch",), (32,)) == P(("pod", "data"))
    # batch 16 does not divide 32 -> prefix fallback shards over 'pod'
    assert r.spec(("batch",), (16,)) == P("pod")
    # batch 1 (long_500k) cannot shard at all
    assert r.spec(("batch",), (1,)) == P()


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "qwen3-moe-235b-a22b",
                                  "jamba-v0.1-52b", "whisper-tiny"])
def test_param_specs_align_with_shapes(arch):
    cfg = get_config(arch)
    rules = single_pod_rules(SIZES)
    defs = registry.param_defs(cfg)
    sds = sds_tree(defs, cfg.dtype)
    specs = spec_tree(defs, rules)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_a = jax.tree_util.tree_leaves(sds)
    assert len(flat_s) == len(flat_a)
    for spec, arr in zip(flat_s, flat_a):
        assert len(spec) <= len(arr.shape)
        for dim, ax in zip(arr.shape, tuple(spec)):
            if ax is None:
                continue
            n = 1
            for a in ((ax,) if isinstance(ax, str) else ax):
                n *= SIZES.get(a, 1)
            assert dim % n == 0, (arch, arr.shape, spec)


def test_whisper_padded_vocab_shards():
    cfg = get_config("whisper-tiny")
    assert cfg.vocab_size == 51865
    assert cfg.padded_vocab == 51872 and cfg.padded_vocab % 16 == 0
    rules = single_pod_rules(SIZES)
    defs = registry.param_defs(cfg)
    specs = spec_tree(defs, rules)
    assert tuple(specs["embed"])[0] == "model"   # vocab dim now shards


def test_opt_state_specs_follow_params():
    cfg = get_config("h2o-danube-1.8b")
    rules = single_pod_rules(SIZES)
    defs = registry.param_defs(cfg)
    p_sds = sds_tree(defs, cfg.dtype)
    p_spec = spec_tree(defs, rules)

    adam = make_optimizer("adamw", 1e-3)
    st = state_partition_specs(adam, p_spec, p_sds)
    assert st.mu == p_spec and st.nu == p_spec and st.count == P()

    af = make_optimizer("adafactor")
    st = state_partition_specs(af, p_spec, p_sds)
    # v_row of w_gate (L, d, f) spec (None,'data','model') -> (None,'data')
    wg_row = st.v_row["layers"]["mlp"]["w_gate"]
    assert wg_row == P(None, "data")
    wg_col = st.v_col["layers"]["mlp"]["w_gate"]
    assert wg_col == P(None, "model")
