"""Cross-pod DSSP (dynamic-period local SGD) end-to-end on a virtual
2-pod mesh (subprocess: 8 host devices, mesh (2, 2, 2))."""

import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def run_snippet(body: str) -> str:
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


def test_local_sgd_dynamic_period_converges_and_syncs():
    """Pods take k local steps between averages (k from the Alg-2
    controller); after a sync step the per-pod replicas must be equal,
    between syncs they drift, and the loss still decreases."""
    out = run_snippet("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.dssp_spmd import (DsspScheduleController,
                                          cross_pod_sync)
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        rng = np.random.RandomState(0)
        w_true = rng.randn(16, 1).astype(np.float32)
        X = rng.randn(512, 16).astype(np.float32)
        Y = X @ w_true

        # per-pod replicas: leading 'pod' dim
        w = jnp.zeros((2, 16, 1))
        w = jax.device_put(w, NamedSharding(mesh, P('pod', None, None)))
        xb = jnp.asarray(X).reshape(2, 256, 16)     # pod-sharded data
        yb = jnp.asarray(Y).reshape(2, 256, 1)
        xb = jax.device_put(xb, NamedSharding(mesh, P('pod', 'data', None)))
        yb = jax.device_put(yb, NamedSharding(mesh, P('pod', 'data', None)))

        def loss(w, x, y):
            return jnp.mean((jnp.einsum('pbd,pdo->pbo', x, w) - y) ** 2)

        @jax.jit
        def local_step(w, x, y):
            g = jax.grad(loss)(w, x, y)
            return w - 0.1 * g

        @jax.jit
        def sync(w):
            return cross_pod_sync(w, mesh, P('pod', None, None))

        ctrl = DsspScheduleController(1, 4)
        l0 = float(loss(w, xb, yb))
        drifted = synced = False
        step = 0
        for outer in range(12):
            k = ctrl.period([1.0, 1.3])       # pod step-time telemetry
            assert 1 <= k <= 4
            for _ in range(k):
                w = local_step(w, xb, yb)
                step += 1
            wl = np.asarray(w)
            if not np.allclose(wl[0], wl[1]):
                drifted = True                # pods diverged locally
            w = sync(w)
            wl = np.asarray(w)
            np.testing.assert_allclose(wl[0], wl[1], rtol=1e-6)
            synced = True
        l1 = float(loss(w, xb, yb))
        assert drifted and synced
        assert l1 < 0.2 * l0, (l0, l1)
        print('LOCAL_SGD_OK', l0, '->', l1, 'steps', step)
    """)
    assert "LOCAL_SGD_OK" in out
