"""Serving-path correctness: prefill == forward, decode continues the
prefill cache exactly, int8 KV quantization stays within tolerance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry, transformer
from repro.models.config import ModelConfig


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=256,
                dtype="float32", remat="none")
    base.update(kw)
    return ModelConfig(**base)


def _setup(cfg, b=2, l=12, seed=0):
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, l), 0,
                              cfg.vocab_size)
    return params, toks


def test_prefill_last_logits_match_forward():
    cfg = _cfg()
    params, toks = _setup(cfg)
    full, _ = transformer.forward(cfg, params, toks)
    pre, cache = transformer.forward_prefill(cfg, params, toks)
    np.testing.assert_allclose(np.asarray(pre[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-4,
                               rtol=2e-4)
    assert cache["k"].shape == (cfg.n_layers, 2, 12, cfg.n_kv_heads,
                                cfg.resolved_head_dim)


@pytest.mark.parametrize("kv_dtype", ["", "int8"])
def test_decode_continues_prefill_cache(kv_dtype):
    """Teacher-forced decode from the prefill cache must reproduce the
    full-forward logits position by position (exactly for bf16/f32
    caches, within quantization tolerance for int8)."""
    cfg = _cfg(kv_cache_dtype=kv_dtype)
    b, l_prompt, l_total = 2, 6, 12
    params, toks = _setup(cfg, b=b, l=l_total)
    full, _ = transformer.forward(cfg, params, toks)

    _, cache = transformer.forward_prefill(cfg, params,
                                           toks[:, :l_prompt])
    pad = l_total - l_prompt
    cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, pad))
                        + ((0, 0),) * (v.ndim - 3))
             for k, v in cache.items()}
    tol = 2e-4 if kv_dtype == "" else 0.12
    for i in range(l_prompt, l_total):
        logits, cache = transformer.forward_decode(
            cfg, params, toks[:, i:i + 1], cache, jnp.int32(i))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, i]),
                                   atol=tol, rtol=tol)


def test_sliding_window_ring_cache_matches_forward():
    """Ring-buffer decode with window < context must equal the windowed
    full forward at every position past the window."""
    cfg = _cfg(sliding_window=4)
    b, l = 2, 10
    params, toks = _setup(cfg, b=b, l=l)
    full, _ = transformer.forward(cfg, params, toks)

    fam = registry.family(cfg)
    cache = fam.init_state(cfg, b, l)          # capped at window=4
    assert cache["k"].shape[2] == 4
    for i in range(l):
        logits, cache = transformer.forward_decode(
            cfg, params, toks[:, i:i + 1], cache, jnp.int32(i))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, i]),
                                   atol=3e-4, rtol=3e-4,
                                   err_msg=f"pos {i}")


def test_int8_quantize_roundtrip_error_bound():
    from repro.models.layers import dequantize_kv, quantize_kv
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 7, 2, 32)) * 3.0
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s, jnp.float32)
    # symmetric int8: error bounded by scale/2 = max|row| / 254
    bound = np.asarray(jnp.max(jnp.abs(x), axis=-1) / 254.0 + 1e-6)
    err = np.asarray(jnp.abs(back - x))
    assert (err <= bound[..., None] + 1e-7).all()


def test_padded_vocab_never_sampled():
    cfg = _cfg(vocab_size=250)     # pads to 256
    assert cfg.padded_vocab == 256
    params, toks = _setup(cfg, l=8)
    logits, _ = transformer.forward(cfg, params, toks)
    assert logits.shape[-1] == 256
    assert np.asarray(logits[..., 250:]).max() <= -1e29
    assert int(jnp.argmax(logits, -1).max()) < 250
