"""Packed wire format: layout round-trips, zero-repack server contract,
fused compression kernels, dtype preservation.

Acceptance probes (ISSUE 2):
  * one packed push performs ZERO host-side per-leaf concatenations /
    packs on the server (perfcount probe),
  * at most one ``pallas_call`` per shard for apply plus one for
    compression,
  * packed-path numerics match the tree path on the same push sequence,
  * bf16 trees round-trip without the silent f32 bounce (satellite),
  * the fused-mode piece cache is rebuilt OUTSIDE the shard lock
    (satellite).
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policies import make_policy, make_policy_factory
from repro.kernels import ref
from repro.kernels.fused_compress import fused_int8_ef, fused_topk_ef
from repro.kernels.fused_update import pack_shard, unpack_shard
from repro.perfcount import WIRE
from repro.ps.server import ParameterServer, ServerOptimizer
from repro.ps.sharded import ShardedParameterServer, build_shard_plan
from repro.ps.worker import PSWorker, run_cluster


def _tree(seed=0, shapes=((40, 16), (16,), (8, 8), ()), dtype=np.float32):
    rng = np.random.RandomState(seed)
    return {f"p{i}": jnp.asarray(np.asarray(rng.randn(*s), dtype))
            for i, s in enumerate(shapes)}


def _grads_like(tree, seed):
    rng = np.random.RandomState(seed)
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(
            np.asarray(rng.randn(*p.shape), p.dtype)), tree)


def _max_diff(a, b):
    return max(float(jnp.abs(jnp.asarray(x, jnp.float32)
                             - jnp.asarray(y, jnp.float32)).max())
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# ------------------------------------------------------------ wire layout
@pytest.mark.parametrize("n_shards", [1, 2, 3, 4, 8])
def test_pack_unpack_roundtrip_bitwise(n_shards):
    tree = _tree()
    plan = build_shard_plan(tree, n_shards)
    back = plan.unpack(plan.pack(tree))
    assert _max_diff(tree, back) == 0.0


def test_pack_unpack_roundtrip_with_split_leaves():
    tree = {"big": jnp.arange(1024 * 8, dtype=jnp.float32).reshape(1024, 8),
            "small": jnp.arange(4, dtype=jnp.float32)}
    plan = build_shard_plan(tree, 4)
    assert any(not sl.whole for s in plan.shards for sl in s.slices)
    assert _max_diff(tree, plan.unpack(plan.pack(tree))) == 0.0
    assert _max_diff(tree, plan.assemble_packed(
        plan.split_packed(tree))) == 0.0


def test_pack_unpack_roundtrip_with_empty_shards():
    tree = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    plan = build_shard_plan(tree, 8)
    assert any(len(s.slices) == 0 for s in plan.shards)
    layout = plan.wire_layout()
    assert any(r == 0 for r in layout.shard_rows)
    assert _max_diff(tree, plan.unpack(plan.pack(tree))) == 0.0


@pytest.mark.parametrize("n_shards", [1, 3, 4])
def test_shard_wire_views_equal_packed_pieces(n_shards):
    """The packed wire's per-shard row ranges hold exactly what
    ``pack_shard_pieces`` would build from the tree split — the view IS
    the shard's wire payload."""
    tree = _tree(seed=3)
    plan = build_shard_plan(tree, n_shards)
    wire = plan.pack(tree)
    for j in range(n_shards):
        view = plan.shard_wire(wire, j)
        built = plan.pack_shard_pieces(plan.shard_pieces(tree, j), j)
        assert view.shape == built.shape
        assert float(jnp.abs(view - built).max()) == 0.0
        for a, b in zip(plan.shard_pieces(tree, j),
                        plan.shard_pieces_from_wire(view, j)):
            assert a.shape == b.shape and a.dtype == b.dtype
            assert float(jnp.abs(a - b).max()) == 0.0


def test_wire_rows_are_lane_and_tile_aligned():
    plan = build_shard_plan(_tree(), 3)
    layout = plan.wire_layout()
    for rows in layout.shard_rows:
        assert rows % 8 == 0
    assert layout.total_rows == sum(layout.shard_rows)
    assert layout.pack_idx.shape == (layout.total_rows * 512,)
    assert layout.unpack_idx.shape == (layout.total_elems,)


def test_pack_unpack_jittable():
    tree = _tree(seed=1)
    plan = build_shard_plan(tree, 2)
    f = jax.jit(lambda t: plan.unpack(plan.pack(t)))
    assert _max_diff(tree, f(tree)) == 0.0


# ------------------------------------------------------------ dtype fix
def test_pack_shard_preserves_uniform_bf16():
    """Satellite regression: bf16 leaves used to bounce through f32 on
    pack/unpack; a uniform-dtype shard must round-trip bitwise in its
    own dtype."""
    leaves = [jnp.asarray(np.random.RandomState(0).randn(33, 7),
                          jnp.bfloat16),
              jnp.asarray(np.random.RandomState(1).randn(130),
                          jnp.bfloat16)]
    buf = pack_shard(leaves)
    assert buf.dtype == jnp.bfloat16
    back = unpack_shard(buf, [x.shape for x in leaves],
                        [x.dtype for x in leaves])
    for a, b in zip(leaves, back):
        assert b.dtype == jnp.bfloat16
        assert jnp.all(a == b)


def test_pack_shard_mixed_dtypes_promote_to_f32():
    leaves = [jnp.ones((4, 4), jnp.bfloat16), jnp.ones((8,), jnp.float32)]
    assert pack_shard(leaves).dtype == jnp.float32


def test_plan_wire_dtype_follows_tree():
    bf = _tree(dtype=np.float32)
    bf = jax.tree_util.tree_map(lambda x: x.astype(jnp.bfloat16), bf)
    plan = build_shard_plan(bf, 2)
    wire = plan.pack(bf)
    assert wire.dtype == jnp.bfloat16
    back = plan.unpack(wire)
    for a, b in zip(jax.tree_util.tree_leaves(bf),
                    jax.tree_util.tree_leaves(back)):
        assert b.dtype == jnp.bfloat16
        assert jnp.all(a == b)


def test_bf16_fused_server_keeps_bf16_store():
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.bfloat16), _tree())
    server = ShardedParameterServer(
        params, make_policy_factory("asp"),
        lambda: ServerOptimizer(lr=0.1), 1, 2, apply_mode="fused")
    for st in server.shards:
        assert st._packed_p.dtype == jnp.bfloat16
        assert st._packed_m.dtype == jnp.bfloat16
    g = _grads_like(params, seed=5)
    server.push_packed(0, server.plan.pack(g))
    assert all(x.dtype == jnp.bfloat16
               for x in jax.tree_util.tree_leaves(server.params))


# -------------------------------------------------- packed server contract
def test_packed_push_matches_tree_push():
    """Acceptance: packed-path numerics == tree path on the same push
    sequence (momentum SGD, several shards)."""
    params = _tree()
    tree_srv = ShardedParameterServer(
        params, make_policy_factory("asp"),
        lambda: ServerOptimizer(lr=0.1, momentum=0.9), 2, 3,
        apply_mode="tree")
    pk_srv = ShardedParameterServer(
        params, make_policy_factory("asp"),
        lambda: ServerOptimizer(lr=0.1, momentum=0.9), 2, 3,
        apply_mode="fused")
    for i in range(12):
        g = _grads_like(params, seed=i)
        tree_srv.push(i % 2, g)
        pk_srv.push_packed(i % 2, pk_srv.plan.pack(g))
    assert _max_diff(tree_srv.params, pk_srv.params) < 1e-5
    assert tree_srv.shard_versions() == pk_srv.shard_versions()


def test_packed_push_zero_server_repacks():
    """Acceptance probe: one packed push = zero per-leaf concats, zero
    pack/unpack events, and at most one kernel launch per shard."""
    params = _tree()
    server = ShardedParameterServer(
        params, make_policy_factory("asp"),
        lambda: ServerOptimizer(lr=0.1), 1, 3, apply_mode="fused")
    wire = server.plan.pack(_grads_like(params, seed=0))
    server.push_packed(0, wire)          # warm up
    WIRE.reset()
    server.push_packed(0, wire)
    snap = WIRE.snapshot()
    assert snap["leaf_concats"] == 0, snap
    assert snap["packs"] == 0 and snap["unpacks"] == 0, snap
    assert snap["pallas_calls"] <= server.n_shards, snap


def test_packed_push_with_compression_one_extra_launch_per_shard():
    params = _tree()
    server = ShardedParameterServer(
        params, make_policy_factory("asp"),
        lambda: ServerOptimizer(lr=0.1), 1, 3, apply_mode="fused",
        wire_compression="int8")
    wire = server.plan.pack(_grads_like(params, seed=0))
    server.push_packed(0, wire)
    WIRE.reset()
    server.push_packed(0, wire)
    snap = WIRE.snapshot()
    assert snap["leaf_concats"] == 0 and snap["packs"] == 0, snap
    assert snap["pallas_calls"] <= 2 * server.n_shards, snap


def test_pull_packed_version_keyed_snapshot_cache():
    params = _tree()
    server = ShardedParameterServer(
        params, make_policy_factory("asp"),
        lambda: ServerOptimizer(lr=0.1), 1, 3, apply_mode="fused")
    w1 = server.pull_packed(0)
    assert server.pull_packed(0) is w1      # cache hit, same versions
    server.push_packed(0, server.plan.pack(_grads_like(params, seed=1)))
    w2 = server.pull_packed(0)
    assert w2 is not w1
    assert _max_diff(server.plan.unpack(w2), server.params) < 1e-6


def test_packed_api_requires_fused_store():
    server = ShardedParameterServer(
        _tree(), make_policy_factory("asp"),
        lambda: ServerOptimizer(lr=0.1), 1, 2, apply_mode="tree")
    with pytest.raises(ValueError):
        server.pull_packed(0)
    with pytest.raises(ValueError):
        server.push_packed(0, server.plan.pack(_tree()))


def test_push_packed_rejects_mismatched_wire():
    """Regression: Python slicing clamps, so an undersized wire buffer
    would silently hand trailing shards an empty region and DROP their
    updates — it must be rejected instead."""
    params = _tree()
    server = ShardedParameterServer(
        params, make_policy_factory("asp"),
        lambda: ServerOptimizer(lr=0.1), 1, 3, apply_mode="fused")
    rows = server.plan.wire_layout().total_rows
    with pytest.raises(ValueError):
        server.push_packed(0, jnp.zeros((rows - 8, 512)))
    with pytest.raises(ValueError):
        server.push_packed(0, [jnp.zeros((8, 512))])   # wrong count
    mono = ParameterServer(params, make_policy("asp"),
                           ServerOptimizer(lr=0.1), 1,
                           apply_mode="packed")
    assert mono.plan.wire_layout().total_rows == 8
    with pytest.raises(ValueError):
        mono.push_packed(0, jnp.zeros((16, 512)))


def test_tree_pull_unpacks_outside_shard_lock(monkeypatch):
    """Satellite: after an apply, a fused-mode pull rebuilds the piece
    cache WITHOUT holding the shard lock — a concurrent push must be
    able to take the lock mid-pull."""
    from repro.ps.sharded.plan import ShardPlan
    params = _tree()
    server = ShardedParameterServer(
        params, make_policy_factory("asp"),
        lambda: ServerOptimizer(lr=0.1), 2, 1, apply_mode="fused")
    server.push_packed(0, server.plan.pack(_grads_like(params, seed=0)))
    st = server.shards[0]
    assert st._pieces is None               # cache invalidated

    lock_free_during_unpack = threading.Event()
    orig = ShardPlan.shard_pieces_from_wire

    def probed(self, buf, j, dtype=None):
        # While the pull is unpacking, the shard lock must be free.
        got = st.cond.acquire(timeout=5.0)
        if got:
            st.cond.release()
            lock_free_during_unpack.set()
        return orig(self, buf, j, dtype)

    monkeypatch.setattr(ShardPlan, "shard_pieces_from_wire", probed)
    server.pull(0)
    assert lock_free_during_unpack.is_set()
    # second pull is a cache hit (no new unpack)
    monkeypatch.setattr(ShardPlan, "shard_pieces_from_wire", orig)
    WIRE.reset()
    server.pull(0)
    assert WIRE.snapshot()["unpacks"] == 0


def test_pull_cache_not_installed_if_version_moved(monkeypatch):
    """The outside-lock unpack must not clobber a newer version's state:
    if a push lands mid-unpack, the stale piece cache is discarded."""
    from repro.ps.sharded.plan import ShardPlan
    params = _tree()
    server = ShardedParameterServer(
        params, make_policy_factory("asp"),
        lambda: ServerOptimizer(lr=0.1), 2, 1, apply_mode="fused")
    wire0 = server.plan.pack(_grads_like(params, seed=0))
    server.push_packed(0, wire0)
    st = server.shards[0]
    orig = ShardPlan.shard_pieces_from_wire

    def racing(self, buf, j, dtype=None):
        out = orig(self, buf, j, dtype)
        monkeypatch.setattr(ShardPlan, "shard_pieces_from_wire", orig)
        server.push_packed(1, wire0)                # version moves mid-pull
        return out

    monkeypatch.setattr(ShardPlan, "shard_pieces_from_wire", racing)
    stale = server.pull(0)
    assert st._pieces is None                       # stale cache discarded
    fresh = server.pull(0)
    assert _max_diff(fresh, server.params) == 0.0
    assert _max_diff(stale, fresh) > 0.0            # pull saw the old version


# ------------------------------------------------------ monolithic packed
def test_monolithic_packed_matches_tree():
    params = _tree()
    mono = ParameterServer(params, make_policy("ssp", staleness=2),
                           ServerOptimizer(lr=0.1, momentum=0.9), 3)
    packed = ParameterServer(params, make_policy("ssp", staleness=2),
                             ServerOptimizer(lr=0.1, momentum=0.9), 3,
                             apply_mode="packed")
    for i in range(30):
        g = _grads_like(params, seed=100 + i)
        mono.push(i % 3, g)
        packed.push_packed(i % 3, packed.plan.pack(g))
    assert mono.version == packed.version == 30
    assert _max_diff(mono.params, packed.params) < 1e-5
    assert mono.metrics.staleness_hist == packed.metrics.staleness_hist


def test_monolithic_packed_tree_push_packs_once():
    params = _tree()
    server = ParameterServer(params, make_policy("asp"),
                             ServerOptimizer(lr=0.1), 1,
                             apply_mode="packed")
    g = _grads_like(params, seed=0)
    server.push(0, g)                       # warm up
    WIRE.reset()
    server.push(0, g)
    snap = WIRE.snapshot()
    assert snap["packs"] == 1 and snap["pallas_calls"] == 1, snap


def test_monolithic_packed_guards():
    server = ParameterServer(_tree(), make_policy("asp"),
                             ServerOptimizer(lr=0.1), 1)
    with pytest.raises(ValueError):
        server.push_packed(0, jnp.zeros((8, 512)))
    with pytest.raises(ValueError):
        server.pull_packed(0)
    with pytest.raises(ValueError):
        ParameterServer(_tree(), make_policy("asp"),
                        ServerOptimizer(lr=0.1), 1, apply_mode="bogus")


# ------------------------------------------------------ fused compression
@pytest.mark.parametrize("rows", [8, 24, 64])
def test_fused_int8_ef_matches_ref(rows):
    rng = np.random.RandomState(rows)
    g = jnp.asarray(rng.randn(rows, 512).astype(np.float32))
    e = jnp.asarray(rng.randn(rows, 512).astype(np.float32) * 0.01)
    dq, er = fused_int8_ef(g, e, interpret=True)
    dqr, err_ = ref.fused_int8_ef_ref(g, e)
    np.testing.assert_allclose(dq, dqr, atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(er, err_, atol=1e-6, rtol=1e-6)
    # error feedback identity: decoded + residual == input + carried err
    np.testing.assert_allclose(np.asarray(dq) + np.asarray(er),
                               np.asarray(g) + np.asarray(e), atol=1e-5)


@pytest.mark.parametrize("fraction", [0.02, 0.05, 0.25])
def test_fused_topk_ef_matches_ref_and_keeps_fraction(fraction):
    rng = np.random.RandomState(7)
    g = jnp.asarray(rng.randn(32, 512).astype(np.float32))
    e = jnp.zeros((32, 512), jnp.float32)
    dq, er = fused_topk_ef(g, e, fraction=fraction, interpret=True)
    dqr, err_ = ref.fused_topk_ef_ref(g, e, fraction=fraction)
    np.testing.assert_allclose(dq, dqr, atol=1e-6)
    np.testing.assert_allclose(er, err_, atol=1e-6)
    kept = float((np.asarray(dq) != 0).mean())
    assert fraction * 0.8 <= kept <= fraction * 1.5, kept
    np.testing.assert_allclose(np.asarray(dq) + np.asarray(er),
                               np.asarray(g), atol=1e-5)


def test_fused_compress_empty_and_bad_shapes():
    z = jnp.zeros((0, 512))
    assert fused_int8_ef(z, z)[0].shape == (0, 512)
    with pytest.raises(ValueError):
        fused_int8_ef(jnp.zeros((7, 512)), jnp.zeros((7, 512)))
    with pytest.raises(ValueError):
        fused_topk_ef(jnp.zeros((8, 512)), jnp.zeros((16, 512)))
    with pytest.raises(ValueError):
        fused_topk_ef(jnp.zeros((8, 512)), jnp.zeros((8, 512)),
                      fraction=0.0)


def test_wire_compression_error_feedback_converges():
    """Error feedback keeps the compression bias from accumulating: the
    sum of decoded pushes tracks the sum of raw gradients."""
    params = _tree()
    raw = ShardedParameterServer(
        params, make_policy_factory("asp"),
        lambda: ServerOptimizer(lr=0.05), 1, 2, apply_mode="fused")
    comp = ShardedParameterServer(
        params, make_policy_factory("asp"),
        lambda: ServerOptimizer(lr=0.05), 1, 2, apply_mode="fused",
        wire_compression="int8")
    for i in range(16):
        w = raw.plan.pack(_grads_like(params, seed=i))
        raw.push_packed(0, w)
        comp.push_packed(0, w)
    drift = _max_diff(raw.params, comp.params)
    assert 0.0 < drift < 0.05, drift


# ------------------------------------------------------ end-to-end worker
def _make_problem(seed=0, dim=8, n=512):
    rng = np.random.RandomState(seed)
    w_true = rng.randn(dim, 1).astype(np.float32)
    x = rng.randn(n, dim).astype(np.float32)
    y = x @ w_true + 0.01 * rng.randn(n, 1).astype(np.float32)
    return x, y


def _batches(x, y, worker, n_workers, bs=32, seed=0):
    sx, sy = x[worker::n_workers], y[worker::n_workers]
    rng = np.random.RandomState(seed + worker)
    while True:
        idx = rng.randint(0, len(sx), size=bs)
        yield sx[idx], sy[idx]


def test_packed_worker_trains_through_sharded_server():
    """PSWorker(wire_format='packed') + jitted unpack-grad-pack step
    converges through the packed hot path."""
    x, y = _make_problem()
    params = {"w": jnp.zeros((x.shape[1], 1)), "b": jnp.zeros((1,))}
    server = ShardedParameterServer(
        params, make_policy_factory("dssp", n_workers=3, s_lower=1,
                                    s_upper=5),
        lambda: ServerOptimizer(lr=0.05), 3, 2, apply_mode="fused")
    plan = server.plan

    def loss_fn(p, batch):
        xb, yb = batch
        return jnp.mean((xb @ p["w"] + p["b"] - yb) ** 2)

    @jax.jit
    def step(wire, batch):
        p = plan.unpack(wire)
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        return plan.pack(grads), {"loss": loss}

    workers = [PSWorker(w, server, step, _batches(x, y, w, 3), 30,
                        wire_format="packed")
               for w in range(3)]
    run_cluster(server, workers, timeout=120.0)
    pred = x @ server.params["w"] + server.params["b"]
    final = float(jnp.mean((pred - y) ** 2))
    assert final < 0.25 * float(jnp.mean(y ** 2))
    assert server.metrics.total_pushes == 3 * 30
    assert all(v == 3 * 30 for v in server.shard_versions())
