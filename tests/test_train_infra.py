"""Trainer + checkpoint/restart + data determinism + DSSP-SPMD semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import dssp_spmd
from repro.data.synthetic import DataConfig, batches
from repro.launch.train import Trainer


def _mk_trainer(tmp_path=None, sync="dssp", arch="h2o-danube-1.8b", **kw):
    cfg = get_smoke_config(arch)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4)
    return Trainer(cfg, data_cfg, sync=sync, lr=5e-3,
                   checkpoint_dir=str(tmp_path) if tmp_path else None,
                   save_every=5, **kw)


# ------------------------------------------------------------------ data
def test_data_deterministic_and_host_sharded():
    cfg = get_smoke_config("h2o-danube-1.8b")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    a = next(batches(cfg, dc))
    b = next(batches(cfg, dc))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # two hosts' shards tile the single-host batch
    h0 = next(batches(cfg, dc, host_index=0, n_hosts=2))
    h1 = next(batches(cfg, dc, host_index=1, n_hosts=2))
    np.testing.assert_array_equal(a["tokens"][0::2], h0["tokens"])
    np.testing.assert_array_equal(a["tokens"][1::2], h1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_resume_cursor():
    cfg = get_smoke_config("h2o-danube-1.8b")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    it = batches(cfg, dc)
    seq = [next(it)["tokens"] for _ in range(5)]
    it2 = batches(cfg, dc, start_step=3)
    np.testing.assert_array_equal(next(it2)["tokens"], seq[3])


# ------------------------------------------------------------- checkpoint
def test_checkpoint_atomic_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    mgr.save(3, tree, extras={"next_step": 3})
    mgr.save(7, tree, extras={"next_step": 7})
    mgr.save(9, tree, extras={"next_step": 9})
    assert mgr.steps() == [7, 9]          # keep=2 GC'd step 3
    restored, extras = mgr.restore(9, tree)
    np.testing.assert_array_equal(restored["a"], tree["a"])
    assert extras["next_step"] == 9


def test_checkpoint_rejects_shape_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore(1, {"a": jnp.ones((3, 3))})


def test_trainer_restart_is_bit_exact(tmp_path):
    """Crash/restart must reproduce the uninterrupted run exactly."""
    t1 = _mk_trainer(tmp_path / "a", sync="dssp")
    log1 = t1.train(12, verbose=False)

    t2 = _mk_trainer(tmp_path / "b", sync="dssp")
    t2.train(5, verbose=False)
    t2.ckpt.wait()
    t3 = _mk_trainer(tmp_path / "b", sync="dssp")
    assert t3.resume()
    assert t3.step_idx == 5
    log3 = t3.train(7, verbose=False)
    np.testing.assert_allclose(log1.losses[-1], log3.losses[-1],
                               rtol=1e-5)
    flat1 = jax.tree_util.tree_leaves(t1.params)
    flat3 = jax.tree_util.tree_leaves(t3.params)
    for a, b in zip(flat1, flat3):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


# --------------------------------------------------------------- training
@pytest.mark.parametrize("sync", ["bsp", "ssp", "dssp"])
def test_trainer_converges_under_each_sync(sync):
    t = _mk_trainer(sync=sync, s_lower=0 if sync == "bsp" else 1,
                    s_upper=3)
    log = t.train(40, verbose=False)
    assert log.losses[-1] < log.losses[0] * 0.98
    if sync == "dssp":
        assert all(1 <= d <= 3 for d in log.delays[1:])


def test_dssp_delay_zero_equals_bsp():
    """push_pop(delay=0) must reproduce BSP exactly."""
    cfg = get_smoke_config("h2o-danube-1.8b")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    a = Trainer(cfg, dc, sync="bsp", lr=1e-2, staleness_damping=False)
    b = Trainer(cfg, dc, sync="ssp", s_lower=0, s_upper=2, lr=1e-2,
                staleness_damping=False)
    # force ssp's fixed delay to 0 by monkeypatching the loop constant
    b.s_lower = 0
    a.train(5, verbose=False)

    # manual loop with delay=0 through b's pipeline step
    from repro.data.synthetic import batches as mkb
    it = mkb(cfg, dc)
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        (b.params, b.opt_state, b.pipeline, b.err_state, loss) = \
            b._jit_step(b.params, b.opt_state, b.pipeline, b.err_state,
                        batch, jnp.int32(0))
    for x, y in zip(jax.tree_util.tree_leaves(a.params),
                    jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=1e-6)


def test_gradient_compression_still_converges():
    t = _mk_trainer(sync="dssp", compressor="int8")
    log = t.train(40, verbose=False)
    assert log.losses[-1] < log.losses[0] * 0.98


# ------------------------------------------------------- pipeline semantics
def test_push_pop_ring_semantics():
    g1 = {"w": jnp.ones(3)}
    st = dssp_spmd.init_pipeline(g1, depth=3)
    # delay 2: first two steps invalid, then grads from t-2 emerge
    outs = []
    for t in range(4):
        g = {"w": jnp.full(3, float(t + 1))}
        out, valid, st = dssp_spmd.push_pop(st, g, jnp.int32(2))
        outs.append((float(out["w"][0]), float(valid)))
    assert outs[0][1] == 0.0 and outs[1][1] == 0.0
    assert outs[2] == (1.0, 1.0)      # step 2 applies grad from step 0
    assert outs[3] == (2.0, 1.0)


def test_controller_delay_tracks_collective_time():
    c = dssp_spmd.DsspScheduleController(1, 8)
    for _ in range(3):
        c.observe(step_time=0.1, collective_time=0.25)
    assert c.delay() == 3                  # ceil(0.25 / 0.1)
    for _ in range(8):
        c.observe(step_time=0.1, collective_time=1.5)
    assert c.delay() == 8                  # clamped at s_upper
    for _ in range(8):
        c.observe(step_time=0.1, collective_time=0.0)
    assert c.delay() == 1                  # never below s_lower


def test_controller_period_from_pod_skew():
    c = dssp_spmd.DsspScheduleController(2, 10)
    # homogeneous pods: Alg-2 alignment = one extra local step (the next
    # push of the slowest pod lands exactly one interval later)
    homog = c.period([1.0, 1.0])
    assert homog == 3                       # s_lower + 1
    # a 3x slower pod: the fast pod runs more extra local steps
    skewed = c.period([1.0, 3.0])
    assert skewed > homog
    assert skewed <= 10                     # bounded by s_upper
