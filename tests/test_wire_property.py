"""Property tests: the offset-based packed wire format is bitwise
equivalent to the list-based ``split``/``assemble`` path.

Sweeps randomly-shaped ragged pytrees (scalars, vectors, matrices,
higher-rank leaves, mixed magnitudes), shard counts that force empty
shards and leading-axis splitting of oversized leaves, and asserts:

  * ``unpack(pack(tree)) == tree`` bitwise,
  * ``assemble(split(tree)) == assemble_packed(split_packed(tree))``,
  * each shard's wire region equals the packed tree-split pieces,
  * per-shard piece round-trips agree between the two formats.

Guarded by ``tests/conftest.py``: on containers without ``hypothesis``
this module is dropped from collection with an explicit header note.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.ps.sharded.plan import WIRE_LANES, build_shard_plan

_shape = st.one_of(
    st.just(()),                                           # scalar
    st.tuples(st.integers(1, 70)),                         # ragged vector
    st.tuples(st.integers(1, 40), st.integers(1, 17)),     # matrix
    st.tuples(st.integers(1, 6), st.integers(1, 5),
              st.integers(1, 7)),                          # rank-3
)


def _tree_from(shapes, seed):
    rng = np.random.RandomState(seed)
    return {f"leaf{i}": jnp.asarray(
        np.asarray(rng.randn(*s) * 10 ** rng.randint(-3, 3), np.float32))
        for i, s in enumerate(shapes)}


def _leaves_equal(a, b):
    return all(x.shape == y.shape and x.dtype == y.dtype
               and bool(jnp.all(x == y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


@given(shapes=st.lists(_shape, min_size=1, max_size=10),
       n_shards=st.integers(1, 9),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_bitwise_equals_split_assemble(shapes, n_shards, seed):
    tree = _tree_from(shapes, seed)
    plan = build_shard_plan(tree, n_shards)

    via_lists = plan.assemble(plan.split(tree))
    via_wire = plan.unpack(plan.pack(tree))
    assert _leaves_equal(tree, via_lists)
    assert _leaves_equal(tree, via_wire)
    assert _leaves_equal(via_lists, via_wire)

    shard_bufs = plan.split_packed(tree)
    assert _leaves_equal(tree, plan.assemble_packed(shard_bufs))


@given(shapes=st.lists(_shape, min_size=1, max_size=8),
       n_shards=st.integers(1, 8),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None)
def test_shard_regions_bitwise_equal_list_split(shapes, n_shards, seed):
    tree = _tree_from(shapes, seed)
    plan = build_shard_plan(tree, n_shards)
    wire = plan.pack(tree)
    layout = plan.wire_layout()
    assert all(r % 8 == 0 for r in layout.shard_rows)
    for j in range(n_shards):
        view = plan.shard_wire(wire, j)
        pieces = plan.shard_pieces(tree, j)
        built = plan.pack_shard_pieces(pieces, j)
        assert view.shape == built.shape == (layout.shard_rows[j],
                                             WIRE_LANES)
        assert bool(jnp.all(view == built))
        for a, b in zip(pieces, plan.shard_pieces_from_wire(view, j)):
            assert a.shape == b.shape and bool(jnp.all(a == b))


@given(lead=st.integers(2, 300), row=st.integers(1, 40),
       n_shards=st.integers(2, 8), seed=st.integers(0, 2 ** 16))
@settings(max_examples=25, deadline=None)
def test_oversized_leaf_splitting_roundtrips(lead, row, n_shards, seed):
    """Leaves bigger than the per-shard target get split along the
    leading axis; the wire format must reassemble them bitwise."""
    rng = np.random.RandomState(seed)
    tree = {"big": jnp.asarray(rng.randn(lead, row).astype(np.float32)),
            "tiny": jnp.asarray(rng.randn(3).astype(np.float32))}
    plan = build_shard_plan(tree, n_shards)
    assert _leaves_equal(tree, plan.unpack(plan.pack(tree)))
    assert _leaves_equal(plan.assemble(plan.split(tree)),
                         plan.unpack(plan.pack(tree)))
