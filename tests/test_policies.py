"""Policy semantics (Alg. 1) + simulator invariants, incl. property tests."""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.policies import (
    ASPPolicy, BackupWorkersBSP, BSPPolicy, DSSPPolicy, SSPPolicy, make_policy,
)
from repro.core.staleness import StalenessTracker, regret_bound_constant
from repro.ps.simulator import (
    PSSimulator, constant_intervals, jittered_intervals, run_policy,
)


# ---------------------------------------------------------------- unit level
def test_ssp_releases_within_threshold():
    tr = StalenessTracker(range(3))
    pol = SSPPolicy(2)
    # worker 0 pushes 3 times while others idle: gaps 1,2,3
    assert pol.on_push(tr, 0, tr.record_push(0, 0.0).timestamp).release_now
    assert pol.on_push(tr, 0, tr.record_push(0, 1.0).timestamp).release_now
    assert not pol.on_push(tr, 0, tr.record_push(0, 2.0).timestamp).release_now
    # slowest catching up releases it
    tr.record_push(1, 3.0)
    assert not pol.may_release(tr, 0)   # worker 2 still at 0
    tr.record_push(2, 3.5)
    tr.record_push(1, 4.0), tr.record_push(2, 4.5)
    assert pol.may_release(tr, 0)


def test_asp_never_blocks():
    tr = StalenessTracker(range(2))
    pol = ASPPolicy()
    for i in range(50):
        d = pol.on_push(tr, 0, tr.record_push(0, float(i)).timestamp)
        assert d.release_now and d.apply_update


def test_dssp_grants_and_spends_credits():
    tr = StalenessTracker(range(2))
    pol = DSSPPolicy(1, 5)
    # Build interval history: worker 1 slow (interval 10), worker 0 fast (1).
    tr.record_push(1, 0.0); pol.controller.observe_push(tr, 1)
    tr.record_push(1, 10.0); pol.controller.observe_push(tr, 1)
    t = 10.0
    # worker 0 sprints: gap grows past s_L=1 -> controller consulted
    released, blocked = 0, 0
    for k in range(8):
        t += 1.0
        tr.record_push(0, t)
        d = pol.on_push(tr, 0, t)
        if d.release_now:
            released += 1
        else:
            blocked += 1
            break
    assert released >= 2            # got extra iterations beyond s_L
    assert pol.credits_granted > 0
    assert blocked == 1             # eventually blocks (bounded staleness)
    assert tr.gap(0) <= pol.s_upper + 1


def test_dssp_max_staleness_bounded_by_upper():
    m = run_policy(DSSPPolicy(2, 6), [0.1, 1.0], max_pushes=600)
    # push-time gap can exceed the *run* bound by one (the blocked push)
    assert m.max_staleness <= 6 + 1


def test_backup_workers_drops_stragglers():
    m = run_policy(BackupWorkersBSP(4, 1), [1.0, 1.0, 1.0, 3.0],
                   max_pushes=400)
    assert m.dropped_updates > 0
    assert m.applied_updates + m.dropped_updates == m.total_pushes
    # the slow worker is never blocked by the committed rounds
    assert m.wait_time.get(3, 0.0) == 0.0


def test_make_policy_factory():
    assert make_policy("bsp").name == "bsp"
    assert make_policy("asp").name == "asp"
    assert "ssp" in make_policy("ssp", staleness=4).name
    assert "dssp" in make_policy("dssp", s_lower=2, s_upper=8).name
    assert "backup" in make_policy("backup", n_workers=4, backups=1).name
    with pytest.raises(ValueError):
        make_policy("nope")


def test_regret_bound_monotone_in_staleness():
    assert regret_bound_constant(15, 4) > regret_bound_constant(3, 4)


# ------------------------------------------------------------- simulator level
def test_bsp_lockstep_counts():
    sim = PSSimulator(BSPPolicy(), 4, constant_intervals([1.0, 1.3, 1.7, 2.9]))
    m = sim.run(max_pushes=200)
    # lockstep: every worker pushed within 1 round of each other
    counts = sorted(m.pushes.values())
    assert counts[-1] - counts[0] <= 1
    assert m.max_staleness <= 1


def test_asp_zero_wait():
    m = run_policy(ASPPolicy(), [1.0, 2.0, 4.0], max_pushes=300)
    assert m.total_wait == 0.0


def test_throughput_ordering_heterogeneous():
    """Paper §V.C / Table I: ASP >= DSSP >= SSP(s_L) >= BSP in a
    heterogeneous cluster (iteration throughput)."""
    intervals = [1.0, 1.1, 1.2, 3.0]     # one straggler (mixed GPUs)
    n_pushes = 2000
    th = {}
    for pol in (ASPPolicy(), DSSPPolicy(3, 15), SSPPolicy(3), BSPPolicy()):
        m = run_policy(pol, intervals, max_pushes=n_pushes)
        th[pol.name] = m.throughput
    assert th["asp"] >= th["dssp(s_L=3,s_U=15,last)"] * 0.999
    assert th["dssp(s_L=3,s_U=15,last)"] > th["ssp(s=3)"]
    assert th["ssp(s=3)"] > th["bsp"]


def test_dssp_reduces_wait_vs_ssp_lower_bound():
    """The paper's core claim: dynamically extending the threshold reduces
    fast-worker waiting versus SSP pinned at s_L."""
    intervals = [1.0, 2.6]
    ssp = run_policy(SSPPolicy(3), intervals, max_pushes=1500)
    dssp = run_policy(DSSPPolicy(3, 15), intervals, max_pushes=1500)
    assert dssp.total_wait < ssp.total_wait
    assert dssp.throughput >= ssp.throughput


def test_dssp_staleness_adapts_homogeneous_vs_hetero():
    """C3: in a homogeneous cluster DSSP stays near s_L; with a straggler
    it exploits the range."""
    homog = run_policy(DSSPPolicy(2, 12), [1.0, 1.0, 1.0, 1.0],
                       max_pushes=1000)
    heter = run_policy(DSSPPolicy(2, 12), [1.0, 1.0, 1.0, 4.0],
                       max_pushes=1000)
    assert heter.mean_staleness > homog.mean_staleness


# ------------------------------------------------------------ property tests
policy_strategy = st.sampled_from(["bsp", "asp", "ssp", "dssp"])


@given(
    name=policy_strategy,
    n=st.integers(2, 6),
    seed=st.integers(0, 2**16),
    jitter=st.floats(0.0, 0.4),
)
@settings(max_examples=60, deadline=None)
def test_no_deadlock_and_bounded_staleness(name, n, seed, jitter):
    import random
    rng = random.Random(seed)
    intervals = [rng.uniform(0.2, 3.0) for _ in range(n)]
    pol = make_policy(name, staleness=3, s_lower=2, s_upper=7, n_workers=n)
    sim = PSSimulator(pol, n, jittered_intervals(intervals, jitter, seed))
    m = sim.run(max_pushes=50 * n)
    assert m.total_pushes >= 50 * n      # progressed: no deadlock
    bound = pol.effective_staleness_bound(sim.tracker)
    if bound != float("inf"):
        # push-time gap exceeds the run bound by at most 1 (blocked push)
        assert m.max_staleness <= bound + 1


@given(n=st.integers(2, 5), seed=st.integers(0, 2**16))
@settings(max_examples=40, deadline=None)
def test_updates_conserved(n, seed):
    import random
    rng = random.Random(seed)
    intervals = [rng.uniform(0.5, 2.0) for _ in range(n)]
    m = run_policy(make_policy("dssp", s_lower=1, s_upper=6),
                   intervals, max_pushes=40 * n)
    assert m.applied_updates == m.total_pushes       # DSSP drops nothing
    assert sum(m.pushes.values()) == m.total_pushes
