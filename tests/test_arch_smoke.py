"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness; plus a decode step where the
family supports serving."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import arch_names, get_smoke_config
from repro.models import registry

ARCHS = arch_names()


def _batch(cfg, b=2, l=16, seed=0):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (b, l), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(k, (b, l, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss_fn = registry.loss_fn(cfg)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(p, b)
        return loss, grads

    loss, grads = step(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    finite = jax.tree_util.tree_map(
        lambda g: bool(jnp.isfinite(g).all()), grads)
    assert jax.tree_util.tree_all(finite), f"{arch}: non-finite grads"
    # one SGD step actually changes the params
    new = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, grads)
    changed = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.any(a != b)), params, new)
    assert any(jax.tree_util.tree_leaves(changed)), f"{arch}: params frozen"


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases_smoke(arch):
    """A few SGD steps on one repeated batch must reduce the loss."""
    cfg = get_smoke_config(arch)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss_fn = registry.loss_fn(cfg)

    @jax.jit
    def step(p, b):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        return loss, jax.tree_util.tree_map(lambda x, g: x - 0.05 * g, p,
                                            grads)

    first, params = step(params, batch)
    last = first
    for _ in range(5):
        last, params = step(params, batch)
    assert float(last) < float(first), f"{arch}: {first} -> {last}"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    cfg = get_smoke_config(arch)
    fam = registry.family(cfg)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    b, max_seq = 2, 16
    if cfg.family == "audio":
        state = fam.init_state(cfg, b, max_seq, max_seq)
    else:
        state = fam.init_state(cfg, b, max_seq)
    token = jnp.zeros((b, 1), jnp.int32)

    @jax.jit
    def step(p, t, s, i):
        return fam.decode_fn(cfg, p, t, s, i)

    logits, state = step(params, token, state, jnp.int32(0))
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite decode"
    # second step consumes the returned state
    logits2, _ = step(params, token, state, jnp.int32(1))
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """Full configs instantiate defs only (no arrays) and land in the
    right parameter-count ballpark for their published size."""
    from repro.configs import get_config
    cfg = get_config(arch)
    n = registry.count_params(cfg)
    expected = {
        "h2o-danube-1.8b": (1.4e9, 2.3e9),
        "qwen1.5-110b": (95e9, 125e9),
        "qwen1.5-32b": (28e9, 38e9),
        "mistral-large-123b": (110e9, 135e9),
        "qwen3-moe-235b-a22b": (200e9, 270e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "xlstm-125m": (0.08e9, 0.2e9),
        "whisper-tiny": (0.02e9, 0.08e9),
        "chameleon-34b": (30e9, 39e9),
        "jamba-v0.1-52b": (45e9, 60e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n/1e9:.2f}B params"


# --------------------------------------------- model.kernels dispatch e2e
# The registry knob must be a pure numerics-preserving dispatch: the same
# smoke config trained under any valid ``model.kernels`` string yields a
# finite loss that matches the "auto" run to float tolerance (Pallas
# variants run interpret=True on CPU).
_KERNEL_ARCHS = ["h2o-danube-1.8b",   # pure attention stack
                 "xlstm-125m",        # recurrent family
                 "jamba-v0.1-52b"]    # hybrid: attention + mamba scan


def _one_step_loss(cfg, seed=0):
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, seed=seed)
    loss_fn = registry.loss_fn(cfg)
    (loss, _), grads = jax.jit(jax.value_and_grad(
        loss_fn, has_aux=True))(params, batch)
    finite = jax.tree_util.tree_map(
        lambda g: bool(jnp.isfinite(g).all()), grads)
    assert jax.tree_util.tree_all(finite), f"{cfg.name}: non-finite grads"
    return float(loss)


@pytest.mark.parametrize("arch", _KERNEL_ARCHS)
@pytest.mark.parametrize("kernels", [
    "pallas",
    "xla",
    "attention=xla,ssm_scan=xla_associative",
])
def test_model_kernels_knob_smoke(arch, kernels):
    import dataclasses
    cfg = get_smoke_config(arch)
    base = _one_step_loss(cfg)                      # kernels == "auto"
    got = _one_step_loss(dataclasses.replace(cfg, kernels=kernels))
    assert jnp.isfinite(got)
    assert abs(got - base) <= 1e-3 * max(1.0, abs(base)), \
        f"{arch} kernels={kernels}: loss {got} vs auto {base}"
