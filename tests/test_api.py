"""The repro.api session layer: spec validation, round-trips, engine
wiring, the server protocol, deprecation shims, and the schema lock.

Covers the PR-4 acceptance surface:

* every sync paradigm x every valid (server, wire, transport)
  combination builds via ``build_session`` from a plain dict and
  round-trips ``to_dict``/``from_dict`` bitwise;
* invalid combinations raise ``SpecError`` with an actionable message;
* legacy direct construction still works, emits a single
  ``DeprecationWarning`` naming the replacement, and is behaviorally
  identical to the api-built server;
* ``ServerOptimizer`` LR changes and second instances do not retrace;
* a process-transport run driven purely by a spec matches the
  pre-refactor manual wiring bitwise (single worker = deterministic).
"""

from __future__ import annotations

import json
import pathlib
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import _compat
from repro.api import (
    DataSpec,
    ModelSpec,
    OptimizerSpec,
    RunSpec,
    ServerSpec,
    SpecError,
    SyncSpec,
    TransportSpec,
    WireSpec,
    build_session,
    dump_schema,
)

SCHEMA_PATH = (pathlib.Path(__file__).parent.parent
               / "src" / "repro" / "api" / "schema.json")


# ---------------------------------------------------------------- helpers
def tiny_problem():
    rng = np.random.RandomState(0)
    x = rng.randn(256, 8).astype(np.float32)
    y = (x.sum(-1) > 0).astype(np.int32)

    def loss_fn(params, batch):
        bx, by = batch
        logp = jax.nn.log_softmax(bx @ params["w"] + params["b"])
        return -jnp.mean(jnp.take_along_axis(logp, by[:, None], 1))

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return grads, {"loss": loss}

    def batches(w, n_workers=2, bs=32):
        sx, sy = x[w::n_workers], y[w::n_workers]
        rng = np.random.RandomState(100 + w)
        while True:
            i = rng.randint(0, len(sx), bs)
            yield sx[i], sy[i]

    params = {"w": jnp.zeros((8, 2)), "b": jnp.zeros((2,))}
    return params, step, batches


def all_valid_specs():
    """The full (sync) x (server, apply, wire, transport) grid."""
    combos = [
        # (kind, apply, wire_format, transport, compression)
        ("mono", "tree", "tree", "inproc", "none"),
        ("mono", "packed", "tree", "inproc", "none"),
        ("mono", "packed", "packed", "inproc", "none"),
        ("mono", "packed", "packed", "tcp", "none"),
        ("mono", "packed", "packed", "shmem", "none"),
        ("sharded", "tree", "tree", "inproc", "none"),
        ("sharded", "tree", "tree", "inproc", "int8"),
        ("sharded", "fused", "tree", "inproc", "topk"),
        ("sharded", "fused", "packed", "inproc", "int8"),
        ("sharded", "fused", "packed", "tcp", "none"),
        ("sharded", "fused", "packed", "tcp", "int8"),
        ("sharded", "fused", "packed", "shmem", "topk"),
    ]
    specs = []
    for sync in ("bsp", "ssp", "dssp"):          # spmd has no asp
        specs.append(RunSpec(sync=SyncSpec(mode=sync, s_lower=1,
                                           s_upper=3)))
    for sync in ("bsp", "asp", "ssp", "dssp"):
        for kind, apply_, wire, tp, comp in combos:
            specs.append(RunSpec(
                sync=SyncSpec(mode=sync, staleness=2, s_lower=1,
                              s_upper=3),
                ps=ServerSpec(kind=kind,
                              shards=1 if kind == "mono" else 2,
                              workers=2, apply=apply_),
                wire=WireSpec(format=wire, compression=comp),
                transport=TransportSpec(kind=tp)))
    return specs


# ============================================================ spec layer
def test_every_valid_combo_builds_and_roundtrips():
    for spec in all_valid_specs():
        d = spec.to_dict()
        # bitwise dict round-trip, through JSON
        again = RunSpec.from_dict(json.loads(json.dumps(d)))
        assert again == spec
        assert again.to_dict() == d
        # and the dict form builds a session of the right engine
        session = build_session(d)
        assert session.engine == spec.engine
        assert not session._started   # building is lazy — no server yet


def test_engine_selection():
    assert RunSpec().engine == "spmd"
    assert RunSpec(ps=ServerSpec(kind="mono", shards=1)).engine == \
        "ps-threads"
    assert RunSpec(ps=ServerSpec(kind="sharded", shards=2, apply="fused"),
                   wire=WireSpec(format="packed"),
                   transport=TransportSpec(kind="tcp")).engine == \
        "ps-transport"


@pytest.mark.parametrize("mutate,needle", [
    # the two combinations the issue names explicitly:
    (dict(ps=dict(kind="sharded", shards=2, apply="fused"),
          wire=dict(format="tree"),
          transport=dict(kind="shmem")), "packed"),
    (dict(ps=dict(kind="mono", shards=1, apply="fused")), "monolithic"),
    # and the rest of the cross-field matrix:
    (dict(ps=dict(kind="sharded", shards=2, apply="packed")), "fused"),
    (dict(sync=dict(mode="asp")), "SPMD"),
    (dict(transport=dict(kind="tcp")), "ps.kind='sharded'"),
    (dict(wire=dict(format="packed")), "wire"),
    (dict(ps=dict(kind="sharded", shards=2, apply="tree"),
          wire=dict(format="packed")), "packed-resident"),
    (dict(ps=dict(kind="mono", shards=1),
          wire=dict(compression="int8")), "compression"),
    (dict(ps=dict(kind="mono", shards=1, gating="global")), "gating"),
    (dict(ps=dict(kind="sharded", shards=0)), "shards"),
    (dict(ps=dict(kind="none", shards=2)), "shards=0"),
    (dict(sync=dict(mode="hogwild")), "sync.mode"),
    (dict(sync=dict(s_lower=5, s_upper=2)), "s_lower"),
    (dict(model=dict(arch="not-a-real-arch")), "architecture"),
    (dict(ps=dict(kind="sharded", shards=2),
          optimizer=dict(name="adamw")), "SGD/momentum"),
    (dict(optimizer=dict(lr=-1.0)), "lr"),
    # PR-5 knobs: delta pulls and coalescing ride the packed wire only
    (dict(wire=dict(delta_pull=True)), "delta_pull"),
    (dict(ps=dict(kind="sharded", shards=2, apply="fused"),
          wire=dict(format="tree", delta_pull=True)), "packed"),
    (dict(ps=dict(kind="sharded", shards=2, apply="fused", coalesce=4),
          wire=dict(format="tree")), "coalesce"),
    (dict(ps=dict(coalesce=2)), "coalesce"),
    (dict(ps=dict(kind="sharded", shards=2, coalesce=0)), "window"),
    (dict(ps=dict(kind="sharded", shards=2, coalesce_wait_ms=-5.0)),
     "coalesce_wait_ms"),
    # PR-7 knobs: the ft block needs a PS, a packed store, a faultable
    # transport, and a restartable (tcp) one for server kills
    (dict(ft=dict(snapshot_every_s=1.0, dir="/tmp/ck")),
     "parameter server"),
    (dict(ps=dict(kind="sharded", shards=2, apply="tree"),
          ft=dict(snapshot_every_s=1.0, dir="/tmp/ck")),
     "packed-resident"),
    (dict(ps=dict(kind="sharded", shards=2, apply="fused"),
          wire=dict(format="packed"),
          ft=dict(fault_drop_prob=0.1)), "transport.kind"),
    (dict(ps=dict(kind="sharded", shards=2, apply="fused"),
          wire=dict(format="packed"), transport=dict(kind="shmem"),
          ft=dict(fault_kill_server_round=5)), "tcp"),
    (dict(ps=dict(kind="sharded", shards=2, apply="fused"),
          wire=dict(format="packed"), transport=dict(kind="shmem"),
          ft=dict(reconnect_tries=3)), "tcp"),
    (dict(ft=dict(keep=0)), "keep"),
    (dict(ps=dict(kind="sharded", shards=2, apply="fused"),
          wire=dict(format="packed"), transport=dict(kind="tcp"),
          ft=dict(snapshot_every_s=1.0)), "ft.dir"),
    (dict(ft=dict(fault_drop_prob=1.5)), "probability"),
    # PR-9 knob: model.kernels dispatch strings (repro.kernels.interface)
    (dict(model=dict(kernels="cuda")), "model.kernels"),
    (dict(model=dict(kernels="xla_associative")), "attention"),
    (dict(model=dict(kernels="attention=xla_associative")),
     "ssm_scan={pallas|xla|xla_associative}"),
    (dict(model=dict(kernels="flash=pallas")), "unknown op"),
    (dict(model=dict(kernels="")), "non-empty"),
])
def test_invalid_combos_raise_actionable_spec_errors(mutate, needle):
    base = RunSpec().to_dict()
    for section, fields in mutate.items():
        base[section].update(fields)
    with pytest.raises(SpecError) as e:
        RunSpec.from_dict(base)
    assert needle.lower() in str(e.value).lower(), \
        f"error not actionable: {e.value}"


def test_from_dict_rejects_unknown_keys():
    d = RunSpec().to_dict()
    d["psx"] = {}
    with pytest.raises(SpecError, match="psx"):
        RunSpec.from_dict(d)
    d2 = RunSpec().to_dict()
    d2["sync"]["staleness_bound"] = 3
    with pytest.raises(SpecError, match="staleness_bound"):
        RunSpec.from_dict(d2)


def test_from_dict_missing_sections_use_defaults():
    spec = RunSpec.from_dict({"sync": {"mode": "ssp", "staleness": 4}})
    assert spec.sync.staleness == 4
    assert spec.ps == ServerSpec()


def test_json_roundtrip_bitwise():
    spec = RunSpec(sync=SyncSpec(mode="dssp", s_lower=2, s_upper=9),
                   ps=ServerSpec(kind="sharded", shards=4, workers=3,
                                 apply="fused", straggler=2.5),
                   wire=WireSpec(format="packed", compression="topk",
                                 topk_fraction=0.125),
                   transport=TransportSpec(kind="tcp", port=7001))
    assert RunSpec.from_json(spec.to_json()) == spec


def test_schema_lock_matches_checked_in_file():
    """The CI API-surface lock, enforced as a test too: regenerate with
    ``python -m repro.api --dump-schema > src/repro/api/schema.json``
    whenever the spec surface changes (that diff IS the review)."""
    on_disk = json.loads(SCHEMA_PATH.read_text())
    assert dump_schema() == on_disk, (
        "RunSpec surface drifted from src/repro/api/schema.json — "
        "regenerate it (python -m repro.api --dump-schema) and review "
        "the diff")


def test_build_session_rejects_unknown_overrides():
    with pytest.raises(SpecError, match="override"):
        build_session(RunSpec(), frobnicate=1)


# ======================================================= session engines
def test_threaded_mono_session_trains():
    params, step, batches = tiny_problem()
    spec = RunSpec(model=ModelSpec(arch="custom"),
                   optimizer=OptimizerSpec(lr=0.5),
                   sync=SyncSpec(mode="bsp"),
                   ps=ServerSpec(kind="mono", shards=1, workers=2))
    with build_session(spec, params=params, step_fn=step,
                       batches=batches) as session:
        m = session.run(30)
    assert m["pushes"] == 30
    assert m["final_loss"] < m["first_loss"]
    assert session.server.stopped


def test_threaded_sharded_session_matches_manual_wiring():
    """The api-built sharded run applies exactly like the pre-refactor
    direct wiring (single worker => deterministic push sequence)."""
    from repro.core.policies import make_policy_factory
    from repro.ps.server import ServerOptimizer
    from repro.ps.sharded import ShardedParameterServer
    from repro.ps.worker import PSWorker, run_cluster

    params, step, batches = tiny_problem()
    spec = RunSpec(model=ModelSpec(arch="custom"),
                   optimizer=OptimizerSpec(lr=0.3),
                   sync=SyncSpec(mode="ssp", staleness=2),
                   ps=ServerSpec(kind="sharded", shards=2, workers=1))
    with build_session(spec, params=params, step_fn=step,
                       batches=lambda w: batches(w, 1)) as session:
        session.run(12)
        api_params = session.server.params

    manual = ShardedParameterServer(
        params, make_policy_factory("ssp", n_workers=1, staleness=2),
        lambda: ServerOptimizer(lr=0.3), 1, 2)
    workers = [PSWorker(0, manual, step, batches(0, 1), 12,
                        loss_from_aux=lambda a: float(a["loss"]))]
    run_cluster(manual, workers, timeout=120.0)
    for a, b in zip(jax.tree_util.tree_leaves(api_params),
                    jax.tree_util.tree_leaves(manual.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_spmd_session_matches_direct_trainer():
    """build_session(spmd spec) == Trainer(...) bitwise (SSP: the delay
    is fixed, so the run is deterministic)."""
    from repro.configs import get_smoke_config
    from repro.data.synthetic import DataConfig
    from repro.launch.train import Trainer

    spec = RunSpec(model=ModelSpec(arch="xlstm-125m"),
                   data=DataSpec(seq_len=16, global_batch=4),
                   optimizer=OptimizerSpec(lr=5e-3),
                   sync=SyncSpec(mode="ssp", s_lower=1, s_upper=3))
    with build_session(spec) as session:
        m = session.run(5)

    cfg = get_smoke_config("xlstm-125m")
    trainer = Trainer(cfg, DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=16, global_batch=4),
                      sync="ssp", s_lower=1, s_upper=3, lr=5e-3)
    log = trainer.train(5)
    assert m["final_loss"] == log.losses[-1]
    assert m["first_loss"] == log.losses[0]


def test_external_workers_session_refuses_run():
    params, _, _ = tiny_problem()
    spec = RunSpec(model=ModelSpec(arch="custom"),
                   sync=SyncSpec(mode="asp"),
                   ps=ServerSpec(kind="sharded", shards=2, workers=1))
    session = build_session(spec, params=params, external_workers=True)
    session.start()
    with pytest.raises(SpecError, match="external"):
        session.run(1)
    assert session.server is not None
    session.close()
    assert session.server.stopped


def test_custom_arch_without_overrides_is_actionable():
    spec = RunSpec(model=ModelSpec(arch="custom"),
                   ps=ServerSpec(kind="mono", shards=1, workers=1),
                   sync=SyncSpec(mode="asp"))
    session = build_session(spec)
    with pytest.raises(SpecError, match="overrides"):
        session.start()


# ===================================================== server protocol
def test_protocol_single_shard_defaults_on_mono():
    _compat.reset_legacy_warnings()
    from repro.core.policies import make_policy
    from repro.ps.server import ParameterServer, ServerOptimizer

    params = {"w": jnp.ones((16, 8)), "b": jnp.zeros((5,))}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        server = ParameterServer(params, make_policy("asp"),
                                 ServerOptimizer(lr=0.1), 1,
                                 apply_mode="packed")
    assert server.packed_wire and server.n_shards == 1
    # shard 0 == the whole store
    np.testing.assert_array_equal(
        np.asarray(server.pull_packed_shard(0)),
        np.asarray(server.pull_packed()))
    wire_g = jnp.ones_like(server.pull_packed())
    server.push_packed_shard(0, 0, wire_g)
    assert server.version == 1
    with pytest.raises(ValueError, match="shard"):
        server.pull_packed_shard(1)
    assert server.shard_versions() == [1]
    # lifecycle aliases
    snap = server.snapshot()
    assert set(snap) == {"w", "b"}
    server.shutdown()
    assert server.stopped


def test_endpoint_accepts_mono_server_per_shard_routing():
    """The endpoint no longer type-checks the server: the protocol's
    single-shard defaults make a packed mono server routable."""
    _compat.reset_legacy_warnings()
    from repro.core.policies import make_policy
    from repro.ps.server import ParameterServer, ServerOptimizer
    from repro.transport import PSServerEndpoint

    params = {"w": jnp.ones((16, 8))}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        server = ParameterServer(params, make_policy("asp"),
                                 ServerOptimizer(lr=0.1), 1,
                                 apply_mode="packed")
        endpoint = PSServerEndpoint(server, shards=[0])
    assert endpoint.wire_rows() == server.plan.wire_layout().total_rows
    with pytest.raises(ValueError, match="shard"):
        PSServerEndpoint(server, shards=[0, 1])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        tree_server = ParameterServer(params, make_policy("asp"),
                                      ServerOptimizer(lr=0.1), 1)
    with pytest.raises(ValueError, match="packed"):
        PSServerEndpoint(tree_server)


# ================================================== deprecation shims
def test_legacy_construction_warns_once_and_behaves_identically():
    from repro.core.policies import make_policy
    from repro.ps.server import ParameterServer, ServerOptimizer

    params = {"w": jnp.zeros((6, 3))}
    grads = {"w": jnp.ones((6, 3))}

    _compat.reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = ParameterServer(params, make_policy("asp"),
                                 ServerOptimizer(lr=0.1), 1)
    dep = [w for w in caught
           if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1 and "repro.api" in str(dep[0].message)

    # a second construction does NOT warn again (single warning)
    with warnings.catch_warnings(record=True) as caught2:
        warnings.simplefilter("always")
        ParameterServer(params, make_policy("asp"),
                        ServerOptimizer(lr=0.1), 1)
    assert not [w for w in caught2
                if issubclass(w.category, DeprecationWarning)]

    # the api-built mono server never warns and applies identically
    spec = RunSpec(model=ModelSpec(arch="custom"),
                   optimizer=OptimizerSpec(lr=0.1),
                   sync=SyncSpec(mode="asp"),
                   ps=ServerSpec(kind="mono", shards=1, workers=1))
    _compat.reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as caught3:
        warnings.simplefilter("always")
        session = build_session(spec, params=params,
                                external_workers=True).start()
    assert not [w for w in caught3
                if issubclass(w.category, DeprecationWarning)]
    legacy.push(0, grads)
    session.server.push(0, grads)
    np.testing.assert_array_equal(np.asarray(legacy.params["w"]),
                                  np.asarray(session.server.params["w"]))
    session.close()
    legacy.stop()


def test_train_ps_shim_warns_and_trains():
    from repro.configs import get_smoke_config
    from repro.data.synthetic import DataConfig
    from repro.launch.train import train_ps

    cfg = get_smoke_config("xlstm-125m")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4)
    with pytest.warns(DeprecationWarning, match="build_session"):
        server = train_ps(cfg, data_cfg, sync="bsp", n_steps=2, lr=1e-2,
                          n_shards=2, n_workers=2, arch="xlstm-125m")
    assert server.version > 0
    assert server.stopped


# ============================================ ServerOptimizer satellite
def test_server_optimizer_shares_one_trace_across_lr_and_instances():
    from repro.ps.server import APPLY_TRACES, ServerOptimizer

    # unique leaf shape => guaranteed-fresh jit cache entry
    params = {"q": jnp.ones((3, 17), jnp.float32)}
    grads = {"q": jnp.full((3, 17), 2.0, jnp.float32)}
    opt = ServerOptimizer(lr=0.5)
    before = APPLY_TRACES["count"]
    p1 = opt.step(params, grads, staleness=0)
    assert APPLY_TRACES["count"] == before + 1
    np.testing.assert_allclose(np.asarray(p1["q"]),
                               np.asarray(params["q"]) - 0.5 * 2.0)

    # LR change: new math, NO new trace
    opt.lr = 0.25
    p2 = opt.step(p1, grads, staleness=0)
    assert APPLY_TRACES["count"] == before + 1
    np.testing.assert_allclose(np.asarray(p2["q"]),
                               np.asarray(p1["q"]) - 0.25 * 2.0)

    # a second instance (different lr AND momentum) shares the entry
    opt2 = ServerOptimizer(lr=0.1, momentum=0.9,
                           staleness_damping=True)
    opt2.step(params, grads, staleness=3)
    assert APPLY_TRACES["count"] == before + 1


def test_server_optimizer_momentum_and_damping_math():
    from repro.ps.server import ServerOptimizer

    params = {"w": jnp.zeros((4,), jnp.float32)}
    grads = {"w": jnp.ones((4,), jnp.float32)}
    opt = ServerOptimizer(lr=1.0, momentum=0.5, staleness_damping=True)
    p = opt.step(params, grads, staleness=1)      # scale = 1/2
    np.testing.assert_allclose(np.asarray(p["w"]), -0.5 * np.ones(4))
    p = opt.step(p, grads, staleness=0)           # v = .5*.5 + 1 = 1.25
    np.testing.assert_allclose(np.asarray(p["w"]),
                               -0.5 - 1.25 * np.ones(4))


def test_worker_task_from_mono_spec_clamps_shards():
    """A mono spec may carry ps.shards=0 (the ServerSpec default); the
    spawn payload must still derive a 1-shard plan or every transport
    worker dies in build_shard_plan."""
    from repro.launch.proc_pool import WorkerTask

    spec = RunSpec(model=ModelSpec(arch="xlstm-125m"),
                   ps=ServerSpec(kind="mono", shards=0, workers=1,
                                 apply="packed"),
                   wire=WireSpec(format="packed"),
                   transport=TransportSpec(kind="tcp"))
    task = WorkerTask.from_spec(spec, 3)
    assert task.n_shards == 1
    assert task.arch == "xlstm-125m" and task.n_iterations == 3
    assert task.delta_pull is False


def test_worker_task_carries_delta_pull():
    from repro.launch.proc_pool import WorkerTask

    spec = RunSpec(model=ModelSpec(arch="xlstm-125m"),
                   ps=ServerSpec(kind="sharded", shards=2, workers=2,
                                 apply="fused", coalesce=2),
                   wire=WireSpec(format="packed", delta_pull=True),
                   transport=TransportSpec(kind="tcp"))
    task = WorkerTask.from_spec(spec, 3)
    assert task.delta_pull is True
    assert task.to_dict()["delta_pull"] is True  # crosses the spawn


def test_cli_spec_rejects_every_wiring_flag():
    """--spec is the single source of truth: ANY wiring flag alongside
    it must be rejected, not silently ignored."""
    import subprocess
    import sys

    spec_path = "/tmp/test_api_cli_spec.json"
    with open(spec_path, "w") as f:
        f.write(RunSpec().to_json())
    for extra in (["--ps-wire", "packed"], ["--lr", "0.1"],
                  ["--compress", "int8"], ["--ps-workers", "8"]):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.train",
             "--spec", spec_path, *extra],
            capture_output=True, text=True,
            env={"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
                 "PATH": "/usr/bin:/bin:/usr/local/bin"},
            cwd=str(SCHEMA_PATH.parents[3]))
        assert proc.returncode == 2, (extra, proc.stderr)
        assert "single source of truth" in proc.stderr


# ====================================== spec-driven process transport
def test_tcp_spec_run_matches_prerefactor_wiring_bitwise():
    """One worker (deterministic push sequence) through --spec-style
    build_session vs the literal pre-refactor manual wiring: identical
    final packed parameters."""
    from repro.configs import get_smoke_config
    from repro.core.policies import make_policy_factory
    from repro.launch.proc_pool import (ProcessWorkerPool, WorkerTask,
                                        raise_on_failure)
    from repro.models import registry
    from repro.ps.server import ServerOptimizer
    from repro.ps.sharded import ShardedParameterServer
    from repro.transport import PSServerEndpoint, make_transport

    steps, seq, batch = 3, 16, 4
    spec = RunSpec(model=ModelSpec(arch="xlstm-125m"),
                   data=DataSpec(seq_len=seq, global_batch=batch),
                   optimizer=OptimizerSpec(lr=3e-3),
                   sync=SyncSpec(mode="dssp", staleness=1, s_lower=1,
                                 s_upper=3),
                   ps=ServerSpec(kind="sharded", shards=2, workers=1,
                                 apply="fused"),
                   wire=WireSpec(format="packed"),
                   transport=TransportSpec(kind="tcp"))
    with build_session(spec) as session:
        m = session.run(steps)
        spec_wire = np.asarray(session.server.pull_packed())
    assert m["iterations_done"] == steps

    # ---- the pre-refactor wiring, by hand ----
    cfg = get_smoke_config("xlstm-125m")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        server = ShardedParameterServer(
            params,
            make_policy_factory("dssp", n_workers=1, staleness=1,
                                s_lower=1, s_upper=3),
            lambda: ServerOptimizer(lr=3e-3), 1, 2, apply_mode="fused")
    endpoint = PSServerEndpoint(server)
    tp = make_transport("tcp", n_workers=1)
    tp.serve(endpoint)
    task = WorkerTask(arch="xlstm-125m", n_shards=2, n_iterations=steps,
                      smoke=True, seq_len=seq, global_batch=batch)
    pool = ProcessWorkerPool(tp.address(), task, 1)
    pool.start()
    try:
        results = pool.join(timeout=600.0, endpoint=endpoint)
    finally:
        server.stop()
        tp.shutdown()
        pool.terminate()
    raise_on_failure(results)
    manual_wire = np.asarray(server.pull_packed())
    np.testing.assert_array_equal(spec_wire, manual_wire)
