"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps + hypothesis.

All kernels run in interpret mode on CPU (the TPU lowering is exercised
by the same pallas_call on real hardware)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.fused_update import fused_update
from repro.kernels.rmsnorm import rmsnorm


# ----------------------------------------------------------- flash attn
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
@pytest.mark.parametrize("b,lq,lk,hq,hkv,d", [
    (1, 128, 128, 2, 2, 64),      # MHA square
    (2, 256, 256, 4, 2, 64),      # GQA
    (1, 128, 256, 4, 1, 128),     # MQA, lk > lq (suffix decode-ish)
])
def test_flash_attention_matches_ref(dtype, causal, window, b, lq, lk,
                                     hq, hkv, d):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, lq, hq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, lk, hkv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, lk, hkv, d)).astype(dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64, interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=causal,
                                       window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_block_shape_invariance():
    """Output must not depend on the chosen BlockSpec tiling."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    outs = [flash_attention_fwd(q, k, v, causal=True, block_q=bq,
                                block_k=bk, interpret=True)
            for bq, bk in ((64, 64), (128, 64), (64, 128), (256, 256))]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)


def test_flash_attention_custom_vjp_grads():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 1, 64))
    v = jax.random.normal(ks[2], (1, 128, 1, 64))

    def loss_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v) ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


@given(
    lq_blocks=st.integers(1, 3),
    heads=st.sampled_from([(2, 2), (4, 2), (8, 1)]),
    d=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(lq_blocks, heads, d, seed):
    hq, hkv = heads
    lq = 64 * lq_blocks
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, lq, hq, d))
    k = jax.random.normal(ks[1], (1, lq, hkv, d))
    v = jax.random.normal(ks[2], (1, lq, hkv, d))
    out = flash_attention_fwd(q, k, v, causal=True, block_q=64,
                              block_k=64, interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, expected, atol=3e-5, rtol=3e-5)


# ----------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 256), (2, 16, 512), (8, 3, 128)])
def test_rmsnorm_matches_ref(dtype, shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), shape[-1:]).astype(dtype)
    out = rmsnorm(x, w, interpret=True)
    expected = ref.rmsnorm_ref(x, w)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=tol, rtol=tol)


@given(rows=st.integers(1, 17), d=st.sampled_from([128, 384, 768]),
       seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_rmsnorm_property(rows, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, d))
    w = jnp.ones((d,))
    out = rmsnorm(x, w, interpret=True)
    np.testing.assert_allclose(out, ref.rmsnorm_ref(x, w),
                               atol=1e-5, rtol=1e-5)
    # invariant: rmsnorm output has unit RMS when weight == 1
    rms = np.sqrt(np.mean(np.square(np.asarray(out)), axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


# ----------------------------------------------------------- fused update
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4096,), (300,), (17, 129), (2, 3, 5)])
def test_fused_update_matches_ref(dtype, shape):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    p = jax.random.normal(ks[0], shape).astype(dtype)
    m = jax.random.normal(ks[1], shape, jnp.float32)
    g = jax.random.normal(ks[2], shape).astype(dtype)
    po, mo = fused_update(p, m, g, lr=0.1, beta=0.9, scale=0.5,
                          interpret=True)
    pe, me = ref.fused_update_ref(p, m, g, lr=0.1, beta=0.9, scale=0.5)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(po, np.float32),
                               np.asarray(pe, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(mo, me, atol=1e-5, rtol=1e-5)


def test_fused_update_traced_scalars_no_recompile():
    """lr/scale are data, not constants: one jit trace serves all values."""
    traces = 0

    @jax.jit
    def step(p, m, g, lr, scale):
        nonlocal traces
        traces += 1
        return fused_update(p, m, g, lr=lr, beta=0.9, scale=scale,
                            interpret=True)

    p = jnp.ones((1024,))
    m = jnp.zeros((1024,))
    g = jnp.ones((1024,))
    for lr, sc in ((0.1, 1.0), (0.2, 0.0), (0.01, 0.5)):
        po, mo = step(p, m, g, jnp.float32(lr), jnp.float32(sc))
        pe, me = ref.fused_update_ref(p, m, g, lr=lr, beta=0.9, scale=sc)
        np.testing.assert_allclose(po, pe, atol=1e-6)
    assert traces == 1


@given(n=st.integers(1, 5000), seed=st.integers(0, 2**16),
       beta=st.floats(0.0, 0.999))
@settings(max_examples=15, deadline=None)
def test_fused_update_property(n, seed, beta):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    p = jax.random.normal(ks[0], (n,))
    m = jax.random.normal(ks[1], (n,))
    g = jax.random.normal(ks[2], (n,))
    po, mo = fused_update(p, m, g, lr=0.05, beta=beta, interpret=True)
    pe, me = ref.fused_update_ref(p, m, g, lr=0.05, beta=beta)
    np.testing.assert_allclose(po, pe, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(mo, me, atol=1e-5, rtol=1e-5)
