"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps + hypothesis.

All kernels run in interpret mode on CPU (the TPU lowering is exercised
by the same pallas_call on real hardware)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import interface, ops, ref, registry
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.fused_update import fused_update
from repro.kernels.interface import KernelType
from repro.kernels.rmsnorm import rmsnorm


# ----------------------------------------------------------- flash attn
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
@pytest.mark.parametrize("b,lq,lk,hq,hkv,d", [
    (1, 128, 128, 2, 2, 64),      # MHA square
    (2, 256, 256, 4, 2, 64),      # GQA
    (1, 128, 256, 4, 1, 128),     # MQA, lk > lq (suffix decode-ish)
])
def test_flash_attention_matches_ref(dtype, causal, window, b, lq, lk,
                                     hq, hkv, d):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, lq, hq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, lk, hkv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, lk, hkv, d)).astype(dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64, interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=causal,
                                       window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_block_shape_invariance():
    """Output must not depend on the chosen BlockSpec tiling."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    outs = [flash_attention_fwd(q, k, v, causal=True, block_q=bq,
                                block_k=bk, interpret=True)
            for bq, bk in ((64, 64), (128, 64), (64, 128), (256, 256))]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)


def test_flash_attention_custom_vjp_grads():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 1, 64))
    v = jax.random.normal(ks[2], (1, 128, 1, 64))

    def loss_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v) ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


@given(
    lq_blocks=st.integers(1, 3),
    heads=st.sampled_from([(2, 2), (4, 2), (8, 1)]),
    d=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(lq_blocks, heads, d, seed):
    hq, hkv = heads
    lq = 64 * lq_blocks
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, lq, hq, d))
    k = jax.random.normal(ks[1], (1, lq, hkv, d))
    v = jax.random.normal(ks[2], (1, lq, hkv, d))
    out = flash_attention_fwd(q, k, v, causal=True, block_q=64,
                              block_k=64, interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, expected, atol=3e-5, rtol=3e-5)


# ----------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 256), (2, 16, 512), (8, 3, 128)])
def test_rmsnorm_matches_ref(dtype, shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), shape[-1:]).astype(dtype)
    out = rmsnorm(x, w, interpret=True)
    expected = ref.rmsnorm_ref(x, w)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=tol, rtol=tol)


@given(rows=st.integers(1, 17), d=st.sampled_from([128, 384, 768]),
       seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_rmsnorm_property(rows, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, d))
    w = jnp.ones((d,))
    out = rmsnorm(x, w, interpret=True)
    np.testing.assert_allclose(out, ref.rmsnorm_ref(x, w),
                               atol=1e-5, rtol=1e-5)
    # invariant: rmsnorm output has unit RMS when weight == 1
    rms = np.sqrt(np.mean(np.square(np.asarray(out)), axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


# ----------------------------------------------------------- fused update
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4096,), (300,), (17, 129), (2, 3, 5)])
def test_fused_update_matches_ref(dtype, shape):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    p = jax.random.normal(ks[0], shape).astype(dtype)
    m = jax.random.normal(ks[1], shape, jnp.float32)
    g = jax.random.normal(ks[2], shape).astype(dtype)
    po, mo = fused_update(p, m, g, lr=0.1, beta=0.9, scale=0.5,
                          interpret=True)
    pe, me = ref.fused_update_ref(p, m, g, lr=0.1, beta=0.9, scale=0.5)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(po, np.float32),
                               np.asarray(pe, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(mo, me, atol=1e-5, rtol=1e-5)


def test_fused_update_traced_scalars_no_recompile():
    """lr/scale are data, not constants: one jit trace serves all values."""
    traces = 0

    @jax.jit
    def step(p, m, g, lr, scale):
        nonlocal traces
        traces += 1
        return fused_update(p, m, g, lr=lr, beta=0.9, scale=scale,
                            interpret=True)

    p = jnp.ones((1024,))
    m = jnp.zeros((1024,))
    g = jnp.ones((1024,))
    for lr, sc in ((0.1, 1.0), (0.2, 0.0), (0.01, 0.5)):
        po, mo = step(p, m, g, jnp.float32(lr), jnp.float32(sc))
        pe, me = ref.fused_update_ref(p, m, g, lr=lr, beta=0.9, scale=sc)
        np.testing.assert_allclose(po, pe, atol=1e-6)
    assert traces == 1


@given(n=st.integers(1, 5000), seed=st.integers(0, 2**16),
       beta=st.floats(0.0, 0.999))
@settings(max_examples=15, deadline=None)
def test_fused_update_property(n, seed, beta):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    p = jax.random.normal(ks[0], (n,))
    m = jax.random.normal(ks[1], (n,))
    g = jax.random.normal(ks[2], (n,))
    po, mo = fused_update(p, m, g, lr=0.05, beta=beta, interpret=True)
    pe, me = ref.fused_update_ref(p, m, g, lr=0.05, beta=beta)
    np.testing.assert_allclose(po, pe, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(mo, me, atol=1e-5, rtol=1e-5)

# ================================================== registry parity grid
# Every registry op, every variant, fwd AND bwd (grads flow through the
# jax.custom_vjp pairing) vs. its kernels/ref.py oracle.  Pallas runs
# interpret=True here (CPU); the same dispatch compiles natively on TPU.

def _tols(dtype, bwd=False):
    if dtype == jnp.float32:
        return (1e-4, 1e-4) if bwd else (3e-5, 3e-5)
    # bf16 bwd: variants legitimately differ from the oracle by ~1 ulp
    # in the probs dtype for the PV matmul — allow a couple of ulps
    return (6e-2, 2e-2) if bwd else (2e-2, 2e-2)


def _assert_close(got, want, dtype, bwd=False):
    atol, rtol = _tols(dtype, bwd)
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   atol=atol, rtol=rtol)


def _sq(out):
    """Scalar loss over an array or tuple-of-arrays output (f32)."""
    return sum(jnp.sum(jnp.square(o.astype(jnp.float32)))
               for o in jax.tree_util.tree_leaves(out))


def _attention_inputs(dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(dtype)
    return q, k, v


def _norm_inputs(dtype):
    x = jax.random.normal(jax.random.PRNGKey(8), (4, 256)).astype(dtype)
    r = jax.random.normal(jax.random.PRNGKey(9), (4, 256)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(10), (256,)).astype(dtype)
    return x, r, w


def _ssm_inputs(dtype):
    ks = jax.random.split(jax.random.PRNGKey(11), 6)
    b, l, di, ds = 2, 64, 4, 8
    u = jax.random.normal(ks[0], (b, l, di)).astype(dtype)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (b, l, di))) \
        .astype(dtype)
    a = -jax.nn.softplus(jax.random.normal(ks[2], (di, ds)))
    bmat = jax.random.normal(ks[3], (b, l, ds)).astype(dtype)
    cmat = jax.random.normal(ks[4], (b, l, ds)).astype(dtype)
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    return u, delta, a, bmat, cmat, h0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("variant", ["pallas", "xla"])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
def test_registry_attention_parity(dtype, variant, causal, window):
    q, k, v = _attention_inputs(dtype)
    spec = f"attention={variant}"
    out = registry.attention(q, k, v, causal=causal, window=window,
                             kernels=spec)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    _assert_close(out, want, dtype)
    g = jax.grad(lambda *xs: _sq(registry.attention(
        *xs, causal=causal, window=window, kernels=spec)),
        argnums=(0, 1, 2))(q, k, v)
    gw = jax.grad(lambda *xs: _sq(ref.flash_attention_ref(
        *xs, causal=causal, window=window)), argnums=(0, 1, 2))(q, k, v)
    _assert_close(g, gw, dtype, bwd=True)


def test_registry_attention_pallas_block_fallback():
    """lq=100 divides no _BLOCKS entry: PALLAS must fall back to the XLA
    formulation, never raise, and still match the oracle."""
    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    q = jax.random.normal(ks[0], (1, 100, 2, 64))
    k = jax.random.normal(ks[1], (1, 100, 2, 64))
    v = jax.random.normal(ks[2], (1, 100, 2, 64))
    out = registry.attention(q, k, v, causal=True, kernels="pallas")
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("variant", ["pallas", "xla"])
def test_registry_rmsnorm_parity(dtype, variant):
    x, _, w = _norm_inputs(dtype)
    spec = f"rmsnorm={variant}"
    out = registry.rmsnorm(x, w, kernels=spec)
    _assert_close(out, ref.rmsnorm_ref(x, w), dtype)
    g = jax.grad(lambda x_, w_: _sq(registry.rmsnorm(
        x_, w_, kernels=spec)), argnums=(0, 1))(x, w)
    gw = jax.grad(lambda x_, w_: _sq(ref.rmsnorm_ref(x_, w_)),
                  argnums=(0, 1))(x, w)
    _assert_close(g, gw, dtype, bwd=True)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("variant", ["pallas", "xla"])
def test_registry_residual_rmsnorm_parity(dtype, variant):
    x, r, w = _norm_inputs(dtype)
    spec = f"residual_rmsnorm={variant}"
    out = registry.residual_rmsnorm(x, r, w, kernels=spec)
    want = ref.residual_rmsnorm_ref(x, r, w)
    assert len(out) == 2 and out[0].dtype == x.dtype
    _assert_close(out, want, dtype)
    g = jax.grad(lambda *xs: _sq(registry.residual_rmsnorm(
        *xs, kernels=spec)), argnums=(0, 1, 2))(x, r, w)
    gw = jax.grad(lambda *xs: _sq(ref.residual_rmsnorm_ref(*xs)),
                  argnums=(0, 1, 2))(x, r, w)
    _assert_close(g, gw, dtype, bwd=True)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("variant", ["pallas", "xla", "xla_associative"])
@pytest.mark.parametrize("chunk", [16, 64, 7])   # 7: forced to l
def test_registry_ssm_scan_parity(dtype, variant, chunk):
    u, delta, a, bmat, cmat, h0 = _ssm_inputs(dtype)
    spec = f"ssm_scan={variant}"
    y, h = registry.ssm_scan(u, delta, a, bmat, cmat, h0, chunk=chunk,
                             kernels=spec)
    yw, hw = ref.ssm_scan_ref(u, delta, a, bmat, cmat, h0)
    assert y.dtype == u.dtype and h.dtype == jnp.float32
    _assert_close((y, h), (yw, hw), dtype)
    g = jax.grad(lambda *xs: _sq(registry.ssm_scan(
        *xs, h0, chunk=chunk, kernels=spec)),
        argnums=(0, 1, 2, 3, 4))(u, delta, a, bmat, cmat)
    gw = jax.grad(lambda *xs: _sq(ref.ssm_scan_ref(*xs, h0)),
                  argnums=(0, 1, 2, 3, 4))(u, delta, a, bmat, cmat)
    _assert_close(g, gw, dtype, bwd=True)


# ================================================== dispatch resolution
def test_dispatch_auto_tpu_picks_pallas_everywhere():
    for op in interface.OPS:
        assert interface.resolve("auto", op, tpu=True) is KernelType.PALLAS


def test_dispatch_auto_off_tpu_matches_historical_paths():
    assert interface.resolve("auto", "attention", tpu=False) \
        is KernelType.XLA
    assert interface.resolve("auto", "rmsnorm", tpu=False) is KernelType.XLA
    assert interface.resolve("auto", "residual_rmsnorm", tpu=False) \
        is KernelType.XLA
    assert interface.resolve("auto", "ssm_scan", tpu=False) \
        is KernelType.XLA_ASSOCIATIVE


def test_dispatch_bare_variant_applies_to_every_op():
    for op in interface.OPS:
        assert interface.resolve("pallas", op, tpu=False) \
            is KernelType.PALLAS
        assert interface.resolve("xla", op, tpu=True) is KernelType.XLA


def test_dispatch_per_op_override_composes_with_auto():
    spec = "ssm_scan=xla_associative,attention=pallas"
    assert interface.resolve(spec, "ssm_scan", tpu=True) \
        is KernelType.XLA_ASSOCIATIVE
    assert interface.resolve(spec, "attention", tpu=False) \
        is KernelType.PALLAS
    # untouched ops keep their auto resolution
    assert interface.resolve(spec, "rmsnorm", tpu=False) is KernelType.XLA
    assert interface.resolve(spec, "rmsnorm", tpu=True) \
        is KernelType.PALLAS


@pytest.mark.parametrize("bad", [
    "xla_associative",            # bare: attention has no such variant
    "attention=xla_associative",  # per-op: not implemented for this op
    "flash=pallas",               # unknown op
    "attention=cuda",             # unknown variant
    "attention",                  # missing '='
])
def test_dispatch_rejects_invalid_spec_listing_overrides(bad):
    with pytest.raises(ValueError) as e:
        interface.parse_kernels(bad)
    msg = str(e.value)
    assert interface.valid_overrides() in msg  # lists valid overrides


def test_registry_resolved_uses_live_backend():
    want_tpu = jax.default_backend() == "tpu"
    assert registry.resolved("attention", "auto") \
        is interface.resolve("auto", "attention", tpu=want_tpu)
    assert registry.resolved("ssm_scan", "pallas") is KernelType.PALLAS
