"""Serving tier (``repro.serve``): spec rules, the MSG_SUB no-seat
invariant, delta-refresh bitwise consistency, the freshness admission
gate, the batching queue, and the train-while-serving e2e.

The e2e spawns REAL OS processes (2 tcp training workers + 2 serving
replicas against one live server) and checks the run's acceptance
contract: loss recorded, served versions advancing, zero
staleness-bound violations, serve spans in the merged trace.
"""

from __future__ import annotations

import json
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policies import make_policy_factory
from repro.obs.trace import TRACE
from repro.ps.server import ServerOptimizer
from repro.ps.sharded import ShardedParameterServer
from repro.serve import (
    BatchQueue,
    DecodeRequest,
    DirectSubscription,
    ParamSubscriber,
    Refresher,
    aggregate_serve,
)
from repro.transport import PSServerEndpoint
from repro import wireformat as wf

pytestmark = pytest.mark.filterwarnings("ignore::UserWarning")


# ---------------------------------------------------------------- helpers
def tiny_params():
    return {"w": jnp.ones((48, 32), jnp.float32),
            "b": jnp.zeros((17,), jnp.float32)}


def make_server(n_workers=1, n_shards=2, policy="asp", **pkw):
    return ShardedParameterServer(
        tiny_params(),
        make_policy_factory(policy, n_workers=n_workers, staleness=2,
                            s_lower=0, s_upper=2, **pkw),
        lambda: ServerOptimizer(lr=0.05),
        n_workers, n_shards, apply_mode="fused")


def make_subscriber(server, replica_id=9):
    layout = server.plan.wire_layout()
    sub = DirectSubscription(server, replica_id)
    return ParamSubscriber(sub, layout, replica_id=replica_id), layout


def push_random(server, rng, layout, worker=0):
    g = rng.randn(layout.total_rows, wf.WIRE_LANES).astype(np.float32)
    server.push_packed(worker, jnp.asarray(g))


def wait_version(server, target, timeout=10.0):
    deadline = time.monotonic() + timeout
    while server.version < target:
        if time.monotonic() > deadline:
            raise TimeoutError(f"server stuck at {server.version} < "
                               f"{target}")
        time.sleep(0.002)


# ============================================================ spec rules
class TestServeSpec:
    def base(self, **serve_kw):
        from repro.api import (ModelSpec, RunSpec, ServeSpec, ServerSpec,
                               WireSpec)
        return dict(
            model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
            ps=ServerSpec(kind="sharded", shards=2, workers=2,
                          apply="fused"),
            wire=WireSpec(format="packed", delta_pull=True),
            serve=ServeSpec(replicas=1, **serve_kw))

    def test_valid_serve_spec_builds(self):
        from repro.api import RunSpec
        spec = RunSpec(**self.base())
        assert spec.serve.replicas == 1

    def test_serve_needs_a_parameter_server(self):
        from repro.api import RunSpec, ServerSpec, SpecError
        kw = self.base()
        kw["ps"] = ServerSpec(kind="none")
        with pytest.raises(SpecError, match="serve.replicas"):
            RunSpec(**kw)

    def test_serve_needs_delta_pull(self):
        from repro.api import RunSpec, SpecError, WireSpec
        kw = self.base()
        kw["wire"] = WireSpec(format="packed", delta_pull=False)
        with pytest.raises(SpecError, match="delta"):
            RunSpec(**kw)

    def test_serve_rejects_custom_arch(self):
        from repro.api import ModelSpec, RunSpec, SpecError
        kw = self.base()
        kw["model"] = ModelSpec(arch="custom")
        with pytest.raises(SpecError, match="custom"):
            RunSpec(**kw)

    @pytest.mark.parametrize("field,value", [
        ("replicas", -1), ("refresh_every_s", 0.0),
        ("staleness_bound", -1), ("batch_window_ms", -0.5),
        ("max_batch", 0), ("requests", 0), ("request_every_ms", -1.0),
        ("start_at_version", -1), ("prompt_len", 0), ("max_new", 0),
    ])
    def test_field_validation(self, field, value):
        from repro.api import ServeSpec, SpecError
        with pytest.raises(SpecError):
            ServeSpec(**{field: value})

    def test_serve_round_trips_through_dict(self):
        from repro.api import RunSpec, ServeSpec
        kw = self.base(staleness_bound=7, requests=11,
                       request_every_ms=3.5, start_at_version=2)
        spec = RunSpec(**kw)
        back = RunSpec.from_dict(spec.to_dict())
        assert back.serve == spec.serve
        assert back.serve.staleness_bound == 7


# ============================================================ MSG_SUB
class TestSubscription:
    def test_sub_frame_codec_roundtrip(self):
        f = wf.Frame(kind=wf.MSG_SUB, worker=5)
        g = wf.decode_frame(wf.encode_frame(f))
        assert (g.kind, g.worker) == (wf.MSG_SUB, 5)

    def test_subscriber_takes_no_barrier_seat(self):
        """2 BSP workers must release with a subscriber present: had
        the SUB taken a seat, the round barrier would wait for a third
        push that never comes."""
        server = make_server(n_workers=2, policy="bsp")
        endpoint = PSServerEndpoint(server)
        for w in (0, 1):
            r = endpoint.handle(wf.Frame(kind=wf.MSG_HELLO, worker=w))
            assert r.kind == wf.MSG_OK
        r = endpoint.handle(wf.Frame(kind=wf.MSG_SUB, worker=9))
        assert r.kind == wf.MSG_OK
        assert r.clock == server.version
        wire = np.zeros((endpoint.wire_rows(), wf.WIRE_LANES),
                        np.float32)
        replies = []

        def push(w):
            replies.append(endpoint.handle(
                wf.Frame(kind=wf.MSG_PUSH, worker=w, payload=wire)).kind)

        threads = [threading.Thread(target=push, args=(w,))
                   for w in (0, 1)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30.0)
        assert not any(t.is_alive() for t in threads), \
            "BSP round blocked — the subscriber took a barrier seat"
        assert replies == [wf.MSG_OK, wf.MSG_OK]
        server.stop()

    def test_dead_subscriber_is_not_removed_as_worker(self):
        server = make_server(n_workers=2)
        endpoint = PSServerEndpoint(server)
        endpoint.handle(wf.Frame(kind=wf.MSG_HELLO, worker=0))
        endpoint.handle(wf.Frame(kind=wf.MSG_SUB, worker=9))
        removed = []
        orig = server.remove_worker
        server.remove_worker = lambda w: (removed.append(w), orig(w))
        endpoint.on_disconnect(9)   # subscriber: unregister only
        assert removed == []
        endpoint.on_disconnect(0)   # worker: seat must be freed
        assert removed == [0]
        server.stop()

    def test_sub_rejected_on_per_shard_endpoint(self):
        server = make_server(n_shards=2)
        endpoint = PSServerEndpoint(server, shards=[0])
        r = endpoint.handle(wf.Frame(kind=wf.MSG_SUB, worker=9))
        assert r.kind == wf.MSG_ERR
        assert "full-store" in r.error
        server.stop()


# ============================================================ refresh
class TestRefresh:
    def test_unbootstrapped_is_never_fresh(self):
        server = make_server()
        ps, _ = make_subscriber(server)
        assert ps.staleness() == ParamSubscriber.UNBOOTSTRAPPED
        assert ps.refresh()
        assert ps.staleness() == 0
        server.stop()

    def test_delta_refresh_matches_full_pull_bitwise(self):
        """The resident buffer after N delta refreshes must equal a
        full pull byte-for-byte — region patching reconstructs the
        exact store, not an approximation of it."""
        server = make_server(n_workers=1, n_shards=3)
        server.add_worker(0)
        ps, layout = make_subscriber(server)
        assert ps.refresh()
        rng = np.random.RandomState(0)
        for i in range(4):
            push_random(server, rng, layout)
            wait_version(server, (i + 1) * 3)
            assert ps.refresh()
            buf, ver = ps.snapshot()
            full = np.asarray(server.pull_packed(0))
            assert buf.tobytes() == full.tobytes()
            assert ver == server.version
        assert ps.full_refreshes == 0  # deltas all the way, never a
        server.stop()                  # dominance-mismatch fallback

    def test_stopped_server_serves_final_weights(self):
        """A replica that trails at stop time must catch up to the
        FINAL weights before freezing — stopping at an older vector
        would pin pre-final parameters forever."""
        server = make_server(n_workers=1)
        server.add_worker(0)
        ps, layout = make_subscriber(server)
        rng = np.random.RandomState(1)
        push_random(server, rng, layout)
        wait_version(server, 2)
        server.stop()
        assert ps.refresh()         # the catch-up delta still lands
        assert not ps.refresh()     # now caught up: STOP freezes it
        assert ps.stopped
        buf, ver = ps.snapshot()
        assert buf.tobytes() == np.asarray(
            server.pull_packed(0)).tobytes()
        assert ver == server.version
        assert ps.wait_fresh(0) == 0  # frozen weights are fresh forever

    def test_wait_fresh_blocks_until_refresh_lands(self):
        server = make_server(n_workers=1)
        server.add_worker(0)
        ps, layout = make_subscriber(server)
        ps.refresh()
        rng = np.random.RandomState(2)
        push_random(server, rng, layout)
        wait_version(server, 2)
        assert ps.staleness() == 2
        TRACE.enable(source="test")
        try:
            admitted = []
            t = threading.Thread(
                target=lambda: admitted.append(ps.wait_fresh(0)))
            t.start()
            time.sleep(0.3)
            assert t.is_alive(), "gate admitted a stale replica"
            assert ps.refresh_needed.is_set()
            ps.refresh()
            t.join(timeout=10.0)
            assert admitted == [0]
            assert ps.blocks == 1
            names = {e["name"] for e in TRACE.drain()}
            assert "staleness_block" in names
            assert "replica_refresh" in names
        finally:
            TRACE.disable()
        server.stop()

    @pytest.mark.parametrize("seed,bound", [(0, 0), (1, 1), (2, 3)])
    def test_admission_staleness_bounded_under_live_updates(self, seed,
                                                           bound):
        """The freshness property: against a seeded schedule of live
        pushes, EVERY admission the gate grants is within the bound —
        measured against the server's version at admission time."""
        server = make_server(n_workers=1)
        server.add_worker(0)
        ps, layout = make_subscriber(server)
        refresher = Refresher(ps, refresh_every_s=0.002)
        refresher.start()
        rng = np.random.RandomState(seed)
        stop = threading.Event()

        def trainer():
            while not stop.is_set():
                push_random(server, rng, layout)
                time.sleep(rng.uniform(0.0, 0.004))

        t = threading.Thread(target=trainer, daemon=True)
        t.start()
        try:
            pace = np.random.RandomState(seed + 100)
            admitted = [ps.wait_fresh(bound) for _ in range(25)
                        if not time.sleep(pace.uniform(0.0, 0.003))]
            assert len(admitted) == 25
            assert all(a <= bound for a in admitted), admitted
        finally:
            stop.set()
            t.join(timeout=10.0)
            refresher.stop()
            server.stop()


# ============================================================ batching
class TestBatchQueue:
    def req(self, i):
        return DecodeRequest(request_id=i,
                             prompt=np.zeros(4, np.int32),
                             enqueue_t=time.perf_counter())

    def test_fifo_batch_up_to_max(self):
        q = BatchQueue()
        for i in range(5):
            q.submit(self.req(i))
        batch = q.next_batch(max_batch=3, window_s=0.0)
        assert [r.request_id for r in batch] == [0, 1, 2]
        batch = q.next_batch(max_batch=3, window_s=0.0)
        assert [r.request_id for r in batch] == [3, 4]

    def test_linger_window_collects_late_arrivals(self):
        q = BatchQueue()
        q.submit(self.req(0))
        threading.Timer(0.05, lambda: q.submit(self.req(1))).start()
        batch = q.next_batch(max_batch=4, window_s=0.5)
        assert len(batch) == 2

    def test_close_drains_then_returns_none(self):
        q = BatchQueue()
        q.submit(self.req(0))
        q.close()
        assert len(q.next_batch(2, 0.0)) == 1
        assert q.next_batch(2, 0.0) is None
        with pytest.raises(RuntimeError):
            q.submit(self.req(1))

    def test_next_batch_blocks_until_submit(self):
        q = BatchQueue()
        got = []
        t = threading.Thread(
            target=lambda: got.append(q.next_batch(2, 0.0)))
        t.start()
        time.sleep(0.1)
        assert t.is_alive()
        q.submit(self.req(7))
        t.join(timeout=10.0)
        assert [r.request_id for r in got[0]] == [7]

    def test_aggregate_handles_empty_and_none(self):
        agg = aggregate_serve([None])
        assert agg["requests"] == 0 and agg["violations"] == 0


# ============================================================ e2e
def _serve_spec(trace_path=""):
    from repro.api import (DataSpec, ModelSpec, ObsSpec, RunSpec,
                           ServeSpec, ServerSpec, SyncSpec,
                           TransportSpec, WireSpec)
    return RunSpec(
        model=ModelSpec(arch="h2o-danube-1.8b", smoke=True),
        data=DataSpec(seq_len=32, global_batch=4),
        ps=ServerSpec(kind="sharded", shards=2, workers=2,
                      apply="fused"),
        sync=SyncSpec(mode="dssp", s_lower=1, s_upper=4),
        wire=WireSpec(format="packed", delta_pull=True),
        transport=TransportSpec(kind="tcp", endpoint=True),
        obs=ObsSpec(trace=bool(trace_path), trace_path=trace_path),
        serve=ServeSpec(replicas=2, requests=6, request_every_ms=100.0,
                        start_at_version=1, prompt_len=8, max_new=4,
                        max_batch=4, staleness_bound=4))


def test_e2e_threaded_train_and_serve():
    """ps-threads engine: replica threads against the in-heap server."""
    import dataclasses

    from repro.api import TransportSpec, build_session
    spec = dataclasses.replace(_serve_spec(), transport=TransportSpec())
    with build_session(spec) as session:
        m = session.run(steps=24)
    serve = m["serve"]
    assert serve["requests"] == 12
    assert serve["violations"] == 0
    assert serve["version_max"] > 0
    assert m["final_loss"] is not None


def test_e2e_tcp_train_and_serve_traced(tmp_path):
    """The acceptance e2e: one RunSpec, 2 tcp worker processes
    training while 2 replica processes serve via delta pulls — loss
    recorded, served versions advance, zero staleness violations, and
    the serve spans land in the merged trace."""
    from repro.api import build_session
    trace = str(tmp_path / "serve_trace.jsonl")
    with build_session(_serve_spec(trace)) as session:
        m = session.run(steps=24)
    assert m["final_loss"] is not None
    assert m["applied_updates"] > 0
    serve = m["serve"]
    assert serve["requests"] == 12, serve
    assert serve["violations"] == 0, serve
    assert serve["staleness_max"] <= 4
    assert serve["version_max"] > 0, \
        "replicas never served an updated version"
    names = set()
    with open(trace) as fh:
        for line in fh:
            names.add(json.loads(line)["name"])
    for want in ("replica_refresh", "decode_batch", "push",
                 "compute_step"):
        assert want in names, f"{want} missing from {sorted(names)}"
