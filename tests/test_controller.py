"""Unit + property tests for Algorithm 2 (synchronization controller)."""

import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.controller import (
    IntervalEstimator,
    SynchronizationController,
    optimal_extra_iterations,
    simulate_push_times,
)
from repro.core.staleness import StalenessTracker


def test_simulate_push_times_fast_worker():
    # Sim_p[0] = A[p][0]; Sim_p[i] = Sim_p[0] + i * I_p   (Alg. 2 line 6)
    assert simulate_push_times(10.0, 2.0, 3) == [10.0, 12.0, 14.0, 16.0]


def test_simulate_push_times_slowest_leads_by_one():
    # Sim_slowest[0] = A[s][0] + I_s                       (Alg. 2 line 7)
    assert simulate_push_times(10.0, 5.0, 2, lead=1) == [15.0, 20.0, 25.0]


def test_figure2_scenario_returns_r_star_3():
    """Figure 2: fast worker interval 1, slowest interval 4.4, r_max = 4.

    The slowest worker's next pushes land at ~4.4, 8.8...; the fast worker
    just pushed at t=5.0 with interval 1.0 ⇒ its simulated pushes are
    5,6,7,8,9.  Waiting now (r=0) costs |8.8-5|=3.8; continuing to r=3
    (t=8) costs 0.8; r=4 (t=9) costs 0.2 — but the paper stops at the
    argmin over the full table; with these numbers r*=4.  Shift slightly
    so the interior optimum r*=3 of the figure emerges.
    """
    sim_fast = simulate_push_times(5.0, 1.0, 4)          # 5,6,7,8,9
    sim_slow = simulate_push_times(3.6, 4.4, 4, lead=1)  # 8.0, 12.4, ...
    assert optimal_extra_iterations(sim_fast, sim_slow) == 3


def test_argmin_tie_breaks_to_smaller_r():
    # equal distance to a slow push from r=1 and r=3 -> pick r=1
    sim_fast = [0.0, 4.0, 6.0, 8.0]
    sim_slow = [6.0, 100.0, 200.0, 300.0]
    # |6-4| == 2 at r=1 and |6-8| == 2 at r=3; r=2 gives 0 so adjust:
    sim_fast = [0.0, 4.0, 8.0, 12.0]
    # gaps: 6, 2, 2, 6 -> tie between r=1 and r=2 -> r=1
    assert optimal_extra_iterations(sim_fast, sim_slow) == 1


@given(
    start_fast=st.floats(0, 1e3),
    i_fast=st.floats(0.01, 100),
    start_slow=st.floats(0, 1e3),
    i_slow=st.floats(0.01, 100),
    r_max=st.integers(0, 32),
)
@settings(max_examples=300, deadline=None)
def test_r_star_is_argmin_property(start_fast, i_fast, start_slow, i_slow, r_max):
    sim_fast = simulate_push_times(start_fast, i_fast, r_max)
    sim_slow = simulate_push_times(start_slow, i_slow, r_max, lead=1)
    r = optimal_extra_iterations(sim_fast, sim_slow)
    assert 0 <= r <= r_max
    best = min(min(abs(ts - tp) for ts in sim_slow) for tp in sim_fast)
    got = min(abs(ts - sim_fast[r]) for ts in sim_slow)
    assert math.isclose(got, best, rel_tol=1e-9, abs_tol=1e-9)


def _push(tracker, ctrl, worker, ts):
    tracker.record_push(worker, ts)
    ctrl.observe_push(tracker, worker)


def test_controller_cold_start_returns_zero():
    tracker = StalenessTracker(range(2))
    ctrl = SynchronizationController(r_max=4)
    _push(tracker, ctrl, 0, 1.0)   # only one push: no interval yet
    assert ctrl(tracker, 0, 1.0) == 0


def test_controller_grants_when_slow_worker_far_out():
    """Fast worker interval 1s, slow interval 10s: the controller should
    grant extra iterations instead of blocking for ~10 s."""
    tracker = StalenessTracker(range(2))
    ctrl = SynchronizationController(r_max=8)
    _push(tracker, ctrl, 1, 0.0)
    _push(tracker, ctrl, 1, 10.0)   # slow: interval 10 -> next push ~20.0
    for t in (0.5, 1.5, 3.5):
        _push(tracker, ctrl, 0, t)  # fast: latest interval 2, count ahead
    r = ctrl(tracker, 0, 3.5)
    # Fast simulated pushes 3.5, 5.5 ... 19.5; slow frees it at 20.0:
    # running all 8 extra iterations lands 0.5 s before the sync point.
    assert r == 8
    assert ctrl.decisions[-1].predicted_wait <= 0.5 + 1e-9


def test_estimator_modes():
    est_last = IntervalEstimator("last")
    est_med = IntervalEstimator("median", window=5)
    est_ema = IntervalEstimator("ema", ema_alpha=0.5)
    for v in [1.0, 1.0, 9.0]:
        est_last.observe(0, v)
        est_med.observe(0, v)
        est_ema.observe(0, v)
    assert est_last.predict(0) == 9.0          # paper: last interval
    assert est_med.predict(0) == 1.0           # robust to the spike
    assert 1.0 < est_ema.predict(0) < 9.0
    assert est_last.predict(1) is None


def test_estimator_rejects_unknown_mode():
    with pytest.raises(ValueError):
        IntervalEstimator("quantum")


@given(vals=st.lists(st.floats(0.01, 100), min_size=1, max_size=20))
@settings(max_examples=100, deadline=None)
def test_estimator_predictions_within_observed_range(vals):
    for mode in ("last", "ema", "median"):
        est = IntervalEstimator(mode, window=32)
        for v in vals:
            est.observe(0, v)
        p = est.predict(0)
        assert min(vals) - 1e-9 <= p <= max(vals) + 1e-9
