"""Sharded parameter server: plan, server, simulator, elasticity.

Covers the subsystem's contract:
  * ShardPlan split/assemble round-trip, balance, oversized-leaf splitting,
  * S=1 behavior-equivalence with the monolithic ParameterServer /
    PSSimulator (same applied-update count, same params, same metrics),
  * per-shard staleness stays within the policy bound on EVERY shard,
  * pushes to distinct shards genuinely overlap (no global lock),
  * elastic membership (join/leave mid-run) never deadlocks any shard's
    barrier and keeps per-shard staleness profiles consistent,
  * the batched fused apply matches the tree apply,
  * per-shard wire compression round-trips through the identity
    compressor (the make_compressor("none") error-state fix).
"""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policies import make_policy, make_policy_factory
from repro.optim.compression import make_compressor
from repro.ps.server import ParameterServer, ServerOptimizer
from repro.ps.sharded import (ShardedParameterServer, build_shard_plan,
                              hot_shard_service, run_sharded_policy)
from repro.ps.simulator import run_policy
from repro.ps.worker import PSWorker, run_cluster


def _tree(seed=0, shapes=((40, 16), (16,), (8, 8), ())):
    rng = np.random.RandomState(seed)
    return {f"p{i}": jnp.asarray(np.asarray(rng.randn(*s), np.float32))
            for i, s in enumerate(shapes)}


def _grads_like(tree, seed):
    rng = np.random.RandomState(seed)
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(np.asarray(rng.randn(*p.shape), np.float32)),
        tree)


def _max_diff(a, b):
    return max(float(jnp.abs(x - y).max()) for x, y in
               zip(jax.tree_util.tree_leaves(a),
                   jax.tree_util.tree_leaves(b)))


# ------------------------------------------------------------------ plan
@pytest.mark.parametrize("n_shards", [1, 2, 3, 4, 8])
def test_plan_split_assemble_roundtrip(n_shards):
    tree = _tree()
    plan = build_shard_plan(tree, n_shards)
    back = plan.assemble(plan.split(tree))
    assert _max_diff(tree, back) == 0.0
    assert plan.total_size == sum(
        int(np.prod(x.shape)) if x.shape else 1
        for x in jax.tree_util.tree_leaves(tree))


def test_plan_splits_oversized_leaves_and_balances():
    tree = {"big": jnp.zeros((1024, 8)), "small": jnp.zeros((4,))}
    plan = build_shard_plan(tree, 4)
    # the 8192-element leaf dominates: without splitting one shard would
    # hold >99% of the weights
    assert plan.imbalance() < 1.2
    assert any(not sl.whole for shard in plan.shards for sl in shard.slices)
    back = plan.assemble(plan.split(tree))
    assert _max_diff(tree, back) == 0.0


def test_plan_no_split_when_disabled():
    tree = {"big": jnp.zeros((1024, 8)), "small": jnp.zeros((4,))}
    plan = build_shard_plan(tree, 4, split_oversized=False)
    assert all(sl.whole for shard in plan.shards for sl in shard.slices)


def test_plan_deterministic():
    tree = _tree()
    a = build_shard_plan(tree, 3)
    b = build_shard_plan(tree, 3)
    assert a.shards == b.shards


# ------------------------------------------------- S=1 server equivalence
def test_s1_equivalent_to_monolithic_server():
    """Acceptance: ShardedParameterServer with S=1 == ParameterServer on
    the same deterministic push sequence (same applied-update count,
    identical final params)."""
    params = _tree()
    mono = ParameterServer(params, make_policy("ssp", staleness=2),
                           ServerOptimizer(lr=0.1, momentum=0.9), 3)
    shrd = ShardedParameterServer(
        params, make_policy_factory("ssp", staleness=2),
        lambda: ServerOptimizer(lr=0.1, momentum=0.9), 3, 1)
    for i in range(30):   # round-robin never exceeds the SSP threshold
        g = _grads_like(params, seed=100 + i)
        mono.push(i % 3, g)
        shrd.push(i % 3, g)
    assert mono.version == shrd.version == 30
    assert _max_diff(mono.params, shrd.params) < 1e-6
    assert (mono.metrics.staleness_hist == shrd.metrics.staleness_hist)


def test_global_gating_matches_monolithic_for_dropping_policy():
    """Regression: in gating='global' the gate's decision must govern
    every shard's apply — with the backup-workers policy (which DROPS
    straggler gradients) the sharded server must apply/drop exactly the
    pushes the monolithic server does."""
    params = _tree()
    mono = ParameterServer(params,
                           make_policy("backup", n_workers=2, backups=1),
                           ServerOptimizer(lr=0.1), 2)
    shrd = ShardedParameterServer(
        params, make_policy_factory("backup", n_workers=2, backups=1),
        lambda: ServerOptimizer(lr=0.1), 2, 2, gating="global")
    for i in range(10):
        g = _grads_like(params, seed=200 + i)
        mono.push(i % 2, g)
        shrd.push(i % 2, g)
    assert mono.metrics.applied_updates == shrd.metrics.applied_updates
    assert mono.metrics.dropped_updates == shrd.metrics.dropped_updates
    assert _max_diff(mono.params, shrd.params) < 1e-6


def test_fused_apply_matches_tree_apply():
    params = _tree()
    servers = [
        ShardedParameterServer(params, make_policy_factory("asp"),
                               lambda: ServerOptimizer(lr=0.1, momentum=0.9),
                               2, 3, apply_mode=mode)
        for mode in ("tree", "fused")]
    for i in range(12):
        g = _grads_like(params, seed=i)
        for s in servers:
            s.push(i % 2, g)
    assert _max_diff(servers[0].params, servers[1].params) < 1e-5
    assert servers[0].shard_versions() == servers[1].shard_versions()


def test_fused_apply_handles_empty_shards():
    """Regression: n_shards > piece count yields empty shards; a
    zero-row pallas_call would reject its tile — apply must no-op."""
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    server = ShardedParameterServer(params, make_policy_factory("asp"),
                                    lambda: ServerOptimizer(lr=0.1), 2, 8,
                                    apply_mode="fused")
    assert any(len(s.slices) == 0 for s in server.plan.shards)
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    for i in range(4):
        server.push(i % 2, g)
    assert server.shard_versions() == [4] * 8
    assert _max_diff(server.params["w"],
                     jnp.ones((4, 4)) - 0.1 * 4 * jnp.ones(())) < 1e-5


def test_fused_update_shard_matches_per_leaf_kernel():
    """The public batched kernel API (one pallas_call over the packed
    shard) is numerically identical to per-leaf fused_update."""
    from repro.kernels.fused_update import fused_update, fused_update_shard
    leaves = list(jax.tree_util.tree_leaves(_tree()))
    ms = [jnp.ones_like(x) * 0.1 for x in leaves]
    gs = list(jax.tree_util.tree_leaves(_grads_like(_tree(), seed=7)))
    po, mo = fused_update_shard(leaves, ms, gs, lr=0.05, beta=0.9,
                                scale=0.5, interpret=True)
    for p, m, g, pn, mn in zip(leaves, ms, gs, po, mo):
        pe, me = fused_update(p, m, g, lr=0.05, beta=0.9, scale=0.5,
                              interpret=True)
        assert float(jnp.abs(pn - pe).max()) < 1e-6
        assert float(jnp.abs(mn - me).max()) < 1e-6
    assert fused_update_shard([], [], [], lr=0.05) == ([], [])


def test_ps_package_import_stays_kernel_free():
    """Importing repro.ps must not drag in the Pallas kernel stack."""
    import subprocess
    import sys
    code = ("import sys; import repro.ps; "
            "sys.exit(1 if any(m.startswith('repro.kernels') "
            "for m in sys.modules) else 0)")
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr


def test_identity_compressor_roundtrips_through_shards():
    params = _tree()
    plain = ShardedParameterServer(params, make_policy_factory("asp"),
                                   lambda: ServerOptimizer(lr=0.1), 2, 2)
    ident = ShardedParameterServer(params, make_policy_factory("asp"),
                                   lambda: ServerOptimizer(lr=0.1), 2, 2,
                                   compressor=make_compressor("none"))
    int8 = ShardedParameterServer(params, make_policy_factory("asp"),
                                  lambda: ServerOptimizer(lr=0.1), 2, 2,
                                  compressor=make_compressor("int8"))
    for i in range(8):
        g = _grads_like(params, seed=i)
        for s in (plain, ident, int8):
            s.push(i % 2, g)
    assert _max_diff(plain.params, ident.params) == 0.0
    # int8 is lossy-but-error-fed-back: close, not identical
    assert 0.0 < _max_diff(plain.params, int8.params) < 0.1


def test_none_compressor_error_state_is_grads_shaped():
    """Regression: make_compressor('none').init_error used to return ()."""
    g = _tree()
    c = make_compressor("none")
    err = c.init_error(g)
    assert (jax.tree_util.tree_structure(err)
            == jax.tree_util.tree_structure(g))
    g2, err2 = c.apply(g, err)
    assert _max_diff(g, g2) == 0.0
    assert (jax.tree_util.tree_structure(err2)
            == jax.tree_util.tree_structure(g))


# ------------------------------------------------- simulator equivalence
@pytest.mark.parametrize("name,kw", [
    ("bsp", {}), ("asp", {}), ("ssp", {"staleness": 3}),
    ("dssp", {"s_lower": 3, "s_upper": 15})])
def test_sim_s1_metrics_identical_to_monolithic(name, kw):
    intervals = [1.0, 1.0, 1.0, 4.0]
    mono = run_policy(make_policy(name, n_workers=4, **kw), intervals,
                      max_pushes=1500)
    s1 = run_sharded_policy(
        make_policy_factory(name, n_workers=4, **kw), intervals, 1,
        max_pushes=1500).metrics
    a, b = mono.summary(), s1.summary()
    for key in ("pushes", "applied", "total_wait", "mean_staleness",
                "max_staleness", "time", "throughput"):
        assert a[key] == b[key], (key, a[key], b[key])


@pytest.mark.parametrize("n_shards", [2, 4, 16])
@pytest.mark.parametrize("name,kw,bound", [
    ("bsp", {}, 0), ("ssp", {"staleness": 3}, 3),
    ("dssp", {"s_lower": 3, "s_upper": 15}, 15)])
def test_sim_per_shard_staleness_bounded(n_shards, name, kw, bound):
    """Acceptance: with S>1 every shard's max observed staleness stays
    within the policy bound (+1 for the at-push transient, the same
    convention the monolithic tests use)."""
    sim = run_sharded_policy(
        make_policy_factory(name, n_workers=4, **kw),
        [1.0, 1.0, 1.0, 4.0], n_shards, max_pushes=1500)
    for shard_max in sim.max_staleness_per_shard():
        assert shard_max <= bound + 1
    assert sim.metrics.total_pushes == 1500


def test_sim_hot_shard_adds_wait_but_keeps_bound():
    factory = make_policy_factory("dssp", s_lower=3, s_upper=15)
    cold = run_sharded_policy(factory, [1.0, 1.0, 1.0, 4.0], 4,
                              max_pushes=800)
    hot = run_sharded_policy(factory, [1.0, 1.0, 1.0, 4.0], 4,
                             max_pushes=800,
                             shard_service_fn=hot_shard_service(0, 0.5))
    assert hot.metrics.total_time > cold.metrics.total_time
    assert max(hot.max_staleness_per_shard()) <= 16


# -------------------------------------------------- threaded: concurrency
class _SlowOptimizer(ServerOptimizer):
    """ServerOptimizer that sleeps inside apply and records how many
    applies run concurrently — the lock-granularity probe."""

    gauge_lock = threading.Lock()
    active = 0
    max_active = 0

    def __init__(self, sleep_s: float):
        super().__init__(lr=0.01)
        self._sleep = sleep_s

    def step(self, params, grads, staleness):
        cls = _SlowOptimizer
        with cls.gauge_lock:
            cls.active += 1
            cls.max_active = max(cls.max_active, cls.active)
        time.sleep(self._sleep)
        try:
            return super().step(params, grads, staleness)
        finally:
            with cls.gauge_lock:
                cls.active -= 1


def test_pushes_to_distinct_shards_do_not_serialize():
    """Acceptance: concurrent pushes to distinct shards overlap — with a
    global lock the in-apply concurrency gauge could never exceed 1."""
    _SlowOptimizer.active = 0
    _SlowOptimizer.max_active = 0
    params = _tree()
    server = ShardedParameterServer(
        params, make_policy_factory("asp"),
        lambda: _SlowOptimizer(0.03), 3, 3)

    def pusher(w):
        for i in range(6):
            server.push(w, _grads_like(params, seed=w * 100 + i))

    threads = [threading.Thread(target=pusher, args=(w,)) for w in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
    assert not any(t.is_alive() for t in threads)
    assert _SlowOptimizer.max_active >= 2, (
        "shard applies never overlapped — pushes serialized globally")


# --------------------------------------------- threaded: training + elastic
def _make_problem(seed=0, dim=8, n=512):
    rng = np.random.RandomState(seed)
    w_true = rng.randn(dim, 1).astype(np.float32)
    x = rng.randn(n, dim).astype(np.float32)
    y = x @ w_true + 0.01 * rng.randn(n, 1).astype(np.float32)
    return x, y


def _step_fn():
    def loss_fn(params, batch):
        x, y = batch
        pred = x @ params["w"] + params["b"]
        return jnp.mean((pred - y) ** 2)

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return grads, {"loss": loss}

    return step


def _batches(x, y, worker, n_workers, bs=32, seed=0):
    sx, sy = x[worker::n_workers], y[worker::n_workers]
    rng = np.random.RandomState(seed + worker)
    while True:
        idx = rng.randint(0, len(sx), size=bs)
        yield sx[idx], sy[idx]


def _sharded_server(params, policy_name, n_workers, n_shards, **kw):
    return ShardedParameterServer(
        params, make_policy_factory(policy_name, n_workers=n_workers, **kw),
        lambda: ServerOptimizer(lr=0.05), n_workers, n_shards)


@pytest.mark.parametrize("policy", ["bsp", "dssp"])
def test_training_converges_through_sharded_server(policy):
    x, y = _make_problem()
    params = {"w": jnp.zeros((x.shape[1], 1)), "b": jnp.zeros((1,))}
    server = _sharded_server(params, policy, 4, 3, s_lower=1, s_upper=5)
    step = _step_fn()
    workers = [PSWorker(w, server, step, _batches(x, y, w, 4), 30)
               for w in range(4)]
    run_cluster(server, workers, timeout=120.0)
    pred = x @ server.params["w"] + server.params["b"]
    final = float(jnp.mean((pred - y) ** 2))
    assert final < 0.25 * float(jnp.mean(y ** 2))
    assert server.metrics.total_pushes == 4 * 30
    # every shard applied every released push
    assert all(v == 4 * 30 for v in server.shard_versions())


def test_dssp_straggler_bounded_on_every_shard_threaded():
    x, y = _make_problem()
    params = {"w": jnp.zeros((x.shape[1], 1)), "b": jnp.zeros((1,))}
    server = _sharded_server(params, "dssp", 4, 4, s_lower=1, s_upper=4)
    step = _step_fn()
    workers = [PSWorker(w, server, step, _batches(x, y, w, 4), 30,
                        speed_factor=(6.0 if w == 3 else 1.0))
               for w in range(4)]
    run_cluster(server, workers, timeout=180.0)
    for m in server.shard_metrics():
        assert m.max_staleness <= 4 + 1


def test_worker_failure_does_not_deadlock_any_shard_barrier():
    """Satellite: remove_worker mid-run must not stall ANY shard's BSP
    barrier — the departed worker leaves every shard tracker."""
    x, y = _make_problem()
    params = {"w": jnp.zeros((x.shape[1], 1)), "b": jnp.zeros((1,))}
    server = _sharded_server(params, "bsp", 4, 3)
    step = _step_fn()
    workers = [PSWorker(w, server, step, _batches(x, y, w, 4), 25)
               for w in range(4)]
    workers[2].abort()
    # Sample membership while the cluster runs.  Departures sweep shards
    # in index order, so at any instant shard j's membership is a subset
    # of shard j+1's; reading in REVERSE shard order makes that chain
    # observable without racing the sweep.
    samples = []
    stop_sampling = threading.Event()

    def snapshot():
        snaps = [None] * server.n_shards
        for st in reversed(server.shards):
            with st.cond:
                snaps[st.index] = frozenset(st.tracker.workers)
        return snaps

    def sampler():
        while not stop_sampling.is_set():
            samples.append(snapshot())
            time.sleep(0.005)

    t = threading.Thread(target=sampler, daemon=True)
    t.start()
    run_cluster(server, workers, timeout=120.0)
    stop_sampling.set()
    t.join(timeout=10.0)
    done = [w.iterations_done for w in workers]
    assert done[2] == 0
    assert all(d == 25 for d in (done[0], done[1], done[3]))
    for snap in samples:
        for a, b in zip(snap, snap[1:]):
            assert a <= b, f"shard membership diverged: {snap}"
    # after the run everyone departed — trackers agree on empty
    assert all(set(p) == set() for p in server.staleness_profile().values())


def test_remove_worker_shrinks_inflight_coalesce_window():
    """Satellite: a flusher lingering for a coalesce window that counts
    a worker who just left must NOT wait out the full linger —
    ``remove_worker`` shrinks the live fill target immediately, and the
    queued payload applies exactly once."""
    params = _tree()
    server = ShardedParameterServer(
        params, make_policy_factory("asp", n_workers=2),
        lambda: ServerOptimizer(lr=0.05), 2, 2, apply_mode="fused",
        coalesce=3, coalesce_wait=20.0)
    wire = server.plan.pack(_grads_like(params, 1))
    done = threading.Event()

    def push():
        server.push_packed(0, wire)   # window target is min(3, 2) = 2:
        done.set()                    # lingers for worker 1's push

    t = threading.Thread(target=push, daemon=True)
    t.start()
    assert not done.wait(0.4), "flusher did not linger for the window"
    t0 = time.monotonic()
    server.remove_worker(1)           # target shrinks to 1 -> flush now
    assert done.wait(10.0), \
        "flusher waited out the full linger after the worker left"
    t.join(timeout=10.0)
    assert time.monotonic() - t0 < 10.0
    # the parked contribution applied exactly once on every shard
    assert server.shard_versions() == [1, 1]
    for st in server.shards:
        assert st.tracker.workers == [0]
        assert st.window.pending == [] and not st.window.applying
    # the survivor keeps pushing through the (now size-1) window
    server.push_packed(0, wire)
    assert server.shard_versions() == [2, 2]
    server.stop()


def test_elastic_join_mid_run_keeps_shard_profiles_consistent():
    """Satellite: add_worker mid-run — the joiner starts at every shard's
    slowest count (no stall) and all shards agree on membership."""
    x, y = _make_problem()
    params = {"w": jnp.zeros((x.shape[1], 1)), "b": jnp.zeros((1,))}
    server = _sharded_server(params, "ssp", 2, 3, staleness=2)
    step = _step_fn()
    first = [PSWorker(w, server, step, _batches(x, y, w, 4), 15)
             for w in range(2)]
    run_cluster(server, first, timeout=120.0)
    server.stopped = False
    server.add_worker(2)
    # the joiner enters EVERY shard's tracker at that shard's slowest
    # count — consistent profiles, no stall on any barrier
    profile = server.staleness_profile()
    assert all(set(p) == {2} for p in profile.values())
    assert all(p[2] == 0 for p in profile.values())
    late = PSWorker(2, server, step, _batches(x, y, 2, 4), 15)
    run_cluster(server, [late], timeout=120.0)
    assert late.iterations_done == 15
    # departed again on exit — all shards agree
    assert all(set(p) == set() for p in server.staleness_profile().values())
