"""Fault tolerance (``repro.ft``): snapshots, failover, reconnect, chaos.

Unit layer: backoff schedules, checkpoint-manager crash hygiene,
snapshot/restore bitwise fidelity (including the cross-shard count
equalization that keeps post-failover DSSP gating deadlock-free), and
deterministic fault injection.

Process layer (the chaos tests CI's ``chaos`` job re-runs in
isolation): a worker SIGKILLed while the other is gated on it frees
its barrier seat and a respawned replacement re-acquires it exactly
once (tcp AND shmem); and the headline end-to-end — a 2-worker DSSP
run over tcp whose server is SIGKILLed mid-run, restarted on the same
port, resumes from the latest snapshot with both workers reconnected,
no duplicate seats, the loss trajectory intact across the failover,
and the per-shard snapshot pause bounded (asserted from obs spans).
"""

from __future__ import annotations

import glob
import json
import math
import os
import socket
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.policies import make_policy_factory
from repro.ft import (
    BackoffPolicy,
    FaultPlan,
    ServerProcess,
    retry,
)
from repro.ft.faults import FaultyChannel
from repro.ft.snapshot import (
    ServerSnapshotter,
    restore_latest,
    restore_server,
    snapshot_server,
)
from repro.ps.server import ServerOptimizer
from repro.ps.sharded import ShardedParameterServer
from repro.transport import (
    PSServerEndpoint,
    TransportClosed,
    connect,
    make_transport,
)
from repro.transport.tcp import TcpTransport
from repro.wireformat import MSG_PULL, MSG_PUSH, WIRE_LANES

pytestmark = pytest.mark.filterwarnings("ignore::UserWarning")

ARCH = "xlstm-125m"  # registry arch the spawned chaos workers rebuild


# ---------------------------------------------------------------- helpers
def tiny_params():
    return {"w": jnp.ones((48, 32), jnp.float32),
            "b": jnp.zeros((17,), jnp.float32)}


def make_server(n_workers=1, n_shards=2, policy="asp", s_lower=0,
                s_upper=3, **pkw):
    # DSSP tests that push single-threaded need a slack s_lower: with a
    # tight bound the first push gates on a peer that never comes.
    return ShardedParameterServer(
        tiny_params(),
        make_policy_factory(policy, n_workers=n_workers, staleness=2,
                            s_lower=s_lower, s_upper=s_upper, **pkw),
        lambda: ServerOptimizer(lr=0.05),
        n_workers, n_shards, apply_mode="fused")


def push_rounds(server, n, workers=(0,), seed=0):
    rng = np.random.RandomState(seed)
    rows = server.plan.wire_layout().total_rows
    for _ in range(n):
        for w in workers:
            g = rng.randn(rows, WIRE_LANES).astype(np.float32)
            server.push_packed(w, jnp.asarray(g))


def packed_state(server):
    return [(np.asarray(st._packed_p).tobytes(),
             np.asarray(st._packed_m).tobytes())
            for st in server.shards]


# ============================================================ backoff
class TestBackoff:
    def test_delays_deterministic_bounded_and_sized(self):
        pol = BackoffPolicy(base_s=0.1, factor=2.0, max_s=0.5, max_tries=6)
        a = list(pol.delays(seed=7))
        b = list(pol.delays(seed=7))
        assert a == b                       # reproducible chaos
        assert len(a) == pol.max_tries - 1  # one sleep between tries
        assert all(0.0 < d <= 0.5 * (1.0 + pol.jitter) for d in a)
        assert a != list(pol.delays(seed=8))

    def test_retry_returns_first_success(self):
        calls = []

        def fn():
            calls.append(1)
            if len(calls) < 3:
                raise OSError("not yet")
            return "ok"

        pol = BackoffPolicy(base_s=0.001, factor=1.0, max_s=0.001,
                            max_tries=5)
        assert retry(fn, pol) == "ok"
        assert len(calls) == 3

    def test_retry_exhausts_schedule_and_reraises_last(self):
        calls = []

        def fn():
            calls.append(1)
            raise ConnectionRefusedError(f"attempt {len(calls)}")

        pol = BackoffPolicy(base_s=0.001, factor=1.0, max_s=0.001,
                            max_tries=4)
        with pytest.raises(ConnectionRefusedError, match="attempt 4"):
            retry(fn, pol)
        assert len(calls) == 4

    def test_retry_does_not_catch_foreign_errors(self):
        pol = BackoffPolicy(base_s=0.001, factor=1.0, max_s=0.001,
                            max_tries=3)
        with pytest.raises(ValueError):
            retry(lambda: (_ for _ in ()).throw(ValueError("x")), pol,
                  retry_on=(OSError,))


# ============================================================ checkpoints
class TestCheckpointManager:
    def test_async_write_failure_surfaces_on_next_call(self, tmp_path,
                                                       monkeypatch):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        boom = RuntimeError("disk full")

        def bad_save(*a, **k):
            raise boom

        monkeypatch.setattr(np, "save", bad_save)
        mgr.save(1, {"x": np.zeros(3)})     # async: returns immediately
        with pytest.raises(RuntimeError, match="disk full"):
            mgr.wait()
        # the parked error is consumed — the manager is usable again
        monkeypatch.undo()
        mgr.save(2, {"x": np.zeros(3)})
        mgr.wait()
        assert mgr.steps() == [2]

    def test_sync_write_failure_raises_at_call_site(self, tmp_path,
                                                    monkeypatch):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        monkeypatch.setattr(
            np, "save",
            lambda *a, **k: (_ for _ in ()).throw(OSError("nope")))
        with pytest.raises(OSError, match="nope"):
            mgr.save(1, {"x": np.zeros(3)})

    def test_tmp_gc_and_torn_snapshots_skipped(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(5, {"x": np.arange(4)}, {"tag": "good"})
        mgr.wait()
        # a crash mid-save leaves a .tmp_; a foreign writer may leave a
        # manifest-less step dir — neither may shadow the good snapshot
        os.makedirs(tmp_path / "step_000000009.tmp_")
        os.makedirs(tmp_path / "step_000000010")
        assert mgr.steps() == [5]
        step, tree, extras = mgr.restore_latest({"x": np.zeros(4, int)})
        assert step == 5 and extras["tag"] == "good"
        np.testing.assert_array_equal(tree["x"], np.arange(4))
        # a NEW manager (the restarted server) GCs the torn tmp dir
        CheckpointManager(str(tmp_path), keep=3)
        assert not (tmp_path / "step_000000009.tmp_").exists()


# ============================================================ snapshots
class TestSnapshotRestore:
    def test_roundtrip_bitwise_and_resume_stays_bitwise(self, tmp_path):
        """Restore is bitwise AND the restored server's next apply is
        bitwise-identical to the original's — resume at a snapshot
        boundary replays the same trajectory."""
        a = make_server(n_workers=2, policy="dssp", s_lower=8,
                        s_upper=16)
        push_rounds(a, 3, workers=(0, 1))
        tree, extras = snapshot_server(a)

        b = make_server(n_workers=2, policy="dssp", s_lower=8,
                        s_upper=16)
        restore_server(b, tree, extras)
        assert packed_state(a) == packed_state(b)
        assert a.shard_versions() == b.shard_versions()
        assert a.metrics.total_pushes == b.metrics.total_pushes
        for sa, sb in zip(a.shards, b.shards):
            assert sa.tracker.counts == sb.tracker.counts
            assert sa.tracker.credits == sb.tracker.credits

        push_rounds(a, 2, workers=(0, 1), seed=99)
        push_rounds(b, 2, workers=(0, 1), seed=99)
        assert packed_state(a) == packed_state(b)

    def test_restore_equalizes_crossshard_counts(self):
        """Regression for the post-failover DSSP hang: a snapshot can
        catch a push recorded on early shards but not late ones; the
        worker then RETRIES that push, and without equalization its
        early-shard counts run two ahead — two workers could block on
        each other across different shards' barriers forever."""
        a = make_server(n_workers=2, policy="dssp", s_lower=8,
                        s_upper=16)
        push_rounds(a, 2, workers=(0, 1))
        tree, extras = snapshot_server(a)
        # simulate the mid-push capture: worker 0's interrupted push
        # made it onto shard 0's tracker only
        counts = extras["shards"][0]["tracker"]["counts"]
        counts["0"] = int(counts["0"]) + 1

        b = make_server(n_workers=2, policy="dssp", s_lower=8,
                        s_upper=16)
        restore_server(b, tree, extras)
        for st in b.shards:
            assert st.tracker.counts == {0: 2, 1: 2}
            # table A is reset: the dead process's clock readings must
            # not feed the Algorithm-2 estimator
            assert all(math.isnan(x) for ts in st.tracker.table.values()
                       for x in ts)

    def test_restore_across_topology_reshards_then_installs(self):
        """A cross-arity restore is no longer an error: the target
        server live-reshards to the snapshot's arity FIRST (installing
        the migration map, so stale-epoch pushes keep translating),
        then installs shard-for-shard — bitwise."""
        a = make_server(n_shards=2)
        push_rounds(a, 2)
        tree, extras = snapshot_server(a)
        b = make_server(n_shards=3)
        restore_server(b, tree, extras)
        assert len(b.shards) == 2
        assert b.reshard_epoch == 1          # the reshard that aligned it
        assert packed_state(a) == packed_state(b)
        assert a.shard_versions() == b.shard_versions()

    def test_restore_rejects_mismatched_mono_topology(self):
        """The monolithic server cannot reshard — a snapshot from a
        different arity still refuses loudly."""
        from repro.ps.server import ParameterServer
        a = make_server(n_shards=2)
        tree, extras = snapshot_server(a)
        mono = ParameterServer(
            tiny_params(), make_policy_factory("asp", n_workers=1)(),
            ServerOptimizer(lr=0.05), 1, apply_mode="packed")
        with pytest.raises(ValueError, match="reshard"):
            restore_server(mono, tree, extras)

    def test_snapshotter_skips_unchanged_and_keeps_k(self, tmp_path):
        server = make_server()
        mgr = CheckpointManager(str(tmp_path), keep=2)
        snap = ServerSnapshotter(server, mgr, every_s=60.0)
        push_rounds(server, 1)
        assert snap.save_now() is True
        assert snap.save_now() is False      # nothing moved
        for seed in (1, 2, 3):
            push_rounds(server, 1, seed=seed)
            assert snap.save_now() is True
        mgr.wait()
        assert len(mgr.steps()) == 2         # keep-K GC ran

    def test_restore_latest_roundtrips_through_disk(self, tmp_path):
        a = make_server(n_workers=2, policy="dssp", s_lower=8,
                        s_upper=16)
        push_rounds(a, 3, workers=(0, 1))
        mgr = CheckpointManager(str(tmp_path), keep=3)
        ServerSnapshotter(a, mgr, every_s=60.0).save_now()
        mgr.wait()

        b = make_server(n_workers=2, policy="dssp", s_lower=8,
                        s_upper=16)
        step = restore_latest(b, CheckpointManager(str(tmp_path), keep=3))
        assert step == a.version
        assert packed_state(a) == packed_state(b)

    def test_restore_latest_on_empty_dir_is_fresh_start(self, tmp_path):
        b = make_server()
        assert restore_latest(
            b, CheckpointManager(str(tmp_path), keep=3)) is None
        assert b.version == 0

    def test_tree_mode_server_is_rejected(self):
        server = ShardedParameterServer(
            tiny_params(), make_policy_factory("asp"),
            lambda: ServerOptimizer(lr=0.05), 1, 2, apply_mode="tree")
        with pytest.raises(ValueError, match="packed"):
            snapshot_server(server)


# ============================================================ fault plans
class _CountingChannel:
    def __init__(self):
        self.requests = 0

    def request(self, data):
        self.requests += 1
        return b"ok"

    def close(self):
        pass


class TestFaultPlan:
    def test_roundtrip_and_unknown_keys_ignored(self):
        plan = FaultPlan(kill_server_round=10, drop_kind=MSG_PUSH,
                         drop_prob=0.25, seed=3)
        d = plan.to_dict()
        d["someday_field"] = 1
        assert FaultPlan.from_dict(d) == plan
        assert FaultPlan.from_dict(None) == FaultPlan()
        assert not FaultPlan().active

    def test_validation(self):
        with pytest.raises(ValueError):
            FaultPlan(drop_prob=1.5)
        with pytest.raises(ValueError):
            FaultPlan(delay_ms=-1.0)

    def test_worker_kill_due(self):
        plan = FaultPlan(kill_worker=1, kill_worker_round=3)
        assert plan.worker_kill_due(1, 3)
        assert not plan.worker_kill_due(0, 3)
        assert not plan.worker_kill_due(1, 2)
        assert not FaultPlan().worker_kill_due(0, 0)

    def test_drops_are_deterministic_and_kind_filtered(self):
        from repro.wireformat import Frame, encode_frame
        push = encode_frame(Frame(
            kind=MSG_PUSH,
            payload=np.zeros((8, WIRE_LANES), np.float32)))
        pull = encode_frame(Frame(kind=MSG_PULL))
        plan = FaultPlan(drop_kind=MSG_PUSH, drop_prob=0.5, seed=11)

        def outcomes():
            ch = FaultyChannel(_CountingChannel(), plan, worker_id=4)
            out = []
            for _ in range(32):
                try:
                    ch.request(push)
                    out.append("ok")
                except TransportClosed:
                    out.append("drop")
            return out, ch

        a, ch_a = outcomes()
        b, _ = outcomes()
        assert a == b                        # same plan+worker, same chaos
        assert "drop" in a and "ok" in a
        # non-matching kinds pass untouched (RNG not even consulted)
        before = ch_a.inner.requests
        for _ in range(8):
            ch_a.request(pull)
        assert ch_a.inner.requests == before + 8


# ============================================================ reconnect
def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


class TestReconnect:
    def test_tcp_connect_retries_until_server_binds(self):
        server = make_server()
        port = _free_port()
        address = ("tcp", "127.0.0.1", port)
        transports = []

        def bind_late():
            time.sleep(0.4)
            endpoint = PSServerEndpoint(server)
            t = TcpTransport("127.0.0.1", port)
            t.serve(endpoint)
            transports.append(t)

        threading.Thread(target=bind_late, daemon=True).start()
        client = connect(address, 0)         # retries with backoff
        try:
            assert client.hello() == server.plan.wire_layout().total_rows
        finally:
            client.close()
            time.sleep(0.05)
            transports[0].shutdown()

    def test_client_reconnect_reacquires_seat_exactly_once(self):
        server = make_server(n_workers=1)
        endpoint = PSServerEndpoint(server)
        port = _free_port()
        t1 = TcpTransport("127.0.0.1", port)
        t1.serve(endpoint)
        client = connect(("tcp", "127.0.0.1", port), 0)
        rows = client.hello()
        wire = client.pull_packed()
        assert wire is not None

        t1.shutdown()                        # the server machine dies
        with pytest.raises((TransportClosed, OSError)):
            for _ in range(4):               # first recv may drain a buffer
                client.pull_packed()
        # drop the dead channel so the server-side socket leaves
        # FIN_WAIT_2 (blocks rebind) for TIME_WAIT (does not); a real
        # worker's reconnect() does this before its first retry
        client.channel.close()

        def rebind():                        # failover on the same port
            t = TcpTransport("127.0.0.1", port)
            t.serve(endpoint)
            return t

        t2 = retry(rebind, BackoffPolicy(base_s=0.05, factor=2.0,
                                         max_s=0.5, max_tries=10))
        try:
            pol = BackoffPolicy(base_s=0.05, factor=2.0, max_s=0.4,
                                max_tries=8)
            assert client.reconnect(pol) == rows
            assert client.reconnects == 1
            # the seat exists exactly once on every shard
            for st in server.shards:
                assert st.tracker.workers == [0]
            # and the wire is live again end to end
            g = np.random.RandomState(0).randn(
                rows, WIRE_LANES).astype(np.float32)
            assert client.push_packed(g) is True
        finally:
            client.close()
            t2.shutdown()


# ============================================================ chaos (procs)
def _registry_server(n_workers, policy="bsp"):
    import jax

    from repro.configs import get_smoke_config
    from repro.models import registry

    params = registry.init_params(get_smoke_config(ARCH),
                                  jax.random.PRNGKey(0))
    return ShardedParameterServer(
        params, make_policy_factory(policy, n_workers=n_workers,
                                    s_lower=0, s_upper=3),
        lambda: ServerOptimizer(lr=0.05), n_workers, 2,
        apply_mode="fused")


@pytest.mark.parametrize("kind", ["tcp", "shmem"])
def test_worker_killed_while_gated_seat_freed_and_respawned(kind):
    """Chaos: worker 1 SIGKILLs itself mid-run while worker 0 is gated
    on it (BSP barrier).  The corpse's seat is freed (worker 0 runs
    on), and the respawned replacement re-acquires the seat exactly
    once."""
    from repro.launch.proc_pool import (ProcessWorkerPool, WorkerTask,
                                        raise_on_failure)

    server = _registry_server(n_workers=2, policy="bsp")
    endpoint = PSServerEndpoint(server)
    transport = make_transport(kind, n_workers=2)
    transport.serve(endpoint)
    task = WorkerTask(
        arch=ARCH, n_shards=2, n_iterations=4,
        fault_plan=FaultPlan(kill_worker=1,
                             kill_worker_round=2).to_dict())
    pool = ProcessWorkerPool(transport.address(), task, 2)
    pool.start()
    try:
        results = pool.join(timeout=240.0, endpoint=endpoint, respawn=1)
        raise_on_failure(results)
        assert pool.respawned == [1]         # exactly one replacement
        assert [r.iterations_done for r in results] == [4, 4]
        # A duplicated seat would leave the BSP barrier waiting on a
        # phantom worker (the join above would time out); completing
        # proves the replacement re-acquired worker 1's seat exactly
        # once.  Clean BYEs then release every seat — none leak.
        for st in server.shards:
            assert st.tracker.workers == []
        assert server.metrics.pushes[0] == 4
        assert server.metrics.pushes[1] >= 4     # corpse's rounds + rerun
    finally:
        pool.terminate()
        server.stop()
        transport.shutdown()


def test_chaos_dssp_server_sigkill_resumes_and_recovers(tmp_path):
    """The headline end-to-end: 2-worker DSSP over tcp, server
    SIGKILLed at aggregate push round 10 by its own FaultPlan watchdog,
    restarted on the SAME port, resumes from the latest snapshot; both
    workers reconnect (no hang, no duplicate barrier seats), finish
    every iteration, the loss trajectory spans the failover, and the
    per-shard snapshot pause is bounded (from the spilled obs spans)."""
    from repro.api import RunSpec
    from repro.launch.proc_pool import (ProcessWorkerPool, WorkerTask,
                                        raise_on_failure)

    ckpt = tmp_path / "ckpt"
    spill = tmp_path / "spill"
    spec = RunSpec.from_dict({
        "model": {"arch": ARCH, "smoke": True},
        "ps": {"kind": "sharded", "shards": 2, "workers": 2,
               "apply": "fused"},
        "wire": {"format": "packed", "delta_pull": True},
        "sync": {"mode": "dssp"},
        "transport": {"kind": "tcp"},
        "ft": {"snapshot_every_s": 0.3, "dir": str(ckpt), "resume": True,
               "reconnect_tries": 10, "reconnect_base_s": 0.1,
               "reconnect_max_s": 2.0, "fault_kill_server_round": 10,
               "fault_seed": 7},
    })
    sp = ServerProcess(spec, trace_spill=str(spill))
    addr = sp.start()
    assert sp.resumed_step is None           # fresh run: nothing to resume
    pool = ProcessWorkerPool(addr, WorkerTask.from_spec(spec, 12), 2)
    pool.start()
    try:
        assert sp.wait_dead(180.0), "FaultPlan watchdog never fired"
        addr2 = sp.restart()
        assert addr2 == addr                 # same host:port across failover
        assert sp.resumed_step is not None and sp.resumed_step > 0
        results = pool.join(timeout=300.0)
        raise_on_failure(results)
        assert [r.iterations_done for r in results] == [12, 12]
    finally:
        pool.terminate()
        sp.stop()
        sp.kill()

    # -- post-mortem over the on-disk snapshots -----------------------
    mgr = CheckpointManager(str(ckpt), keep=spec.ft.keep)
    step = mgr.latest_step()
    assert step is not None and step >= sp.resumed_step
    # every captured state (including mid-run ones) holds each barrier
    # seat at most once — a duplicate seat after reconnect would also
    # have hung the join above
    for s in mgr.steps():
        with open(os.path.join(mgr._step_dir(s), "manifest.json")) as f:
            ex = json.load(f)["extras"]
        for shard in ex["shards"]:
            workers = shard["tracker"]["workers"]
            assert len(workers) == len(set(workers))
            assert set(workers) <= {0, 1}
    # the final (graceful-stop) snapshot: clean BYEs released every
    # seat, and both workers pushed their full run through the server.
    # Metrics are restored from the last pre-kill snapshot, so pushes
    # acked in the (snapshot, SIGKILL] window are legitimately absent —
    # the worker got its ack and never re-sends them.  The kill fires
    # once total_pushes reaches 10 and resumed_step is the version the
    # snapshot captured, so that window holds at most 10 - resumed_step
    # pushes (+ slack for watchdog-poll overshoot).  Conversely the one
    # in-flight push a worker DOES retry can be double-counted: +1.
    with open(os.path.join(mgr._step_dir(step), "manifest.json")) as f:
        extras = json.load(f)["extras"]
    for shard in extras["shards"]:
        assert shard["tracker"]["workers"] == []
    pushes = {int(w): c for w, c in extras["metrics"]["pushes"].items()}
    lost = max(0, 10 - sp.resumed_step) + 2
    assert set(pushes) == {0, 1}
    assert pushes[0] >= 12 - lost and pushes[1] >= 12 - lost
    assert pushes[0] <= 13 and pushes[1] <= 13
    assert sum(pushes.values()) >= 24 - lost
    losses = [p[2] for p in extras["metrics"]["loss_trajectory"]]
    assert len(losses) >= 12                 # spans both incarnations
    assert all(math.isfinite(x) for x in losses)
    assert min(losses[-4:]) <= losses[0] + 0.5   # training recovered

    # -- spilled obs spans survived the SIGKILL -----------------------
    events = []
    for p in glob.glob(str(spill / "*.jsonl")):
        with open(p) as f:
            events.extend(json.loads(line) for line in f)
    names = {e["name"] for e in events}
    assert {"snapshot", "snapshot_shard", "failover"} <= names
    failover = [e for e in events if e["name"] == "failover"]
    assert len(failover) == 1
    assert failover[0]["args"]["step"] == sp.resumed_step
    # per-shard pause = the snapshot's lock HOLD, bounded well below
    # the push path's own apply latency on this box
    pauses = [e["dur"] for e in events if e["name"] == "snapshot_shard"]
    assert pauses and max(pauses) < 0.5


def test_chaos_reshard_sigkill_mid_migration_resumes_untorn(tmp_path):
    """Reshard x failover: the server's own FaultPlan SIGKILLs it
    MID-MIGRATION (after old shards have been copied out, before the
    swap), it restarts on the same port, resumes from the latest
    snapshot, the re-armed trigger finishes the interrupted migration,
    and both workers complete every iteration.  Every on-disk snapshot
    holds EITHER the pre-kill plan or the post-migration plan — never a
    torn mixture."""
    from repro.api import RunSpec
    from repro.launch.proc_pool import (ProcessWorkerPool, WorkerTask,
                                        raise_on_failure)

    ckpt = tmp_path / "ckpt"
    spec = RunSpec.from_dict({
        "model": {"arch": ARCH, "smoke": True},
        "ps": {"kind": "sharded", "shards": 2, "workers": 2,
               "apply": "fused"},
        "wire": {"format": "packed", "delta_pull": True},
        "sync": {"mode": "dssp"},
        "transport": {"kind": "tcp"},
        "ft": {"snapshot_every_s": 0.3, "dir": str(ckpt), "resume": True,
               "reconnect_tries": 10, "reconnect_base_s": 0.1,
               "reconnect_max_s": 2.0, "reshard_shards": 3,
               "reshard_round": 8, "fault_kill_mid_reshard": True,
               "fault_seed": 7},
    })
    sp = ServerProcess(spec)
    addr = sp.start()
    pool = ProcessWorkerPool(addr, WorkerTask.from_spec(spec, 12), 2)
    pool.start()
    try:
        assert sp.wait_dead(180.0), "mid-migration kill never fired"
        addr2 = sp.restart()
        assert addr2 == addr
        assert sp.resumed_step is not None and sp.resumed_step > 0
        results = pool.join(timeout=300.0)
        raise_on_failure(results)
        assert [r.iterations_done for r in results] == [12, 12]
    finally:
        pool.terminate()
        sp.stop()
        sp.kill()

    # -- post-mortem: no snapshot is ever torn ------------------------
    mgr = CheckpointManager(str(ckpt), keep=spec.ft.keep)
    steps = mgr.steps()
    assert steps
    arities = set()
    for s in steps:
        with open(os.path.join(mgr._step_dir(s), "manifest.json")) as f:
            ex = json.load(f)["extras"]
        # internally consistent: the shard list, version vector and
        # arity agree (epoch-stable capture retries across a racing
        # migration rather than mixing two plans)
        assert len(ex["shards"]) == ex["n_shards"] == len(ex["versions"])
        assert ex["n_shards"] in (2, 3)
        arities.add((ex["n_shards"], ex["reshard_epoch"]))
    # epoch and arity move together: 2 shards only at epoch 0, 3 only
    # after the migration bumped it
    for n, e in arities:
        assert (n == 2 and e == 0) or (n == 3 and e >= 1)
    # the re-armed trigger finished the interrupted migration in the
    # second incarnation: the final snapshot is post-migration
    with open(os.path.join(mgr._step_dir(steps[-1]),
                           "manifest.json")) as f:
        final = json.load(f)["extras"]
    assert final["n_shards"] == 3
    losses = [p[2] for p in final["metrics"]["loss_trajectory"]]
    assert len(losses) >= 12 and all(math.isfinite(x) for x in losses)


# ============================================================ session wiring
def test_session_ft_rig_snapshots_and_resumes(tmp_path):
    """The declarative path: a RunSpec with an ``ft`` block makes the
    session snapshot while training and a second session resume."""
    from repro.api import build_session

    base = {
        "model": {"arch": ARCH, "smoke": True},
        "ps": {"kind": "sharded", "shards": 2, "workers": 2,
               "apply": "fused"},
        "wire": {"format": "packed"},
        "sync": {"mode": "asp"},
        "transport": {"kind": "inproc"},
        "ft": {"snapshot_every_s": 0.05, "dir": str(tmp_path),
               "resume": False},
    }
    with build_session(base) as session:
        out = session.run(4)
    assert out["ft"]["snapshots"] >= 1
    assert out["ft"]["resumed_step"] is None
    assert out["ft"]["latest_step"] is not None

    resume = dict(base, ft={"snapshot_every_s": 0.0,
                            "dir": str(tmp_path), "resume": True})
    with build_session(resume) as session:
        out2 = session.run(2)
    # close() takes one final snapshot after metrics() was read, so the
    # resumed step is at least the last step the first session reported
    assert out2["ft"]["resumed_step"] >= out["ft"]["latest_step"]
