"""Roofline extraction unit tests: HLO shape parsing, wire-byte model,
affine depth fit, model-flops accounting."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.shapes import SHAPES, cell_applicable
from repro.roofline import analysis as R


def test_shape_bytes():
    assert R.shape_bytes("bf16[16,256,512]{2,1,0}") == 16 * 256 * 512 * 2
    assert R.shape_bytes("f32[]") == 4
    assert R.shape_bytes("(f32[8], bf16[4,4])") == 8 * 4 + 16 * 2
    assert R.shape_bytes("pred[10]") == 10
    assert R.shape_bytes("token[]") == 0      # unknown dtype ignored


HLO = """
ENTRY %main {
  %ar = f32[16,1024]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  %arp = f32[16,1024]{1,0} all-reduce(%y), replica_groups=[16,16]<=[256], to_apply=%add.clone_promoted
  %ag = bf16[32,2048]{1,0} all-gather(%z), replica_groups=[8,32]<=[256], dimensions={1}
  %rs = f32[4,64]{1,0} reduce-scatter(%w), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = bf16[128]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %aa = f32[8,8]{1,0} all-to-all(%u), replica_groups=[2,128]<=[256]
}
"""


def test_parse_collectives_wire_model():
    st = R.parse_collectives(HLO)
    s_ar = 16 * 1024 * 4
    # plain AR: 2*S*(n-1)/n with n=16; promoted AR counted at half size
    expected_ar = 2 * s_ar * 15 / 16 + 2 * (s_ar // 2) * 15 / 16
    assert st.bytes_by_kind["all-reduce"] == int(expected_ar)
    s_ag = 32 * 2048 * 2
    assert st.bytes_by_kind["all-gather"] == int(s_ag * 31 / 32)
    s_rs = 4 * 64 * 4
    assert st.bytes_by_kind["reduce-scatter"] == s_rs * 3
    assert st.bytes_by_kind["collective-permute"] == 128 * 2
    assert st.count_by_kind["all-reduce"] == 2
    assert st.total_bytes > 0


def test_affine_fit():
    # c(d) = 10 + 7d measured at d=1,2 -> extrapolate to 24
    assert R.affine_fit(17.0, 24.0, 1, 2, 24) == pytest.approx(10 + 7 * 24)


def test_model_flops_train_vs_decode():
    cfg = get_config("h2o-danube-1.8b")
    tr = R.model_flops(cfg, SHAPES["train_4k"])
    de = R.model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.param_count()
    assert tr == pytest.approx(6.0 * n * 4096 * 256)
    assert de == pytest.approx(2.0 * n * 128)


def test_model_flops_moe_uses_active_params():
    cfg = get_config("qwen3-moe-235b-a22b")
    total = cfg.param_count()
    active = cfg.active_param_count()
    assert active < 0.2 * total          # 22B active of 235B
    assert R.model_flops(cfg, SHAPES["train_4k"]) == pytest.approx(
        6.0 * active * 4096 * 256)


def test_cell_applicability_matrix():
    full_attn = ["qwen1.5-110b", "qwen1.5-32b", "mistral-large-123b",
                 "qwen3-moe-235b-a22b", "deepseek-moe-16b",
                 "whisper-tiny", "chameleon-34b"]
    subq = ["h2o-danube-1.8b", "xlstm-125m", "jamba-v0.1-52b"]
    for a in full_attn:
        assert not cell_applicable(a, "long_500k")
        assert cell_applicable(a, "train_4k")
    for a in subq:
        assert cell_applicable(a, "long_500k")


def test_cost_configs_families():
    for arch, expect_none in (("xlstm-125m", True),
                              ("h2o-danube-1.8b", False),
                              ("jamba-v0.1-52b", False),
                              ("whisper-tiny", False)):
        cc = R.cost_configs(get_config(arch))
        assert (cc is None) == expect_none
        if cc is not None:
            c1, c2, d1, d2, L = cc
            assert c1.scan_unroll and c2.scan_unroll
            assert c1.attn_chunk == 0 and c1.grad_accum == 1
            assert d2 > d1 and L >= d2


def test_slstm_correction_only_for_xlstm():
    x = R.slstm_correction_flops(get_config("xlstm-125m"),
                                 SHAPES["train_4k"])
    assert x > 0
    assert R.slstm_correction_flops(get_config("h2o-danube-1.8b"),
                                    SHAPES["train_4k"]) == 0.0


def test_roofline_terms_dominant_and_fraction():
    t = R.RooflineTerms(
        arch="a", shape="train_4k", mesh="16x16",
        flops=1e12, hbm_bytes=1e11, collective_bytes=1e9,
        t_compute=1e12 / R.PEAK_FLOPS, t_memory=1e11 / R.HBM_BW,
        t_collective=1e9 / R.ICI_BW,
        model_flops=6e14, per_device_argument_bytes=1e9,
        peak_memory_bytes=2e9, collective_counts={})
    assert t.dominant == "memory"
    assert 0 < t.roofline_fraction < 1
    assert t.useful_flops_ratio == pytest.approx(6e14 / (1e12 * 256))
