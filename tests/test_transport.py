"""Transport layer: frame codec, process-boundary round trips, failure
paths, and the end-to-end process-isolated training run.

The process tests spawn REAL OS worker processes (multiprocessing
``spawn`` — never fork, jax is live in the parent) and verify the
packed (rows, 512) buffer survives the wire bitwise, for both ``tcp``
and ``shmem``, with and without frame-level int8 compression.
"""

from __future__ import annotations

import hashlib
import multiprocessing
import os
import socket
import struct
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policies import make_policy_factory
from repro.perfcount import TRANSPORT
from repro.ps.server import ServerOptimizer
from repro.ps.sharded import ShardedParameterServer
from repro.transport import (
    PSServerEndpoint,
    ShardRouter,
    TransportClosed,
    connect,
    make_transport,
)
from repro import wireformat as wf

pytestmark = pytest.mark.filterwarnings(
    "ignore::UserWarning")  # mp resource_tracker chatter on some paths


# ---------------------------------------------------------------- helpers
def tiny_params():
    return {"w": jnp.ones((48, 32), jnp.float32),
            "b": jnp.zeros((17,), jnp.float32)}


def make_server(n_workers=1, n_shards=2, policy="asp", **pkw):
    return ShardedParameterServer(
        tiny_params(),
        make_policy_factory(policy, n_workers=n_workers, staleness=2,
                            s_lower=0, s_upper=2, **pkw),
        lambda: ServerOptimizer(lr=0.05),
        n_workers, n_shards, apply_mode="fused")


def serve(kind, server, n_workers=1, shards=None):
    endpoint = PSServerEndpoint(server, shards=shards)
    transport = make_transport(kind, n_workers=n_workers)
    transport.serve(endpoint)
    return endpoint, transport


def digest(arr) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


# ============================================================ frame codec
class TestFrameCodec:
    def test_f32_roundtrip_bitwise(self):
        a = np.random.RandomState(0).randn(16, wf.WIRE_LANES)
        a = a.astype(np.float32)
        f = wf.Frame(kind=wf.MSG_PUSH, worker=7, shard=3, clock=99,
                     payload=a)
        g = wf.decode_frame(wf.encode_frame(f))
        assert g.payload.tobytes() == a.tobytes()
        assert (g.kind, g.worker, g.shard, g.clock) == (
            wf.MSG_PUSH, 7, 3, 99)

    def test_bf16_roundtrip_bitwise(self):
        import ml_dtypes
        a = np.random.RandomState(1).randn(8, wf.WIRE_LANES)
        a = a.astype(ml_dtypes.bfloat16)
        g = wf.decode_frame(wf.encode_frame(
            wf.Frame(kind=wf.MSG_PULL, payload=a)))
        assert g.payload.dtype == a.dtype
        assert g.payload.tobytes() == a.tobytes()

    def test_int8_compression_shrinks_and_bounds_error(self):
        a = np.random.RandomState(2).randn(8, wf.WIRE_LANES)
        a = a.astype(np.float32)
        raw = wf.encode_frame(wf.Frame(kind=wf.MSG_PUSH, payload=a))
        packed = wf.encode_frame(wf.Frame(kind=wf.MSG_PUSH, payload=a),
                                 compress="int8")
        assert len(packed) - wf.HEADER_SIZE == \
            (len(raw) - wf.HEADER_SIZE) // 4
        g = wf.decode_frame(packed)
        assert g.flags & wf.FLAG_INT8
        scale = np.max(np.abs(a)) / 127.0
        assert np.max(np.abs(g.payload - a)) <= scale * 0.5 + 1e-7

    def test_int8_decode_is_deterministic(self):
        a = np.random.RandomState(3).randn(8, wf.WIRE_LANES)
        a = a.astype(np.float32)
        raw = wf.encode_frame(wf.Frame(kind=wf.MSG_PUSH, payload=a),
                              compress="int8")
        assert wf.decode_frame(raw).payload.tobytes() == \
            wf.decode_frame(raw).payload.tobytes()

    def test_error_frame(self):
        f = wf.decode_frame(wf.encode_frame(
            wf.Frame(kind=wf.MSG_ERR, error="kaboom")))
        assert f.error == "kaboom" and f.payload is None

    @pytest.mark.parametrize("mangle", [
        lambda b: b[:20],                                 # short header
        lambda b: b"XXXX" + b[4:],                        # bad magic
        lambda b: b[:4] + bytes([99]) + b[5:],            # bad version
        lambda b: b[:5] + bytes([200]) + b[6:],           # unknown kind
        lambda b: b[:6] + bytes([77]) + b[7:],            # unknown dtype
        lambda b: b[:-8],                                 # truncated body
    ])
    def test_header_validation_rejects(self, mangle):
        a = np.zeros((8, wf.WIRE_LANES), np.float32)
        raw = wf.encode_frame(wf.Frame(kind=wf.MSG_PUSH, payload=a))
        before = TRANSPORT.header_rejects
        with pytest.raises(wf.FrameError):
            wf.decode_frame(mangle(raw))
        assert TRANSPORT.header_rejects == before + 1

    def test_length_field_must_match_rows(self):
        a = np.zeros((8, wf.WIRE_LANES), np.float32)
        raw = bytearray(wf.encode_frame(wf.Frame(kind=wf.MSG_PUSH,
                                                 payload=a)))
        # corrupt payload_len (offset: 4s B B B B i i q I -> 28..36)
        struct.pack_into("<Q", raw, 28, 12345)
        with pytest.raises(wf.FrameError):
            wf.decode_frame(bytes(raw))

    def test_non_wire_shape_rejected_on_encode(self):
        with pytest.raises(wf.FrameError):
            wf.encode_frame(wf.Frame(kind=wf.MSG_PUSH,
                                     payload=np.zeros((4, 100))))


# ============================================= process-boundary round trip
def _echo_child(address, seed, q):
    """Spawned child: echoes a deterministic buffer through the server
    endpoint (plain + int8 frames) and reports digests of what came
    back, plus a digest of the pulled params."""
    try:
        client = connect(address, 0)
        rows = client.hello()
        rng = np.random.RandomState(seed)
        buf = rng.randn(rows, 512).astype(np.float32)
        back = client.echo(buf)
        back8 = client.echo(buf, compress="int8")
        pulled = client.pull_packed()
        client.bye()
        client.close()
        q.put({"echo": digest(back), "echo8": digest(back8),
               "pull": digest(pulled), "rows": rows})
    except BaseException as e:
        q.put({"error": repr(e)})


@pytest.mark.parametrize("kind", ["tcp", "shmem"])
def test_bitwise_roundtrip_across_process_boundary(kind):
    server = make_server()
    endpoint, transport = serve(kind, server)
    rows = server.plan.wire_layout().total_rows
    ctx = multiprocessing.get_context("spawn")
    q = ctx.Queue()
    p = ctx.Process(target=_echo_child, args=(transport.address(), 42, q),
                    daemon=True)
    p.start()
    got = q.get(timeout=120.0)
    p.join(timeout=30.0)
    server.stop()
    transport.shutdown()
    assert "error" not in got, got
    assert got["rows"] == rows
    # Same machine, same seed: the child's buffer is reproducible here,
    # so a bitwise-equal digest proves the frame survived two crossings
    # of a real process boundary unchanged.
    rng = np.random.RandomState(42)
    buf = rng.randn(rows, 512).astype(np.float32)
    assert got["echo"] == digest(buf)
    # int8 is lossy but deterministic: quantize+dequantize locally and
    # require the over-the-wire version to match BITWISE.
    deq = wf.decode_frame(wf.encode_frame(
        wf.Frame(kind=wf.MSG_ECHO, payload=buf), compress="int8")).payload
    assert got["echo8"] == digest(deq)
    # And the pull: the server's packed params, bitwise.
    assert got["pull"] == digest(np.asarray(server.pull_packed()))


def _push_child(address, seed, q):
    try:
        client = connect(address, 0)
        rows = client.hello()
        rng = np.random.RandomState(seed)
        grads = rng.randn(rows, 512).astype(np.float32)
        ok = client.push_packed(grads)
        after = client.pull_packed()
        client.bye()
        client.close()
        q.put({"ok": ok, "after": digest(after)})
    except BaseException as e:
        q.put({"error": repr(e)})


@pytest.mark.parametrize("kind", ["tcp", "shmem"])
def test_push_across_boundary_matches_local_push(kind):
    """A spawned process's push must land bit-identically to the same
    push made locally (the full pull-push-apply-pull cycle)."""
    remote = make_server()
    local = make_server()
    endpoint, transport = serve(kind, remote)
    rows = remote.plan.wire_layout().total_rows
    ctx = multiprocessing.get_context("spawn")
    q = ctx.Queue()
    p = ctx.Process(target=_push_child, args=(transport.address(), 7, q),
                    daemon=True)
    p.start()
    got = q.get(timeout=120.0)
    p.join(timeout=30.0)
    remote.stop()
    transport.shutdown()
    assert "error" not in got, got
    assert got["ok"]
    grads = np.random.RandomState(7).randn(rows, 512).astype(np.float32)
    local.push_packed(0, jnp.asarray(grads))
    assert got["after"] == digest(np.asarray(local.pull_packed()))


# ==================================================== shard-routed endpoints
def test_per_shard_routing_across_two_endpoints():
    """Different shards behind different endpoints (even different
    backends) apply exactly like one full-buffer push."""
    routed = make_server()
    mono = make_server()
    layout = routed.plan.wire_layout()
    ep0, t0 = serve("tcp", routed, shards=[0])
    ep1, t1 = serve("shmem", routed, n_workers=1, shards=[1])
    c0, c1 = t0.connect(0), t1.connect(0)
    c0.hello(), c1.hello()
    router = ShardRouter({0: c0, 1: c1}, layout.shard_rows)

    wire = np.random.RandomState(5).randn(
        layout.total_rows, 512).astype(np.float32)
    assert router.push_packed(wire)
    mono.push_packed(0, jnp.asarray(wire))
    assert digest(router.pull_packed()) == \
        digest(np.asarray(mono.pull_packed()))
    assert routed.shard_versions() == mono.shard_versions()

    # frames for a shard an endpoint does not serve are rejected
    with pytest.raises(wf.FrameError):
        c0.pull_packed(shard=1)
    with pytest.raises(wf.FrameError):
        c0.pull_packed()  # routed endpoints require an explicit shard
    routed.stop(), mono.stop()
    t0.shutdown(), t1.shutdown()


def test_routed_push_rejects_global_gating():
    server = ShardedParameterServer(
        tiny_params(), make_policy_factory("asp", n_workers=1),
        lambda: ServerOptimizer(lr=0.05), 1, 2,
        apply_mode="fused", gating="global")
    with pytest.raises(ValueError, match="gating"):
        server.push_packed_shard(
            0, 0, jnp.zeros((server.plan.wire_layout().shard_rows[0], 512)))
    server.stop()


# ========================================================== failure paths
def _truncating_child(address, q):
    """Connects, HELLOs, then sends HALF a push frame and dies — the
    'worker process killed mid-push' wire state."""
    try:
        client = connect(address, 0)
        rows = client.hello()
        buf = np.ones((rows, 512), np.float32)
        raw = wf.encode_frame(wf.Frame(kind=wf.MSG_PUSH, worker=0,
                                       payload=buf))
        sock = client.channel._sock
        sock.sendall(raw[:len(raw) // 2])
        q.put("sent-half")
    except BaseException as e:
        q.put(f"error {e!r}")
    # flush the queue's feeder thread, THEN die without any clean-up
    q.close()
    q.join_thread()
    os._exit(1)


def test_worker_killed_mid_push_frees_its_barrier_seat():
    """BSP gates worker 1 on worker 0's pushes; killing worker 0 halfway
    through a push frame must (a) not crash the server and (b) remove
    worker 0 from the barrier group so worker 1 is released."""
    server = make_server(n_workers=2, policy="bsp")
    endpoint, transport = serve("tcp", server)
    ctx = multiprocessing.get_context("spawn")
    q = ctx.Queue()
    p = ctx.Process(target=_truncating_child,
                    args=(transport.address(), q), daemon=True)
    p.start()
    assert q.get(timeout=120.0) == "sent-half"
    p.join(timeout=30.0)

    # The server notices the dead connection and frees the seat.
    deadline = time.monotonic() + 20.0
    while time.monotonic() < deadline:
        if all(0 not in st.tracker.counts for st in server.shards):
            break
        time.sleep(0.05)
    assert all(0 not in st.tracker.counts for st in server.shards), \
        "dead worker still holds a barrier seat"

    # Worker 1's BSP push does not block on the corpse.
    c1 = transport.connect(1)
    c1.hello()
    rows = server.plan.wire_layout().total_rows
    t0 = time.monotonic()
    assert c1.push_packed(np.zeros((rows, 512), np.float32))
    assert time.monotonic() - t0 < 10.0
    c1.bye()
    server.stop()
    transport.shutdown()


def test_tcp_garbage_header_gets_error_reply():
    server = make_server()
    endpoint, transport = serve("tcp", server)
    _, host, port = transport.address()
    with socket.create_connection((host, port), timeout=10.0) as sock:
        sock.sendall(b"GARBAGE!" * 8)  # 64 junk bytes >= one header
        reply = sock.recv(1 << 16)
    frame = wf.decode_frame(reply)
    assert frame.kind == wf.MSG_ERR and "magic" in frame.error
    # the server keeps serving fresh connections
    c = transport.connect(0)
    c.hello()
    out = c.echo(np.ones((8, 512), np.float32))
    assert out.shape == (8, 512)
    server.stop()
    transport.shutdown()


def test_tcp_oversized_length_field_rejected():
    server = make_server()
    endpoint, transport = serve("tcp", server)
    _, host, port = transport.address()
    raw = bytearray(wf.encode_frame(wf.Frame(
        kind=wf.MSG_PUSH, worker=0,
        payload=np.zeros((8, 512), np.float32))))
    struct.pack_into("<Q", raw, 28, wf.MAX_PAYLOAD + 1)
    with socket.create_connection((host, port), timeout=10.0) as sock:
        sock.sendall(bytes(raw))
        frame = wf.decode_frame(sock.recv(1 << 16))
    assert frame.kind == wf.MSG_ERR and "exceeds" in frame.error
    server.stop()
    transport.shutdown()


@pytest.mark.parametrize("kind", ["tcp", "shmem"])
def test_clean_shutdown_unblocks_waiting_dssp_workers(kind):
    """A DSSP worker blocked in the policy gate (too far ahead of a
    silent peer) must be released by server.stop() with a STOP reply —
    the clean-shutdown contract."""
    server = make_server(n_workers=2, policy="dssp")
    endpoint, transport = serve(kind, server, n_workers=2)
    rows = server.plan.wire_layout().total_rows
    released = threading.Event()
    state = {}

    def runner():
        c = transport.connect(0)
        c.hello()
        alive = True
        for i in range(50):  # hits the DSSP upper threshold long before 50
            alive = c.push_packed(
                np.zeros((rows, 512), np.float32), clock=i)
            if not alive:
                break
        state["alive"] = alive
        released.set()
        c.close()

    t = threading.Thread(target=runner, daemon=True)
    t.start()
    # Let the worker run into the gate (worker 1 never pushes).
    time.sleep(1.0)
    assert not released.is_set(), "worker was never gated — bad setup"
    server.stop()
    assert released.wait(timeout=15.0), \
        "stop() did not unblock the gated DSSP worker"
    assert state["alive"] is False  # the release was a STOP, not an OK
    t.join(timeout=10.0)
    transport.shutdown()


def test_client_surfaces_shutdown_as_transport_closed():
    server = make_server()
    endpoint, transport = serve("tcp", server)
    c = transport.connect(0)
    c.hello()
    server.stop()
    transport.shutdown()
    with pytest.raises((TransportClosed, wf.FrameError)):
        for _ in range(3):  # first call may still see a buffered STOP
            c.pull_packed()


# ================================================= end-to-end process run
def test_e2e_tcp_processes_match_inproc_threads():
    """Acceptance: train.py's --transport tcp path (3 spawned worker
    processes, DSSP) reaches the same final-loss tolerance as the
    threaded inproc packed path."""
    from repro.configs import get_smoke_config
    from repro.data.synthetic import DataConfig
    from repro.launch.train import train_ps

    cfg = get_smoke_config("xlstm-125m")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4)
    kw = dict(sync="dssp", n_steps=24, lr=0.02, n_shards=2, n_workers=3,
              s_lower=0, s_upper=3, straggler=1.5, arch="xlstm-125m",
              smoke=True)
    inproc = train_ps(cfg, data_cfg, wire_format="packed",
                      transport="inproc", **kw)
    tcp = train_ps(cfg, data_cfg, transport="tcp", **kw)

    assert tcp.version > 0 and tcp.metrics.total_pushes >= 3
    losses_in = [l for _, _, l in inproc.metrics.loss_trajectory]
    losses_tcp = [l for _, _, l in tcp.metrics.loss_trajectory]
    assert losses_in and losses_tcp
    fin_in, fin_tcp = losses_in[-1], losses_tcp[-1]
    assert np.isfinite(fin_in) and np.isfinite(fin_tcp)
    # Same model/data/steps either side of the process boundary: the
    # final losses must agree to the asynchrony tolerance.
    assert abs(fin_tcp - fin_in) <= max(0.15 * abs(fin_in), 0.15), \
        (fin_in, fin_tcp)
