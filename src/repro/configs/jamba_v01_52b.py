"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
[arXiv:2403.19887; hf]
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536, head_dim=128,
    attn_period=8, attn_offset=3, use_rope=False,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336, every=2),
    d_state=16, d_conv=4, expand=2,
    optimizer="adafactor",
    grad_accum=8,
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=256, head_dim=16,
                         attn_period=4, attn_offset=1,
                         moe=MoEConfig(n_experts=4, top_k=2, d_expert=128,
                                       every=2),
                         dtype="float32", remat="none")
