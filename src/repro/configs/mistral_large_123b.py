"""mistral-large-123b — dense GQA.

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=28672, vocab_size=32768, head_dim=128,
    rope_theta=1_000_000.0,
    optimizer="adafactor",
    grad_accum=16,
    decode_batch_shard=False,  # §Perf it.12: contraction-sharded
    # weights psum tiny activations instead of per-token FSDP
    # weight gathers (2.1x faster decode)
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                         d_ff=224, vocab_size=256, head_dim=16,
                         dtype="float32", remat="none")
