"""xlstm-125m — sLSTM + mLSTM blocks (xLSTM[7:1]-ish at 12 layers).

12L d_model=768 4H vocab=50304 (d_ff=0: blocks carry their own
projections).  [arXiv:2405.04517; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    ssm_kind="xlstm", slstm_layers=(5, 11),  # ~7:1 mix at 12 layers
    tie_embeddings=True,
    grad_accum=1, model_axis_role="dp",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=3, d_model=64, n_heads=2, n_kv_heads=2,
                         vocab_size=256, slstm_layers=(1,),
                         dtype="float32", remat="none")
