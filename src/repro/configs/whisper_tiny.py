"""whisper-tiny — encoder-decoder backbone; conv/mel frontend stubbed
(input_specs provides precomputed frame embeddings).

4L(enc)+4L(dec) d_model=384 6H d_ff=1536 vocab=51865, LayerNorm+GELU,
tied embeddings.  [arXiv:2212.04356; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, n_encoder_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    norm="layernorm", act="gelu", use_rope=False, tie_embeddings=True,
    grad_accum=1, model_axis_role="dp",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, n_encoder_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                         dtype="float32", remat="none")
