"""chameleon-34b — early-fusion VLM: VQ image tokens share the text vocab,
so the backbone is a dense LM with qk-norm over a 65536 vocab.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
[arXiv:2405.09818; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536,
    qk_norm=True, rope_theta=10_000.0,
    optimizer="adafactor",
    grad_accum=16,
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=160, vocab_size=256, dtype="float32",
                         remat="none")
