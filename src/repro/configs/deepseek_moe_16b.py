"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6.

28L d_model=2048 16H (MHA kv=16) d_ff=1408(expert) vocab=102400.
[arXiv:2401.06066; hf]

Simplification vs HF checkpoint: the real model keeps layer 0 as a dense
FFN; here every layer is MoE + shared experts (uniform scan body).  Noted
in DESIGN.md §5.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
    grad_accum=4,
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=96, vocab_size=256,
                         moe=MoEConfig(n_experts=8, top_k=2, n_shared=1,
                                       d_expert=96),
                         dtype="float32", remat="none")
