"""qwen3-moe-235b-a22b — MoE, 128 experts top-8, fine-grained d_ff=1536.

94L d_model=4096 64H (GQA kv=4) d_ff=1536(expert) vocab=151936.
[hf:Qwen/Qwen3-30B-A3B family scaling; hf]
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536),
    optimizer="adafactor",
    grad_accum=16,
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=96, vocab_size=256, head_dim=16,
                         moe=MoEConfig(n_experts=8, top_k=2, d_expert=96),
                         dtype="float32", remat="none")
