"""Assigned architecture configs (public-literature shapes) + paper's own.

Each module exposes ``CONFIG`` (full-scale) and ``smoke_config()``
(reduced, same family — used by the per-arch smoke tests).  The dry-run
exercises the full configs via ShapeDtypeStruct only.
"""

from repro.configs.shapes import SHAPES, ShapeSpec, input_specs
from repro.models.config import ModelConfig

_ARCH_MODULES = (
    "h2o_danube_1p8b",
    "qwen15_110b",
    "qwen15_32b",
    "mistral_large_123b",
    "qwen3_moe_235b_a22b",
    "deepseek_moe_16b",
    "xlstm_125m",
    "whisper_tiny",
    "chameleon_34b",
    "jamba_v01_52b",
)

# CLI ids use dashes (match the assignment listing)
_ALIASES = {
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "qwen1.5-110b": "qwen15_110b",
    "qwen1.5-32b": "qwen15_32b",
    "mistral-large-123b": "mistral_large_123b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "xlstm-125m": "xlstm_125m",
    "whisper-tiny": "whisper_tiny",
    "chameleon-34b": "chameleon_34b",
    "jamba-v0.1-52b": "jamba_v01_52b",
}


def arch_names():
    return list(_ALIASES)


def get_config(name: str) -> ModelConfig:
    import importlib
    mod = _ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    try:
        m = importlib.import_module(f"repro.configs.{mod}")
    except ImportError as e:
        raise KeyError(f"unknown architecture {name!r}: {e}") from e
    return m.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    import importlib
    mod = _ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    m = importlib.import_module(f"repro.configs.{mod}")
    return m.smoke_config()
