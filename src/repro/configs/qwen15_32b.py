"""qwen1.5-32b — dense with QKV bias; 40 heads (not 16-divisible: TP falls
back to replicated attention heads + sharded FFN, see DESIGN.md §6).

64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064.
[hf:Qwen/Qwen1.5-0.5B family scaling; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=27392, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
    optimizer="adafactor",
    grad_accum=8,
    decode_batch_shard=False,  # 40-head MHA cache: seq takes both axes
    kv_cache_dtype="int8",     # 5.1 TiB cache at bf16 > 16 GiB/chip
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=60, n_heads=5, n_kv_heads=5,
                         d_ff=144, vocab_size=256, dtype="float32",
                         remat="none")
