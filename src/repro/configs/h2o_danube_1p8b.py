"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
[arXiv:2401.16818; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab_size=32000,
    sliding_window=4096, rope_theta=10_000.0,
    grad_accum=1, model_axis_role="dp",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=160, vocab_size=256, sliding_window=16,
                         dtype="float32", remat="none")
