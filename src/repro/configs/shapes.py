"""Assigned input shapes and ShapeDtypeStruct builders for every cell.

  train_4k     seq 4096,    global batch 256   -> train_step
  prefill_32k  seq 32768,   global batch 32    -> serve prefill
  decode_32k   seq 32768,   global batch 128   -> serve_step (1 new token,
                                                 KV/state cache of seq_len)
  long_500k    seq 524288,  global batch 1     -> serve_step, sub-quadratic
                                                 attention archs only

``input_specs`` returns (kind, specs-dict) where every leaf is a
``jax.ShapeDtypeStruct`` — weak-type-correct, shardable, no allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs with sub-quadratic attention (SWA / recurrent / hybrid) run
# long_500k; pure full-attention archs skip it (DESIGN.md §5)
SUB_QUADRATIC = {"h2o-danube-1.8b", "xlstm-125m", "jamba-v0.1-52b"}


def cell_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in SUB_QUADRATIC
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model inputs as ShapeDtypeStructs for the given cell."""
    b, l = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.family == "audio":
            return {"frames": _sds((b, l, cfg.d_model), cfg.dtype),
                    "tokens": _sds((b, l), jnp.int32),
                    "labels": _sds((b, l), jnp.int32)}
        return {"tokens": _sds((b, l), jnp.int32),
                "labels": _sds((b, l), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {"frames": _sds((b, l, cfg.d_model), cfg.dtype),
                    "tokens": _sds((b, l), jnp.int32),
                    "labels": _sds((b, l), jnp.int32)}
        return {"tokens": _sds((b, l), jnp.int32),
                "labels": _sds((b, l), jnp.int32)}
    # decode: one new token against a cache of length seq_len
    return {"token": _sds((b, 1), jnp.int32)}


def state_sds(cfg: ModelConfig, shape: ShapeSpec) -> Any:
    """Decode cache/state as ShapeDtypeStructs (kind == 'decode')."""
    from repro.models import registry
    b, l = shape.global_batch, shape.seq_len
    fam = registry.family(cfg)
    if cfg.family == "audio":
        return jax.eval_shape(
            lambda: fam.init_state(cfg, b, l, l))
    return jax.eval_shape(lambda: fam.init_state(cfg, b, l))
