"""qwen1.5-110b — dense with QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
[hf:Qwen/Qwen1.5-0.5B family scaling; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=49152, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
    optimizer="adafactor",   # Adam state would not fit 16 GB/chip at 110B
    grad_accum=16,
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
                         d_ff=192, vocab_size=256, dtype="float32",
                         remat="none")
