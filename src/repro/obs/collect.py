"""Cross-process trace collection: many ring buffers, one timeline.

Worker processes drain their ``TRACE`` ring as ``MSG_TRACE`` frames
over whatever transport the run already speaks (plus a per-process
JSONL spill for abnormal exits — see ``launch/proc_pool.py``); the
server-side ``PSServerEndpoint`` hands each batch to a
``TraceCollector``, which dedups and merges them with the server's own
recorder into one run timeline.

Dedup is by ``(src, seq)``: a worker's events may arrive twice (once
over a frame, once recovered from its spill file), and the per-recorder
monotone ``seq`` makes the duplicate exact, so recovery after a kill is
idempotent with the happy path.

``MetricsSampler`` is the interval half of the telemetry: a daemon
thread sampling a callable (staleness histogram, per-worker wait,
effective threshold, perfcount counters — whatever the session wires
in) into ``metrics_snapshot`` instants on the server recorder.
"""

from __future__ import annotations

import pathlib
import threading
from typing import Any, Callable, Dict, Iterable, List

from repro.obs.trace import TraceRecorder


class TraceCollector:
    """Merge drained event batches from many sources, exactly once."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._seen: set = set()

    def __len__(self) -> int:
        return len(self._events)

    # -- ingestion -------------------------------------------------------
    def ingest(self, source: str, events: Iterable[Any]) -> int:
        """Add one drained batch; returns how many were new.

        Each event keeps its own ``src`` when it carries one (spill
        files and frames both ship recorder-stamped events); ``source``
        is the fallback for events without.  Malformed entries are
        dropped, not raised — collection must never fail a run.
        """
        added = 0
        with self._lock:
            for e in events:
                if not isinstance(e, dict) or "name" not in e:
                    continue
                src = e.get("src") or source
                key = (src, e.get("seq", -1))
                if key in self._seen:
                    continue
                self._seen.add(key)
                if e.get("src") != src:
                    e = dict(e)
                    e["src"] = src
                self._events.append(e)
                added += 1
        return added

    def ingest_local(self, recorder: TraceRecorder,
                     source: str = "server") -> int:
        """Drain an in-process recorder straight into the collector."""
        return self.ingest(recorder.source or source, recorder.drain())

    def ingest_spill_dir(self, path) -> int:
        """Recover per-process JSONL spill files (``<src>.jsonl``).

        The reader tolerates a truncated final line — exactly what a
        killed worker leaves behind.
        """
        from repro.obs.export import read_jsonl
        p = pathlib.Path(path)
        if not p.is_dir():
            return 0
        added = 0
        for f in sorted(p.glob("*.jsonl")):
            added += self.ingest(f.stem, read_jsonl(f))
        return added

    # -- merged views ----------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def timeline(self) -> List[Dict[str, Any]]:
        """All events on one wall-clock axis (export order)."""
        return sorted(self.events(),
                      key=lambda e: (e.get("ts", 0.0), e.get("src", ""),
                                     e.get("seq", -1)))

    def by_worker_clock(self) -> List[Dict[str, Any]]:
        """The run timeline the DSSP analysis wants: grouped by worker,
        ordered by that worker's iteration clock.  The key is total —
        ``(worker, clock, ts, src, seq)`` — so the merge order is
        stable regardless of frame/spill arrival order."""
        return sorted(self.events(),
                      key=lambda e: (e.get("worker", -1),
                                     e.get("clock", -1),
                                     e.get("ts", 0.0),
                                     e.get("src", ""),
                                     e.get("seq", -1)))


class MetricsSampler(threading.Thread):
    """Periodic ``metrics_snapshot`` instants on a recorder.

    ``fn`` runs on this daemon thread every ``every`` seconds; its dict
    becomes the event's ``args``.  ``stop()`` takes one final sample so
    even a run shorter than the interval gets a snapshot.
    """

    def __init__(self, recorder: TraceRecorder,
                 fn: Callable[[], Dict[str, Any]], every: float):
        super().__init__(name="obs-metrics-sampler", daemon=True)
        if every <= 0:
            raise ValueError(f"sample interval must be > 0, got {every}")
        self.recorder = recorder
        self.fn = fn
        self.every = float(every)
        # NOT named _stop: threading.Thread has a private _stop() method
        # that join() calls internally — shadowing it with an Event
        # makes every join() blow up.
        self._halt = threading.Event()

    def _sample(self) -> None:
        try:
            self.recorder.instant("metrics_snapshot", args=self.fn())
        except Exception:
            pass  # telemetry must never take the run down

    def run(self) -> None:
        while not self._halt.wait(self.every):
            self._sample()

    def stop(self) -> None:
        if not self._halt.is_set():
            self._halt.set()
            self._sample()
        self.join(timeout=2.0)
