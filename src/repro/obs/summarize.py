"""Trace analysis: the paper's quantities out of a trace file.

``summarize(events)`` reduces a merged event list (from a
``TraceCollector`` or read back via ``export.read_trace``) to the
numbers the DSSP paper reports on:

  * **wait fraction** — total ``gate_wait`` time over the run's
    worker-seconds (wall span x number of workers seen computing),
    i.e. the fraction of capacity the synchronization gate burned.
  * **threshold timeline** — the effective staleness threshold chosen
    at each ``dssp_decision`` event, in (worker, clock) order, plus the
    count of threshold *extensions* (decisions where a credit was
    granted or spent — exactly the pushes ``RunMetrics`` counts in
    ``credit_releases``).
  * **staleness percentiles** — p50/p90/p99 of per-push staleness,
    computed from the histogram of ``push`` span args with the same
    weighted-quantile rule as ``ps/metrics.staleness_percentile``.

``python -m repro.obs summarize <trace>`` prints ``format_summary``.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List


def summarize(events: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Reduce a merged trace to the run-level summary dict."""
    from repro.ps.metrics import hist_percentile

    events = list(events)
    spans = [e for e in events if float(e.get("dur", 0.0)) > 0.0]
    t_lo = min((float(e["ts"]) for e in events), default=0.0)
    t_hi = max((float(e.get("ts", 0.0)) + float(e.get("dur", 0.0))
                for e in events), default=0.0)
    wall = max(t_hi - t_lo, 0.0)

    by_name: Dict[str, int] = {}
    for e in events:
        name = e.get("name", "event")
        by_name[name] = by_name.get(name, 0) + 1

    workers = sorted({int(e.get("worker", -1)) for e in events
                      if e.get("name") in ("compute_step", "gate_wait",
                                           "push")
                      and int(e.get("worker", -1)) >= 0})
    wait_s = sum(float(e["dur"]) for e in spans
                 if e.get("name") == "gate_wait")
    busy_s = sum(float(e["dur"]) for e in spans
                 if e.get("name") == "compute_step")
    worker_seconds = wall * max(len(workers), 1)
    wait_fraction = (wait_s / worker_seconds) if worker_seconds > 0 else 0.0

    per_worker_wait: Dict[int, float] = {}
    for e in spans:
        if e.get("name") == "gate_wait":
            w = int(e.get("worker", -1))
            per_worker_wait[w] = per_worker_wait.get(w, 0.0) + float(e["dur"])

    # DSSP decision timeline, in the stable (worker, clock) merge order.
    decisions = sorted(
        (e for e in events if e.get("name") == "dssp_decision"),
        key=lambda e: (int(e.get("clock", -1)), int(e.get("worker", -1)),
                       e.get("seq", -1)))
    timeline = []
    # Extensions dedup by (worker, clock): a sharded server runs one
    # policy PER SHARD, so one push emits S decision events with the
    # same worker-clock; ``RunMetrics.credit_releases`` counts that
    # push once (credit ORed across shards), and so must we.
    extended = set()
    for e in decisions:
        a = e.get("args") or {}
        reason = a.get("reason", "")
        if reason in ("grant", "credit_spend"):
            extended.add((int(e.get("worker", -1)),
                          int(e.get("clock", -1))))
        timeline.append({
            "worker": int(e.get("worker", -1)),
            "clock": int(e.get("clock", -1)),
            "threshold": a.get("threshold"),
            "reason": reason,
            "s_lower": a.get("s_lower"),
            "s_upper": a.get("s_upper"),
        })

    # Staleness distribution from push spans, as a histogram — the
    # weighted-quantile helper keeps this O(distinct values).
    hist: Dict[int, int] = {}
    for e in events:
        if e.get("name") == "push":
            s = (e.get("args") or {}).get("staleness")
            if s is not None:
                hist[int(s)] = hist.get(int(s), 0) + 1
    percentiles = {f"p{q}": hist_percentile(hist, q / 100.0)
                   for q in (50, 90, 99)} if hist else {}

    return {
        "events": len(events),
        "event_counts": by_name,
        "wall_s": wall,
        "workers": workers,
        "wait_s": wait_s,
        "busy_s": busy_s,
        "wait_fraction": wait_fraction,
        "per_worker_wait_s": per_worker_wait,
        "dssp": {
            "decisions": len(decisions),
            "threshold_extensions": len(extended),
            "timeline": timeline,
        },
        "staleness": {"hist": hist, **percentiles},
    }


def format_summary(summary: Dict[str, Any]) -> str:
    """Human-readable rendering of a ``summarize`` dict."""
    lines: List[str] = []
    lines.append(f"events           {summary['events']}")
    counts = summary.get("event_counts", {})
    if counts:
        body = "  ".join(f"{k}={v}" for k, v in sorted(counts.items()))
        lines.append(f"by name          {body}")
    lines.append(f"wall time        {summary['wall_s']:.3f}s")
    workers = summary.get("workers", [])
    lines.append(f"workers          {len(workers)} "
                 f"({', '.join(map(str, workers)) or '-'})")
    lines.append(f"gate wait        {summary['wait_s']:.3f}s  "
                 f"(fraction {summary['wait_fraction']:.4f})")
    pww = summary.get("per_worker_wait_s", {})
    if pww:
        body = "  ".join(f"w{w}={t:.3f}s" for w, t in sorted(pww.items()))
        lines.append(f"wait by worker   {body}")
    dssp = summary.get("dssp", {})
    lines.append(f"dssp decisions   {dssp.get('decisions', 0)}  "
                 f"(threshold extensions {dssp.get('threshold_extensions', 0)})")
    timeline = dssp.get("timeline", [])
    if timeline:
        lines.append("threshold timeline (worker@clock -> threshold/reason):")
        shown = timeline if len(timeline) <= 20 else timeline[:20]
        for d in shown:
            lines.append(f"    w{d['worker']}@{d['clock']:<6d} -> "
                         f"{d['threshold']} ({d['reason']})")
        if len(timeline) > len(shown):
            lines.append(f"    ... {len(timeline) - len(shown)} more")
    st = summary.get("staleness", {})
    if st.get("hist"):
        lines.append(f"staleness        p50={st.get('p50')}  "
                     f"p90={st.get('p90')}  p99={st.get('p99')}")
    return "\n".join(lines)
