"""Run-wide tracing & live telemetry for the DSSP stack.

The paper's contribution is a *runtime* decision — the staleness
threshold is re-chosen per iteration from workers' measured intervals —
so this package makes the runtime observable: typed spans for every
push / gate wait / apply / pull, the DSSP decision timeline, periodic
metrics snapshots, cross-process collection over the existing frame
transports, and Chrome ``trace_event`` (Perfetto-loadable) export.

Layers (see ``src/repro/obs/README.md`` for the event schema and the
overhead contract):

  * ``trace``     — ``TRACE``, the process-local bounded ring-buffer
    recorder every hook writes through.  Disabled (the default) it is
    a no-op attribute check; nothing is allocated, no hot-path event
    counter moves (gated by ``benchmarks/obs_overhead.py``).
  * ``collect``   — ``TraceCollector`` merges drained ring buffers from
    many processes into one run timeline (dedup by ``(src, seq)``,
    stable order by ``(worker, clock)``), plus the ``MetricsSampler``
    interval thread.
  * ``export``    — Chrome trace JSON / JSONL writers and the
    format-sniffing reader.
  * ``summarize`` — the paper's quantities (wait fraction, threshold
    timeline, staleness percentiles) from a trace;
    ``python -m repro.obs summarize <trace>`` on the CLI.

Everything here is stdlib-only: spawned worker processes import it
long before they touch jax.
"""

from repro.obs.collect import MetricsSampler, TraceCollector
from repro.obs.export import (read_jsonl, read_trace, write_chrome_trace,
                              write_jsonl)
from repro.obs.summarize import format_summary, summarize
from repro.obs.trace import TRACE, TraceRecorder

__all__ = [
    "TRACE",
    "TraceRecorder",
    "TraceCollector",
    "MetricsSampler",
    "write_chrome_trace",
    "write_jsonl",
    "read_jsonl",
    "read_trace",
    "summarize",
    "format_summary",
]
