"""Process-local bounded ring-buffer trace recorder.

One module-global recorder (``TRACE``) per process; every hook in the
hot path is written as

    t0 = TRACE.now() if TRACE.enabled else 0.0
    ... the traced region ...
    if TRACE.enabled:
        TRACE.span("push", t0, worker=w, clock=it, args={...})

so a build that never enables tracing pays exactly one attribute read
per hook site, and a *call* on the disabled recorder is a single
early-return (``benchmarks/obs_overhead.py`` measures both and
``perf_gate.py`` gates the trajectory).

Design constraints, in order:

  * **Bounded.**  Events land in a ``collections.deque(maxlen=...)`` —
    a run that out-produces its drain cadence silently drops its
    *oldest* events instead of growing without bound.
  * **Cheap.**  The enabled fast path is one ``perf_counter`` read, one
    counter bump and one tuple append (all GIL-atomic enough for the
    server's many pushing threads; the per-recorder ``seq`` comes from
    ``itertools.count``, whose ``__next__`` is atomic in CPython).
  * **Mergeable.**  Timestamps are monotonic (``time.perf_counter``)
    while recording and converted to *wall-clock* seconds on ``drain``
    using the wall/mono anchor captured at ``enable`` — so ring
    buffers drained from different processes land on one comparable
    time axis, and ordering within a process never goes backwards.

Event record (the dict ``drain`` emits; also the JSONL line format):

    {"seq": int,          # per-recorder monotone id (dedup key)
     "ts": float,         # wall-clock seconds (start of the span)
     "dur": float,        # seconds; 0.0 for instant events
     "name": str,         # see EVENT_NAMES
     "worker": int,       # -1 when not worker-scoped
     "shard": int,        # -1 when not shard-scoped
     "clock": int,        # worker iteration / push count; -1 unknown
     "src": str,          # recorder source ("server", "w0", ...)
     "args": dict}        # optional event payload

Stdlib-only on purpose: spawned workers and CLI tooling import this
without jax anywhere near the path.
"""

from __future__ import annotations

import collections
import itertools
import threading
import time
from typing import Any, Dict, List, Optional

#: Ring capacity when ``enable`` is not given one.  At ~100 bytes per
#: event tuple this bounds a recorder around a few MB.
DEFAULT_CAPACITY = 65536

#: The typed event vocabulary (exporters and ``summarize`` key on it).
EVENT_NAMES = (
    "push",              # span: one gated push, server side
    "gate_wait",         # span: time blocked in the Algorithm-1 gate
    "apply",             # span: one optimizer apply (tree or fused)
    "coalesce_flush",    # span: one batched fused_update_batched launch
    "pull",              # span: full-snapshot pull
    "pull_delta",        # span: version-delta pull
    "kernel_launch",     # instant: one pallas_call dispatch
    "compute_step",      # span: one worker forward/backward iteration
    "dssp_decision",     # instant: Algorithm-1/2 gate decision (DSSP)
    "frame_tx",          # instant: one encoded transport frame
    "frame_rx",          # instant: one decoded transport frame
    "metrics_snapshot",  # instant: periodic MetricsSampler sample
    "snapshot",          # span: one whole server checkpoint (repro.ft)
    "snapshot_shard",    # span: one shard's state grab UNDER its lock —
                         #       the only pause a snapshot imposes
    "reconnect",         # span: a client's backoff reconnect loop
    "failover",          # span: server restart-and-resume from a snapshot
    "fault",             # instant: one injected FaultPlan event
    "replica_refresh",   # span: a serving replica's delta-pull refresh
    "decode_batch",      # span: one continuously-batched decode call
    "staleness_block",   # span: admission blocked on the serve-side
                         #       SSP gate until a fresh refresh landed
)


class TraceRecorder:
    """Bounded, process-local, thread-tolerant event ring."""

    __slots__ = ("enabled", "source", "capacity", "_events", "_seq",
                 "_wall0", "_mono0", "_lock")

    def __init__(self) -> None:
        self.enabled = False
        self.source = ""
        self.capacity = DEFAULT_CAPACITY
        self._events: collections.deque = collections.deque(
            maxlen=DEFAULT_CAPACITY)
        self._seq = itertools.count()
        self._wall0 = 0.0
        self._mono0 = 0.0
        self._lock = threading.Lock()

    # -- lifecycle -------------------------------------------------------
    def enable(self, source: str = "server",
               capacity: int = DEFAULT_CAPACITY) -> None:
        """Arm the recorder: fresh ring, fresh seq, wall/mono anchor."""
        with self._lock:
            self.source = source
            self.capacity = int(capacity)
            self._events = collections.deque(maxlen=self.capacity)
            self._seq = itertools.count()
            self._wall0 = time.time()
            self._mono0 = time.perf_counter()
            self.enabled = True

    def disable(self) -> None:
        """Stop recording and drop anything not yet drained."""
        with self._lock:
            self.enabled = False
            self._events.clear()

    # -- recording (the hot path) ----------------------------------------
    def now(self) -> float:
        """Span start timestamp (monotonic; pair with ``span``)."""
        return time.perf_counter()

    def instant(self, name: str, *, worker: int = -1, shard: int = -1,
                clock: int = -1,
                args: Optional[Dict[str, Any]] = None) -> None:
        """Record a zero-duration event; no-op while disabled."""
        if not self.enabled:
            return
        self._events.append((next(self._seq), time.perf_counter(), 0.0,
                             name, worker, shard, clock, args))

    def span(self, name: str, t0: float, *, worker: int = -1,
             shard: int = -1, clock: int = -1,
             args: Optional[Dict[str, Any]] = None) -> None:
        """Record a span started at ``t0`` (= an earlier ``now()``)
        ending now; no-op while disabled."""
        if not self.enabled:
            return
        dur = time.perf_counter() - t0
        self._events.append((next(self._seq), t0, dur, name, worker,
                             shard, clock, args))

    # -- draining --------------------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    def drain(self) -> List[Dict[str, Any]]:
        """Swap the ring out and return its events as wall-clock dicts.

        Safe to call while recording continues: the swap happens under
        the lock; an append racing the swap lands in whichever ring it
        grabbed first (at most a handful of events slide to the next
        drain — never lost, never duplicated).
        """
        with self._lock:
            if not self._events:
                return []
            batch = self._events
            self._events = collections.deque(maxlen=self.capacity)
            wall0, mono0, src = self._wall0, self._mono0, self.source
        out = []
        for seq, t0, dur, name, worker, shard, clock, args in batch:
            e: Dict[str, Any] = {
                "seq": seq,
                "ts": wall0 + (t0 - mono0),
                "dur": dur,
                "name": name,
                "worker": worker,
                "shard": shard,
                "clock": clock,
                "src": src,
            }
            if args:
                e["args"] = args
            out.append(e)
        return out


#: The process-global recorder every instrumented site writes through.
TRACE = TraceRecorder()
