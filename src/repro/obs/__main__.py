"""CLI surface of the obs layer.

    python -m repro.obs summarize run_trace.json     # Chrome trace
    python -m repro.obs summarize run_trace.jsonl    # JSONL trace
    python -m repro.obs summarize --json trace.json  # machine-readable

Reads either export format (sniffed by content, not extension) and
prints the paper's quantities — wait fraction, DSSP threshold timeline,
staleness percentiles — for the whole merged run.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.obs.export import read_trace
from repro.obs.summarize import format_summary, summarize


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    sp = sub.add_parser("summarize",
                        help="print wait fraction, threshold timeline and "
                             "staleness percentiles from a trace file")
    sp.add_argument("trace", metavar="TRACE",
                    help="Chrome trace JSON or JSONL trace file")
    sp.add_argument("--json", action="store_true",
                    help="emit the summary as JSON instead of text")
    args = ap.parse_args(argv)

    try:
        events = read_trace(args.trace)
    except OSError as e:
        print(f"cannot read {args.trace}: {e}", file=sys.stderr)
        return 1
    if not events:
        print(f"no events in {args.trace}", file=sys.stderr)
        return 1
    summary = summarize(events)
    try:
        if args.json:
            print(json.dumps(summary, indent=2, sort_keys=True,
                             default=str))
        else:
            print(format_summary(summary))
        sys.stdout.flush()
    except BrokenPipeError:
        # ``summarize trace | head`` closed the pipe — not an error.
        # Unhook stdout so the interpreter's exit flush stays quiet.
        sys.stdout = open(os.devnull, "w")
    return 0


if __name__ == "__main__":
    sys.exit(main())
