"""Trace export/import: Chrome ``trace_event`` JSON and JSONL.

Two formats, one event schema (see ``trace.py``):

  * **JSONL** — one event dict per line.  This is the spill format
    workers write incrementally (a truncated last line from a killed
    process is tolerated on read) and the lossless interchange format.
  * **Chrome trace JSON** — ``{"traceEvents": [...]}``, loadable in
    Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.  Spans
    become complete events (``ph: "X"``), zero-duration records become
    thread-scoped instants (``ph: "i"``); each source gets its own pid
    with a ``process_name`` metadata record, and the worker id becomes
    the tid so per-worker lanes line up.  The native fields Chrome has
    no slot for (``seq``/``clock``/``shard``/``src``) ride in ``args``
    so ``read_trace`` can round-trip the file back into event dicts.
"""

from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, Iterable, List

_US = 1e6  # trace_event timestamps are microseconds


# -- JSONL ---------------------------------------------------------------
def write_jsonl(events: Iterable[Dict[str, Any]], path) -> int:
    """Write events one-per-line; returns the count written."""
    n = 0
    with open(path, "w", encoding="utf-8") as fh:
        for e in events:
            fh.write(json.dumps(e, separators=(",", ":")))
            fh.write("\n")
            n += 1
    return n


def read_jsonl(path) -> List[Dict[str, Any]]:
    """Read a JSONL trace, tolerating a truncated final line (the
    signature a killed worker's spill file leaves behind)."""
    out: List[Dict[str, Any]] = []
    try:
        fh = open(path, "r", encoding="utf-8")
    except OSError:
        return out
    with fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                e = json.loads(line)
            except json.JSONDecodeError:
                continue  # mid-write when the process died
            if isinstance(e, dict):
                out.append(e)
    return out


# -- Chrome trace_event --------------------------------------------------
def _chrome_records(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    pids = {src: i + 1 for i, src in
            enumerate(sorted({e.get("src", "") for e in events}))}
    records: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
         "args": {"name": src or "unknown"}}
        for src, pid in pids.items()
    ]
    for e in events:
        args = dict(e.get("args") or {})
        for k in ("seq", "clock", "shard", "src"):
            if k in e:
                args[k] = e[k]
        rec: Dict[str, Any] = {
            "name": e.get("name", "event"),
            "cat": "repro",
            "ts": float(e.get("ts", 0.0)) * _US,
            "pid": pids.get(e.get("src", ""), 0),
            "tid": max(int(e.get("worker", -1)), 0),
            "args": args,
        }
        dur = float(e.get("dur", 0.0))
        if dur > 0.0:
            rec["ph"] = "X"
            rec["dur"] = dur * _US
        else:
            rec["ph"] = "i"
            rec["s"] = "t"
        records.append(rec)
    return records


def write_chrome_trace(events: Iterable[Dict[str, Any]], path) -> int:
    """Write a Perfetto-loadable ``{"traceEvents": [...]}`` file;
    returns the number of (non-metadata) events written."""
    events = list(events)
    doc = {"traceEvents": _chrome_records(events),
           "displayTimeUnit": "ms"}
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh)
    return len(events)


def _from_chrome(records: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    out: List[Dict[str, Any]] = []
    for r in records:
        if not isinstance(r, dict) or r.get("ph") == "M":
            continue
        args = dict(r.get("args") or {})
        e: Dict[str, Any] = {
            "seq": args.pop("seq", -1),
            "ts": float(r.get("ts", 0.0)) / _US,
            "dur": float(r.get("dur", 0.0)) / _US,
            "name": r.get("name", "event"),
            "worker": int(r.get("tid", -1)),
            "shard": args.pop("shard", -1),
            "clock": args.pop("clock", -1),
            "src": args.pop("src", ""),
        }
        if args:
            e["args"] = args
        out.append(e)
    return out


def read_trace(path) -> List[Dict[str, Any]]:
    """Read either trace format back into event dicts.

    Sniffs the content: a JSON object with ``traceEvents`` is a Chrome
    trace; anything else is treated as JSONL.
    """
    p = pathlib.Path(path)
    text = p.read_text(encoding="utf-8")
    stripped = text.lstrip()
    if stripped.startswith("{"):
        try:
            doc = json.loads(text)
        except json.JSONDecodeError:
            doc = None
        if isinstance(doc, dict) and "traceEvents" in doc:
            return _from_chrome(doc["traceEvents"])
    return read_jsonl(p)
