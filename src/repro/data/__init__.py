"""Synthetic data pipeline (deterministic, host-sharded, resumable)."""

from repro.data.synthetic import DataConfig, MarkovLM, batches, loss_floor

__all__ = ["DataConfig", "MarkovLM", "batches", "loss_floor"]
