"""Deterministic synthetic data pipeline.

A seeded first-order Markov chain over the vocabulary (Zipfian marginals,
banded transitions) — cheap to generate, deterministic, and *learnable*:
cross-entropy drops well below the unigram entropy, so convergence
experiments (paper Fig. 3/4 analogues) have a real signal.

Sharding contract: ``batches(...)`` yields host-local shards, keyed by
(seed, step, host) — every host computes only its rows, any host can
deterministically regenerate any step (checkpoint resume = set cursor;
elastic rescale = change n_hosts, data order stays a pure function of
the step index).  For the audio (whisper) family the "frontend stub"
emits pseudo frame embeddings derived from the same stream.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 32        # out-degree of the Markov chain


class MarkovLM:
    """Vocab-sized first-order chain with Zipf marginals."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        v = cfg.vocab_size
        # each token transitions to `branching` successors with Zipf weights
        self.successors = rng.randint(0, v, size=(v, cfg.branching))
        w = 1.0 / np.arange(1, cfg.branching + 1) ** 1.2
        self.weights = (w / w.sum()).astype(np.float64)

    def sample_rows(self, step: int, rows: np.ndarray) -> np.ndarray:
        """Generate tokens (len(rows), seq_len) for global row ids at a
        step — pure function of (seed, step, row)."""
        cfg = self.cfg
        out = np.empty((len(rows), cfg.seq_len + 1), np.int32)
        for i, r in enumerate(rows):
            rng = np.random.RandomState(
                (cfg.seed * 1_000_003 + step * 7_919 + int(r)) % 2**31)
            tok = rng.randint(cfg.vocab_size)
            choices = rng.choice(cfg.branching, size=cfg.seq_len + 1,
                                 p=self.weights)
            for t in range(cfg.seq_len + 1):
                out[i, t] = tok
                tok = self.successors[tok, choices[t]]
        return out

    def unigram_entropy_bound(self) -> float:
        """Entropy of the transition distribution = achievable loss floor."""
        w = self.weights
        return float(-(w * np.log(w)).sum())


def batches(model_cfg: ModelConfig, data_cfg: DataConfig, *,
            host_index: int = 0, n_hosts: int = 1,
            start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Host-sharded batch iterator.  Rows [host_index::n_hosts]."""
    if data_cfg.global_batch % n_hosts:
        raise ValueError("global_batch must divide across hosts")
    chain = MarkovLM(data_cfg)
    rows = np.arange(data_cfg.global_batch)[host_index::n_hosts]
    step = start_step
    while True:
        toks = chain.sample_rows(step, rows)
        batch: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }
        if model_cfg.family == "audio":
            # frontend stub: pseudo frame embeddings from the token ids
            rng = np.random.RandomState(data_cfg.seed + 17)
            proj = rng.randn(64, model_cfg.d_model).astype(np.float32) * 0.1
            batch["frames"] = proj[toks[:, :-1] % 64]
        yield batch
        step += 1


def loss_floor(data_cfg: DataConfig) -> float:
    """Achievable NLL on this stream (the chain's conditional entropy)."""
    return MarkovLM(data_cfg).unigram_entropy_bound()
