"""Threaded parameter server — the runnable counterpart of the simulator.

Holds the globally shared weights, applies pushed gradients under a lock
(paper Alg. 1 line 2: concurrent pushes are serialized/aggregated), and
gates workers through the configured ``SyncPolicy``.  Workers are threads
executing real jitted JAX train steps (see ``repro.ps.worker``); the GIL
is released inside XLA compute and inside ``time.sleep`` so heterogeneity
injection behaves like genuinely slower devices.

The server optimizer is pluggable; the paper uses plain SGD on the server
(workers send raw gradients).  A staleness-aware variant scales the step
by 1/(1+staleness) (Omnivore-style momentum tempering, §II related work).
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro._compat import warn_legacy
from repro.api.protocol import DeltaPull, ParameterServerProtocol
from repro.core.policies import SyncPolicy
from repro.core.staleness import StalenessTracker
from repro.obs.trace import TRACE
from repro.perfcount import WIRE
from repro.ps.metrics import RunMetrics

Params = Any  # pytree
Grads = Any   # pytree

#: Trace-time counter for the shared apply (tests assert that LR
#: changes and additional optimizer instances do NOT retrace).
APPLY_TRACES = {"count": 0}

#: Default linger (seconds) a coalescing flusher waits for its window
#: to fill before launching a partial batch — small enough to vanish
#: next to a training step, large enough for concurrently-pushing
#: workers to land in one batch.
DEFAULT_COALESCE_WAIT_S = 0.05


class CoalesceWindow:
    """Bounded micro-batching window over packed applies.

    One instance per lock domain (the mono server, or one shard of the
    sharded server).  ``submit`` is called UNDER ``cond`` with one
    contribution; the first contributor with no flush in flight becomes
    the *flusher*: it lingers up to ``server.coalesce_wait`` for the
    window to fill (capped at the live worker count — a window larger
    than the barrier group can ever fill would stall every round),
    drains up to ``server.coalesce`` contributions, and folds them
    through ONE ``fused_update_batched`` launch in enqueue order.  The
    kernel dispatch runs with ``cond`` RELEASED so concurrent pushes
    can queue into the next window (that concurrency IS the batching);
    ``install`` re-installs the buffers and the version bump together
    under the lock, so readers never observe a version that does not
    match the resident buffer.  Later contributors wait until the
    flusher has applied their sequence number.

    ``server`` supplies the live knobs (``coalesce``,
    ``coalesce_wait``, ``stopped``, ``_clock``); ``get_pm`` returns the
    resident (params, momentum) wire buffers; ``install(p, m, n)``
    commits them plus an ``n``-contribution version bump (called under
    ``cond``).
    """

    def __init__(self, server, cond, optimizer, tracker, get_pm,
                 install):
        self.server = server
        self.cond = cond
        self.optimizer = optimizer
        self.tracker = tracker
        self.get_pm = get_pm
        self.install = install
        self.pending: list = []      # (wire_g, scale) tuples
        self.applying = False        # a flusher owns the window
        self.enq_seq = 0             # contributions ever queued
        self.applied_seq = 0         # contributions ever applied

    def submit(self, wire_g, scale: float) -> None:
        """Queue one contribution (called under ``cond``) and return
        once it has been applied."""
        srv = self.server
        self.pending.append((wire_g, scale))
        self.enq_seq += 1
        my_seq = self.enq_seq
        self.cond.notify_all()       # wake a lingering flusher
        if self.applying:
            while self.applied_seq < my_seq and not srv.stopped:
                self.cond.wait(timeout=0.5)
            return
        self.applying = True
        try:
            # The fill target is capped at the LIVE worker count and
            # recomputed on every wake-up: a worker removed while the
            # flusher lingers (its seat freed by ``remove_worker``,
            # which notifies this cond) shrinks the target immediately
            # instead of stalling the flush for the full linger on a
            # window that can no longer fill.
            def window() -> int:
                return max(1, min(srv.coalesce, len(self.tracker.workers)))

            while self.pending and not srv.stopped:
                if srv.coalesce_wait > 0.0 and len(self.pending) < window():
                    deadline = srv._clock() + srv.coalesce_wait
                    while (len(self.pending) < window()
                           and not srv.stopped):
                        remaining = deadline - srv._clock()
                        if remaining <= 0:
                            break
                        self.cond.wait(timeout=remaining)
                batch = self.pending[:srv.coalesce]
                del self.pending[:len(batch)]
                self._flush(batch)
        finally:
            self.applying = False
            self.cond.notify_all()

    def _flush(self, batch: list) -> None:
        """One batched launch over ``batch`` (called under ``cond``;
        drops the lock for the kernel dispatch)."""
        from repro.kernels import ops as kops
        t0 = TRACE.now() if TRACE.enabled else 0.0
        opt = self.optimizer
        bufs = [b for b, _ in batch]
        scales = [s for _, s in batch]
        p, m = self.get_pm()
        self.cond.release()
        try:
            gs = bufs[0][None] if len(bufs) == 1 else jnp.stack(bufs)
            new_p, new_m = kops.fused_update_batched(
                p, m, gs, lr=opt.lr, beta=opt.momentum, scales=scales)
        finally:
            self.cond.acquire()
        self.install(new_p, new_m, len(batch))
        self.applied_seq += len(batch)
        if len(batch) > 1:
            WIRE.apply_launches_saved += len(batch) - 1
        if TRACE.enabled:
            TRACE.span("coalesce_flush", t0, args={"n": len(batch)})
        self.cond.notify_all()


@jax.jit
def _momentum_sgd(params, grads, velocity, lr, momentum, scale):
    """One damped momentum-SGD step, shared by every ServerOptimizer.

    ``lr``/``momentum``/``scale`` arrive as traced f32 scalars, NOT
    Python closures: changing an optimizer's LR (spec-driven schedules)
    never retraces, and all optimizer instances with like-shaped trees
    share one compilation cache entry.
    """
    APPLY_TRACES["count"] += 1  # Python side runs only when tracing
    new_v = jax.tree_util.tree_map(
        lambda v, g: momentum * v + g * scale, velocity, grads)
    new_p = jax.tree_util.tree_map(
        lambda p, v: p - lr * v, params, new_v)
    return new_p, new_v


class ServerOptimizer:
    """SGD with optional momentum + staleness-aware damping."""

    def __init__(self, lr: float, momentum: float = 0.0,
                 staleness_damping: bool = False):
        self.lr = lr
        self.momentum = momentum
        self.staleness_damping = staleness_damping
        self._velocity: Optional[Params] = None

    def step(self, params: Params, grads: Grads, staleness: int) -> Params:
        if self._velocity is None:
            self._velocity = jax.tree_util.tree_map(jnp.zeros_like, grads)
        scale = 1.0 / (1.0 + staleness) if self.staleness_damping else 1.0
        params, self._velocity = _momentum_sgd(
            params, grads, self._velocity,
            jnp.asarray(self.lr, jnp.float32),
            jnp.asarray(self.momentum, jnp.float32),
            jnp.asarray(scale, jnp.float32))
        return params


class ParameterServer(ParameterServerProtocol):
    """Global weight store + Algorithm-1 gating.  Thread-safe.

    ``apply_mode='packed'`` makes the plan's lane-aligned (rows, 512)
    wire buffer the resident representation: params + momentum live
    packed, a tree push packs ONCE and folds through a single fused
    Pallas launch, and ``push_packed``/``pull_packed`` skip the
    pytree<->wire boundary entirely (the monolithic counterpart of the
    sharded server's packed hot path).
    """

    def __init__(self, params: Params, policy: SyncPolicy,
                 optimizer: ServerOptimizer, n_workers: int,
                 clock: Callable[[], float] = time.monotonic,
                 apply_mode: str = "tree", coalesce: int = 1,
                 coalesce_wait: Optional[float] = None):
        warn_legacy("ParameterServer",
                    "repro.api.build_session(RunSpec(ps=ServerSpec("
                    "kind='mono', ...)))")
        if apply_mode not in ("tree", "packed"):
            raise ValueError(f"unknown apply mode {apply_mode!r}")
        if coalesce < 1:
            raise ValueError(f"coalesce window must be >= 1, got {coalesce}")
        if coalesce > 1 and apply_mode != "packed":
            raise ValueError("coalesce > 1 batches packed applies; it "
                             "requires apply_mode='packed'")
        self._params: Optional[Params] = params
        self.policy = policy
        self.optimizer = optimizer
        self.apply_mode = apply_mode
        self.tracker = StalenessTracker(range(n_workers))
        self.metrics = RunMetrics(policy=policy.name, n_workers=n_workers)
        self._cond = threading.Condition()
        self._clock = clock
        self._t0 = clock()
        self.version = 0          # number of applied updates
        self.stopped = False
        self.coalesce = coalesce
        self.coalesce_wait = (coalesce_wait if coalesce_wait is not None
                              else (DEFAULT_COALESCE_WAIT_S
                                    if coalesce > 1 else 0.0))
        if apply_mode == "packed":
            # The plan (1 shard) carries the wire layout; kernel imports
            # stay inside the apply so `import repro.ps` is kernel-free.
            from repro.ps.sharded.plan import build_shard_plan
            self.plan = build_shard_plan(params, 1)
            self._wire_p = self.plan.pack(params)
            self._wire_m = jnp.zeros_like(self._wire_p)
            self._window = CoalesceWindow(
                self, self._cond, optimizer, self.tracker,
                self._get_pm, self._install_pm)
        else:
            self.plan = None

    # -- worker API -----------------------------------------------------------
    def pull(self, worker: int) -> Params:
        """Fetch the latest global weights (jax arrays are immutable ⇒ a
        reference snapshot is consistent).

        Packed mode keeps a version-keyed unpacked cache that is rebuilt
        OUTSIDE the lock, so a pull right after an apply never blocks
        concurrent pushes for the duration of the unpack.
        """
        t0 = TRACE.now() if TRACE.enabled else 0.0
        with self._cond:
            if self._params is not None:
                params, version = self._params, self.version
                if TRACE.enabled:
                    TRACE.span("pull", t0, worker=worker,
                               args={"version": version, "cached": True})
                return params
            wire, version = self._wire_p, self.version
        params = self.plan.unpack(wire)
        with self._cond:
            if self.version == version and self._params is None:
                self._params = params
            if TRACE.enabled:
                TRACE.span("pull", t0, worker=worker,
                           args={"version": version, "cached": False})
            return params

    def pull_packed(self, worker: int = -1) -> jax.Array:
        """The packed wire buffer itself — already a consistent snapshot."""
        if self.apply_mode != "packed":
            raise ValueError("pull_packed requires apply_mode='packed'")
        t0 = TRACE.now() if TRACE.enabled else 0.0
        with self._cond:
            wire, version = self._wire_p, self.version
        if TRACE.enabled:
            TRACE.span("pull", t0, worker=worker,
                       args={"version": version, "packed": True})
        return wire

    def pull_delta(self, worker: int,
                   versions: Optional[Any] = None) -> DeltaPull:
        """Single-shard version-delta pull: the whole buffer when the
        version moved (or on a vector mismatch — ``full=True``), an
        empty delta when the worker is already current."""
        if self.apply_mode != "packed":
            raise ValueError("pull_delta requires apply_mode='packed'")
        t0 = TRACE.now() if TRACE.enabled else 0.0
        with self._cond:
            wire, version = self._wire_p, self.version
        full_bytes = int(wire.size) * jnp.dtype(wire.dtype).itemsize
        mismatch = (versions is None or len(versions) != 1
                    or int(versions[0]) > version)
        if not mismatch and int(versions[0]) == version:
            WIRE.full_pull_bytes_avoided += full_bytes
            if TRACE.enabled:
                TRACE.span("pull_delta", t0, worker=worker,
                           args={"version": version, "empty": True})
            return DeltaPull(versions=(version,))
        WIRE.delta_bytes_tx += full_bytes
        if TRACE.enabled:
            TRACE.span("pull_delta", t0, worker=worker,
                       args={"version": version, "full": mismatch})
        return DeltaPull(versions=(version,), shards=(0,),
                         regions=(wire,), full=mismatch)

    def push(self, worker: int, grads: Grads) -> None:
        """Alg. 1 server block: update weights, then gate.  Blocks the
        calling worker thread until the policy releases it."""
        self._push(worker, grads, packed=False)

    def push_packed(self, worker: int, wire: jax.Array) -> None:
        """Packed-wire push: the gradient arrives in wire layout and folds
        straight through one fused launch — zero server-side packing."""
        if self.apply_mode != "packed":
            raise ValueError("push_packed requires apply_mode='packed'")
        if wire.shape != self._wire_p.shape:
            raise ValueError(f"wire buffer {wire.shape} does not match "
                             f"layout {self._wire_p.shape}")
        self._push(worker, wire, packed=True)

    def _push(self, worker: int, payload: Any, packed: bool) -> None:
        t_push = TRACE.now() if TRACE.enabled else 0.0
        if self.apply_mode == "packed" and not packed:
            # Packing depends only on the (immutable) payload — do it
            # BEFORE taking the lock so concurrent pulls/pushes never
            # stall behind the concat+gather dispatch.
            payload = self.plan.pack(payload)
        with self._cond:
            now = self._clock() - self._t0
            rec = self.tracker.record_push(worker, now)
            dec = self.policy.on_push(self.tracker, worker, now)
            if dec.apply_update:
                t_apply = TRACE.now() if TRACE.enabled else 0.0
                if self.apply_mode == "packed":
                    if self.coalesce > 1:
                        self._apply_coalesced(payload, rec.staleness)
                    else:
                        self._apply_packed(payload, rec.staleness)
                        self.version += 1
                else:
                    self._params = self.optimizer.step(
                        self._params, payload, rec.staleness)
                    self.version += 1
                if TRACE.enabled:
                    TRACE.span("apply", t_apply, worker=worker,
                               clock=rec.iteration)
            self.metrics.record_push(
                worker, rec.staleness, applied=dec.apply_update,
                credit=dec.credit_used, time=now)
            self._cond.notify_all()
            if not dec.release_now:
                t_wait = TRACE.now() if TRACE.enabled else 0.0
                arrival = self._clock()
                while (not self.stopped
                       and not self.policy.may_release(self.tracker, worker)):
                    self._cond.wait(timeout=0.5)
                waited = self._clock() - arrival
                rec.waited = waited
                self.metrics.record_wait(worker, waited)
                if TRACE.enabled:
                    TRACE.span("gate_wait", t_wait, worker=worker,
                               clock=rec.iteration)
            if TRACE.enabled:
                TRACE.span("push", t_push, worker=worker,
                           clock=rec.iteration,
                           args={"staleness": rec.staleness,
                                 "applied": dec.apply_update,
                                 "credit": dec.credit_used})

    def _apply_packed(self, wire_g: jax.Array, staleness: int) -> None:
        from repro.kernels import ops as kops
        opt = self.optimizer
        scale = 1.0 / (1.0 + staleness) if opt.staleness_damping else 1.0
        self._wire_p, self._wire_m = kops.fused_update(
            self._wire_p, self._wire_m, wire_g,
            lr=opt.lr, beta=opt.momentum, scale=scale)
        self._params = None

    # -- coalescing-window plumbing (see ``CoalesceWindow``) ------------------
    def _get_pm(self):
        return self._wire_p, self._wire_m

    def _install_pm(self, p, m, n: int) -> None:
        self._wire_p, self._wire_m = p, m
        self._params = None
        self.version += n

    def _apply_coalesced(self, wire_g: jax.Array, staleness: int) -> None:
        """Route one packed apply through the coalescing window (the
        mono server is one lock domain = one window).  Called under
        ``self._cond``."""
        opt = self.optimizer
        scale = 1.0 / (1.0 + staleness) if opt.staleness_damping else 1.0
        self._window.submit(wire_g, scale)

    def record_loss(self, step: int, loss: float) -> None:
        """Record (wall_time, applied_update_count, loss).  Keying the
        curve by *applied updates* (server version) lets benchmarks
        compose it with virtual-time update schedules from the
        discrete-event simulator (single-core wall time cannot exhibit
        asynchrony wins — see benchmarks/paper_tables.py)."""
        with self._cond:
            now = self._clock() - self._t0
            self.metrics.record_loss_point(now, self.version, float(loss))

    # -- elastic membership ---------------------------------------------------
    def add_worker(self, worker: int) -> None:
        with self._cond:
            self.tracker.add_worker(worker)
            self.metrics.n_workers = len(self.tracker.workers)

    def remove_worker(self, worker: int) -> None:
        """A departing/failed worker must not stall the barrier: drop it
        from the tracker so gap computations ignore it, then wake waiters."""
        with self._cond:
            self.tracker.remove_worker(worker)
            self.metrics.n_workers = len(self.tracker.workers)
            self._cond.notify_all()

    def stop(self) -> None:
        """Unblock everything (end of training / fault injection)."""
        with self._cond:
            self.stopped = True
            self._cond.notify_all()

    # -- inspection ----------------------------------------------------------
    # (``params``/``snapshot``/``shutdown`` and the single-shard
    # ``*_packed_shard`` defaults come from ParameterServerProtocol.)
    def staleness_profile(self) -> Dict[int, int]:
        with self._cond:
            return self.tracker.staleness_profile()
