"""Parameter-server substrate: discrete-event simulator + threaded runtime
(monolithic in ``server.py``/``simulator.py``, partitioned in ``sharded/``)."""

from repro.ps.metrics import RunMetrics, compare
from repro.ps.server import ParameterServer, ServerOptimizer
from repro.ps.sharded import (
    ShardedParameterServer,
    ShardedPSSimulator,
    ShardPlan,
    build_shard_plan,
    run_sharded_policy,
)
from repro.ps.simulator import (
    PSSimulator,
    constant_intervals,
    jittered_intervals,
    phase_shift_intervals,
    run_policy,
)
from repro.ps.worker import PSWorker, run_cluster

__all__ = [
    "ParameterServer", "ServerOptimizer", "PSWorker", "run_cluster",
    "PSSimulator", "run_policy", "constant_intervals",
    "jittered_intervals", "phase_shift_intervals",
    "RunMetrics", "compare",
    "ShardedParameterServer", "ShardedPSSimulator", "ShardPlan",
    "build_shard_plan", "run_sharded_policy",
]
