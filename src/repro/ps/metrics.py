"""Run metrics for the parameter-server layer.

Everything the paper measures lives here: iteration throughput (pushes/s,
i.e. update frequency on the server), per-worker waiting time, staleness
distribution, and the (time, updates) trajectory used for the
convergence-vs-wall-clock plots (paper Fig. 3/4).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

#: Trajectory lists are decimated (every other point dropped, endpoints
#: kept) whenever they reach this length, so a long run holds at most
#: ~CAP points instead of one tuple per push.
TRAJECTORY_CAP = 8192


def _decimate(lst: List) -> None:
    """Halve a trajectory in place, keeping the first and last points
    (readers depend on ``lst[0]``/``lst[-1]`` being the run endpoints)."""
    last = lst[-1]
    dec = lst[::2]
    if dec[-1] != last:
        dec.append(last)
    lst[:] = dec


@dataclasses.dataclass
class RunMetrics:
    policy: str
    n_workers: int
    total_pushes: int = 0
    applied_updates: int = 0
    dropped_updates: int = 0
    total_time: float = 0.0
    wait_time: Dict[int, float] = dataclasses.field(default_factory=dict)
    pushes: Dict[int, int] = dataclasses.field(default_factory=dict)
    staleness_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    credit_releases: int = 0
    # (virtual/wall time, cumulative applied updates) trajectory
    update_trajectory: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)
    # optional loss trajectory from real training: (time, step, loss)
    loss_trajectory: List[Tuple[float, int, float]] = dataclasses.field(
        default_factory=list)

    # -- recording ---------------------------------------------------------
    def record_push(self, worker: int, staleness: int, *,
                    applied: bool, credit: bool, time: float) -> None:
        self.total_pushes += 1
        self.pushes[worker] = self.pushes.get(worker, 0) + 1
        self.staleness_hist[staleness] = (
            self.staleness_hist.get(staleness, 0) + 1)
        if applied:
            self.applied_updates += 1
        else:
            self.dropped_updates += 1
        if credit:
            self.credit_releases += 1
        self.update_trajectory.append((time, self.applied_updates))
        if len(self.update_trajectory) >= TRAJECTORY_CAP:
            _decimate(self.update_trajectory)
        self.total_time = max(self.total_time, time)

    def record_loss_point(self, time: float, step: int,
                          loss: float) -> None:
        self.loss_trajectory.append((time, step, loss))
        if len(self.loss_trajectory) >= TRAJECTORY_CAP:
            _decimate(self.loss_trajectory)

    def record_wait(self, worker: int, waited: float) -> None:
        self.wait_time[worker] = self.wait_time.get(worker, 0.0) + waited

    # -- summaries ----------------------------------------------------------
    @property
    def total_wait(self) -> float:
        return sum(self.wait_time.values())

    @property
    def throughput(self) -> float:
        """Applied updates per unit time — the paper's iteration throughput."""
        return self.applied_updates / self.total_time if self.total_time else 0.0

    @property
    def max_staleness(self) -> int:
        return max(self.staleness_hist, default=0)

    @property
    def mean_staleness(self) -> float:
        n = sum(self.staleness_hist.values())
        if not n:
            return 0.0
        return sum(s * c for s, c in self.staleness_hist.items()) / n

    def wait_fraction(self) -> float:
        """Fraction of aggregate worker-time spent blocked."""
        denom = self.n_workers * self.total_time
        return self.total_wait / denom if denom else 0.0

    def time_to_updates(self, n: int) -> Optional[float]:
        """Virtual/wall time at which the n-th update was applied (Table I analogue)."""
        for t, u in self.update_trajectory:
            if u >= n:
                return t
        return None

    def time_to_loss(self, target: float) -> Optional[float]:
        """Wall time to first reach loss <= target (paper Table I analogue)."""
        for t, _, loss in self.loss_trajectory:
            if loss <= target:
                return t
        return None

    def summary(self) -> Dict[str, float]:
        return {
            "policy": self.policy,
            "workers": self.n_workers,
            "pushes": self.total_pushes,
            "applied": self.applied_updates,
            "dropped": self.dropped_updates,
            "time": round(self.total_time, 6),
            "throughput": round(self.throughput, 3),
            "total_wait": round(self.total_wait, 6),
            "wait_frac": round(self.wait_fraction(), 4),
            "mean_staleness": round(self.mean_staleness, 3),
            "max_staleness": self.max_staleness,
            "credit_releases": self.credit_releases,
        }


def compare(metrics: List[RunMetrics]) -> str:
    """Fixed-width comparison table for benchmark output."""
    cols = ["policy", "throughput", "total_wait", "wait_frac",
            "mean_staleness", "max_staleness", "applied", "time"]
    rows = [[str(m.summary()[c]) for c in cols] for m in metrics]
    widths = [max(len(c), *(len(r[i]) for r in rows)) for i, c in enumerate(cols)]
    out = ["  ".join(c.ljust(w) for c, w in zip(cols, widths))]
    for r in rows:
        out.append("  ".join(v.ljust(w) for v, w in zip(r, widths)))
    return "\n".join(out)


def hist_percentile(hist: Dict[int, int], q: float) -> float:
    """q-quantile (q in [0,1]) of a value->count histogram.

    Weighted quantile straight off the histogram — O(distinct values),
    never materializing one entry per observation.  Matches
    ``statistics.quantiles(xs, n=100, method='exclusive')`` at the
    percentile index the old list-based implementation used, so results
    are bit-identical to the pre-histogram code path.
    """
    items = sorted((s, c) for s, c in hist.items() if c > 0)
    total = sum(c for _, c in items)
    if total == 0:
        return 0.0
    if total == 1:
        return float(items[0][0])

    def order_stat(k: int) -> int:
        # 0-indexed k-th smallest observation, by cumulative count.
        cum = 0
        for s, c in items:
            cum += c
            if k < cum:
                return s
        return items[-1][0]

    # statistics.quantiles(n=100) exclusive method, at cut point i:
    #   j = clamp(i * (N + 1) // 100, 1, N - 1)
    #   delta = i * (N + 1) - j * 100      (after clamping, so it can
    #                                       leave [0, 100] at the tails)
    #   result = (x[j-1] * (100 - delta) + x[j] * delta) / 100
    i = min(98, max(0, int(q * 100) - 1)) + 1
    m = total + 1
    j = min(max(i * m // 100, 1), total - 1)
    delta = i * m - j * 100
    lo, hi = order_stat(j - 1), order_stat(j)
    return (lo * (100 - delta) + hi * delta) / 100


def staleness_percentile(m: RunMetrics, q: float) -> float:
    """q-quantile of observed staleness (q in [0,1])."""
    return hist_percentile(m.staleness_hist, q)
