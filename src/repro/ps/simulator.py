"""Discrete-event simulator of the parameter-server cluster.

Deterministic virtual-time execution of any ``SyncPolicy`` over a set of
workers with configurable (possibly heterogeneous and time-varying)
iteration intervals.  This is the instrument for the paper's *systems*
claims — waiting time, iteration throughput, staleness bounds — decoupled
from SGD noise:

  * Figure 2's geometry (where should the fastest worker stop?) becomes an
    executable experiment,
  * Table I's ordering (DSSP ≈ ASP ≫ SSP ≫ BSP in heterogeneous clusters)
    is reproduced in virtual time,
  * property tests drive thousands of random speed profiles through every
    policy and assert the invariants (staleness ≤ bound, BSP lockstep,
    DSSP wait ≤ SSP(s_L) wait, ...).

Worker model: worker ``i`` becomes ready to push ``interval_fn(i, k)``
seconds after its k-th release.  The interval covers compute + comms,
matching the paper's definition of *iteration interval* ("time period
between two consecutive updates the server receives from the worker").
"""

from __future__ import annotations

import heapq
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.policies import SyncPolicy
from repro.core.staleness import StalenessTracker
from repro.ps.metrics import RunMetrics

IntervalFn = Callable[[int, int], float]  # (worker, iteration_idx) -> seconds


def constant_intervals(values: Sequence[float]) -> IntervalFn:
    """Homogeneous-per-worker intervals (value per worker)."""
    vals = list(values)

    def fn(worker: int, k: int) -> float:
        return vals[worker]

    return fn


def jittered_intervals(values: Sequence[float], jitter: float,
                       seed: int = 0) -> IntervalFn:
    """Per-worker base interval with multiplicative uniform jitter.

    Deterministic: uses a counter-based hash so (worker, k) always maps to
    the same draw regardless of event order.
    """
    vals = list(values)

    def fn(worker: int, k: int) -> float:
        h = (worker * 1_000_003 + k * 7_919 + seed * 104_729) & 0xFFFFFFFF
        h ^= h >> 16
        h = (h * 0x45D9F3B) & 0xFFFFFFFF
        h ^= h >> 16
        u = h / 0xFFFFFFFF  # [0, 1]
        return vals[worker] * (1.0 + jitter * (2.0 * u - 1.0))

    return fn


def phase_shift_intervals(base: Sequence[float], slow_after: int,
                          factor: float, worker: int = 0) -> IntervalFn:
    """One worker degrades by ``factor`` after ``slow_after`` iterations —
    models the paper's 'unstable environment' future-work scenario and
    exercises the controller's adaptivity."""
    vals = list(base)

    def fn(w: int, k: int) -> float:
        v = vals[w]
        if w == worker and k >= slow_after:
            v *= factor
        return v

    return fn


class PSSimulator:
    """Event-driven PS cluster under a synchronization policy."""

    def __init__(self, policy: SyncPolicy, n_workers: int,
                 interval_fn: IntervalFn):
        self.policy = policy
        self.n = n_workers
        self.interval_fn = interval_fn
        self.tracker = StalenessTracker(range(n_workers))
        self.metrics = RunMetrics(policy=policy.name, n_workers=n_workers)
        self._events: List[Tuple[float, int, int]] = []  # (time, seq, worker)
        self._seq = itertools.count()
        self._blocked: Dict[int, float] = {}  # worker -> arrival time
        self._iters: Dict[int, int] = {w: 0 for w in range(n_workers)}
        self.now = 0.0

    # -- scheduling --------------------------------------------------------
    def _schedule_next(self, worker: int, at: float) -> None:
        k = self._iters[worker]
        self._iters[worker] += 1
        push_at = at + self.interval_fn(worker, k)
        heapq.heappush(self._events, (push_at, next(self._seq), worker))

    def _release(self, worker: int, at: float, waited: float) -> None:
        if waited > 0:
            self.metrics.record_wait(worker, waited)
        self._schedule_next(worker, at)

    # -- main loop ------------------------------------------------------------
    def run(self, max_pushes: Optional[int] = None,
            max_time: Optional[float] = None) -> RunMetrics:
        if max_pushes is None and max_time is None:
            raise ValueError("need a stopping condition")
        for w in range(self.n):
            self._schedule_next(w, 0.0)

        while self._events:
            t, _, w = heapq.heappop(self._events)
            if max_time is not None and t > max_time:
                break
            self.now = t
            rec = self.tracker.record_push(w, t)
            dec = self.policy.on_push(self.tracker, w, t)
            self.metrics.record_push(
                w, rec.staleness, applied=dec.apply_update,
                credit=dec.credit_used, time=t)
            if dec.release_now:
                self._release(w, t, 0.0)
            else:
                self._blocked[w] = t
            # Every push may unblock waiters (Alg. 1 line 17 re-check).
            self._drain_blocked(t)
            if max_pushes is not None and self.metrics.total_pushes >= max_pushes:
                break

        # Workers still blocked at the end contribute their tail wait.
        for w, arrival in self._blocked.items():
            self.metrics.record_wait(w, max(0.0, self.now - arrival))
        self._blocked.clear()
        return self.metrics

    def _drain_blocked(self, t: float) -> None:
        # Iterate to fixpoint: releasing one worker never increases another
        # blocked worker's gap, but BSP-style policies release in groups.
        progressed = True
        while progressed:
            progressed = False
            for w in sorted(self._blocked):
                if self.policy.may_release(self.tracker, w):
                    arrival = self._blocked.pop(w)
                    self._release(w, t, t - arrival)
                    progressed = True


def run_policy(policy: SyncPolicy, intervals: Sequence[float], *,
               max_pushes: int = 2000, jitter: float = 0.0,
               seed: int = 0) -> RunMetrics:
    """Convenience wrapper used by benchmarks and tests."""
    n = len(intervals)
    fn = (constant_intervals(intervals) if jitter == 0.0
          else jittered_intervals(intervals, jitter, seed))
    sim = PSSimulator(policy, n, fn)
    return sim.run(max_pushes=max_pushes)
