"""Sharded parameter server: partitioned weight store + per-shard gating.

See ``plan.py`` for the shard plan format, ``server.py`` for the
threaded runtime and ``simulator.py`` for the virtual-time instrument.
"""

from repro.ps.sharded.plan import (LeafSlice, Shard, ShardPlan,
                                   WireLayout, build_shard_plan)
from repro.ps.sharded.server import ShardedParameterServer
from repro.ps.sharded.simulator import (ShardedPSSimulator,
                                        hot_shard_service,
                                        run_sharded_policy)

__all__ = [
    "LeafSlice", "Shard", "ShardPlan", "WireLayout", "build_shard_plan",
    "ShardedParameterServer",
    "ShardedPSSimulator", "run_sharded_policy", "hot_shard_service",
]
