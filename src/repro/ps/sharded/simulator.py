"""Sharded extension of the discrete-event PS simulator.

``PSSimulator`` models one server; here every worker iteration fans out
into **per-shard service events**: after computing for
``interval_fn(w, k)`` virtual seconds, worker ``w`` visits shards
0..S-1 in order, paying ``shard_service_fn(shard, w)`` service time per
visit (default 0 — pure gating study), and each shard gates the visit
with its OWN stateful policy instance over its OWN
``StalenessTracker``.  The worker starts its next compute interval only
once the LAST shard has released it.

All workers visit shards in the SAME canonical order — with blocking
policies a rotated/random order deadlocks (worker A blocked at shard 0's
barrier while worker B, whose push would release it, is blocked at
shard 1's, circularly).  A total order over shards makes the wait-for
graph acyclic; pushes to distinct shards still overlap in pipeline
fashion.

This turns the paper's Table-I throughput/wait comparisons into a
function of shard count: at S=1 it degenerates to ``PSSimulator``
(identical event order ⇒ identical metrics), at S>1 it answers the
questions the monolithic paper setup could not pose — does per-shard
DSSP keep every shard's staleness within bound?  how much waiting does
skewed shard load (hot shards via ``shard_service_fn``) add per policy?

Metrics: one aggregate ``RunMetrics`` over worker iterations (a "push"
= one completed fan-out; staleness = the max across shards seen that
iteration) plus one per-shard ``RunMetrics`` with exact per-shard
staleness/wait accounting.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.policies import SyncPolicy
from repro.core.staleness import StalenessTracker
from repro.ps.metrics import RunMetrics
from repro.ps.simulator import IntervalFn, constant_intervals

ShardServiceFn = Callable[[int, int], float]  # (shard, worker) -> seconds


class _SimShard:
    def __init__(self, index: int, policy: SyncPolicy, n_workers: int):
        self.index = index
        self.policy = policy
        self.tracker = StalenessTracker(range(n_workers))
        self.metrics = RunMetrics(policy=f"{policy.name}/shard{index}",
                                  n_workers=n_workers)
        self.blocked: Dict[int, float] = {}   # worker -> arrival time


class _WorkerState:
    __slots__ = ("k", "order", "pos", "wait", "stale", "applied", "credit")

    def __init__(self, order: List[int]):
        self.k = 0            # completed compute iterations
        self.order = order    # canonical shard visit order (see module doc)
        self.pos = 0          # index into order for the current fan-out
        self.wait = 0.0       # wait accumulated this fan-out
        self.stale = 0        # max per-shard staleness this fan-out
        self.applied = False
        self.credit = False


class ShardedPSSimulator:
    """Event-driven sharded PS cluster; per-shard gating in virtual time."""

    def __init__(self, policy_factory: Callable[[], SyncPolicy],
                 n_workers: int, n_shards: int, interval_fn: IntervalFn, *,
                 shard_service_fn: Optional[ShardServiceFn] = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.n = n_workers
        self.s = n_shards
        self.interval_fn = interval_fn
        self.service_fn = shard_service_fn or (lambda shard, worker: 0.0)
        self.shards = [_SimShard(j, policy_factory(), n_workers)
                       for j in range(n_shards)]
        self.metrics = RunMetrics(
            policy=f"{self.shards[0].policy.name} xS{n_shards}",
            n_workers=n_workers)
        self._events: List[Tuple[float, int, int]] = []  # (time, seq, worker)
        self._seq = itertools.count()
        self._workers = [_WorkerState(list(range(n_shards)))
                         for _ in range(n_workers)]
        self.now = 0.0

    # -- scheduling ----------------------------------------------------------
    def _schedule_compute(self, worker: int, at: float) -> None:
        ws = self._workers[worker]
        k = ws.k
        ws.k += 1
        ws.pos = 0
        ws.wait = 0.0
        ws.stale = 0
        ws.applied = False
        ws.credit = False
        first = ws.order[0]
        push_at = (at + self.interval_fn(worker, k)
                   + self.service_fn(first, worker))
        heapq.heappush(self._events, (push_at, next(self._seq), worker))

    def _advance(self, worker: int, at: float, waited: float) -> None:
        """Worker released from its current shard: go to the next shard,
        or finish the fan-out and start the next compute interval."""
        ws = self._workers[worker]
        ws.wait += waited
        ws.pos += 1
        if ws.pos < self.s:
            nxt = ws.order[ws.pos]
            heapq.heappush(self._events,
                           (at + self.service_fn(nxt, worker),
                            next(self._seq), worker))
        else:
            if ws.wait > 0:
                self.metrics.record_wait(worker, ws.wait)
            self._schedule_compute(worker, at)

    # -- main loop -------------------------------------------------------------
    def run(self, max_pushes: Optional[int] = None,
            max_time: Optional[float] = None) -> RunMetrics:
        """``max_pushes`` counts completed worker fan-outs (one per
        compute iteration — comparable to ``PSSimulator`` pushes)."""
        if max_pushes is None and max_time is None:
            raise ValueError("need a stopping condition")
        for w in range(self.n):
            self._schedule_compute(w, 0.0)

        while self._events:
            t, _, w = heapq.heappop(self._events)
            if max_time is not None and t > max_time:
                break
            self.now = t
            ws = self._workers[w]
            shard = self.shards[ws.order[ws.pos]]
            rec = shard.tracker.record_push(w, t)
            dec = shard.policy.on_push(shard.tracker, w, t)
            shard.metrics.record_push(w, rec.staleness,
                                      applied=dec.apply_update,
                                      credit=dec.credit_used, time=t)
            ws.stale = max(ws.stale, rec.staleness)
            ws.applied = ws.applied or dec.apply_update
            ws.credit = ws.credit or dec.credit_used
            if ws.pos == self.s - 1:
                # All shards have seen this fan-out: record the aggregate
                # push at ARRIVAL (matching PSSimulator's timing — a
                # blocked worker's push still counts before its wait).
                self.metrics.record_push(w, ws.stale, applied=ws.applied,
                                         credit=ws.credit, time=t)
            if dec.release_now:
                self._advance(w, t, 0.0)
            else:
                shard.blocked[w] = t
            self._drain(shard, t)
            if (max_pushes is not None
                    and self.metrics.total_pushes >= max_pushes):
                break

        # Tail waits of workers still blocked in some shard.
        for shard in self.shards:
            for w, arrival in shard.blocked.items():
                waited = max(0.0, self.now - arrival)
                shard.metrics.record_wait(w, waited)
                self.metrics.record_wait(w, waited)
            shard.blocked.clear()
        return self.metrics

    def _drain(self, shard: _SimShard, t: float) -> None:
        progressed = True
        while progressed:
            progressed = False
            for w in sorted(shard.blocked):
                if shard.policy.may_release(shard.tracker, w):
                    arrival = shard.blocked.pop(w)
                    waited = t - arrival
                    if waited > 0:
                        shard.metrics.record_wait(w, waited)
                    self._advance(w, t, waited)
                    progressed = True

    # -- inspection ------------------------------------------------------------
    def shard_metrics(self) -> List[RunMetrics]:
        return [s.metrics for s in self.shards]

    def max_staleness_per_shard(self) -> List[int]:
        return [s.metrics.max_staleness for s in self.shards]


def run_sharded_policy(policy_factory: Callable[[], SyncPolicy],
                       intervals: Sequence[float], n_shards: int, *,
                       max_pushes: int = 2000,
                       shard_service_fn: Optional[ShardServiceFn] = None,
                       ) -> ShardedPSSimulator:
    """Convenience wrapper mirroring ``repro.ps.simulator.run_policy`` —
    returns the simulator (aggregate in ``.metrics``, per-shard via
    ``.shard_metrics()``)."""
    sim = ShardedPSSimulator(policy_factory, len(intervals), n_shards,
                             constant_intervals(intervals),
                             shard_service_fn=shard_service_fn)
    sim.run(max_pushes=max_pushes)
    return sim


def hot_shard_service(hot_shard: int, hot_seconds: float,
                      base_seconds: float = 0.0) -> ShardServiceFn:
    """Skewed shard load: one shard is slower to service (hot key range /
    oversized embedding slice) — a scenario the monolithic paper setup
    cannot express."""
    def fn(shard: int, worker: int) -> float:
        return hot_seconds if shard == hot_shard else base_seconds

    return fn
