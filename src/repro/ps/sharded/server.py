"""Sharded (partitioned) parameter server — per-shard locks and gating.

The monolithic ``ParameterServer`` serializes *every* push on one lock
and one version counter: the exact single-machine bottleneck the PS
framework exists to avoid.  Here the weight pytree is partitioned by a
``ShardPlan`` into S size-balanced shards, and every shard owns its own

  * lock (condition variable)      — pushes to distinct shards overlap,
  * version counter                — per-shard applied-update count,
  * ``ServerOptimizer`` state      — momentum lives with its slice,
  * ``SyncPolicy`` + ``StalenessTracker`` — per-shard Algorithm-1 gating,
  * ``RunMetrics``                 — per-shard staleness/wait accounting.

Gating modes
------------
``sharded`` (default)  every shard gates independently with its own
    policy instance; a DSSP shard's Algorithm-2 controller reads that
    shard's interval table (table A), so skewed shard load produces
    per-shard credit schedules.  A worker's push returns when the LAST
    shard releases it.
``global``  one policy/tracker gates the worker exactly once per push
    (the monolithic semantics) while the weight store stays partitioned —
    the ablation that isolates lock-granularity wins from gating wins.

Wire compression (``optim/compression.py``) runs per shard with
per-(worker, shard) error-feedback state, emulating worker-side
compression of each shard RPC.

The apply path is pluggable: ``apply_mode='tree'`` steps the shard's
piece list through its ``ServerOptimizer`` (bitwise-identical to the
monolithic server), ``apply_mode='fused'`` keeps params+momentum packed
in one lane-aligned (rows, 512) buffer and folds the whole shard through
a single Pallas ``fused_update`` launch per push.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.policies import Decision, SyncPolicy
from repro.core.staleness import StalenessTracker
from repro.optim.compression import Compressor
from repro.ps.metrics import RunMetrics
from repro.ps.server import ServerOptimizer
from repro.ps.sharded.plan import ShardPlan, build_shard_plan

Params = Any
Grads = Any


class _ShardState:
    """Everything one shard owns.  All mutation under ``self.cond``."""

    def __init__(self, index: int, pieces: List[jax.Array],
                 policy: SyncPolicy, optimizer: ServerOptimizer,
                 workers: Sequence[int], apply_mode: str):
        self.index = index
        self.cond = threading.Condition()
        self.policy = policy
        self.optimizer = optimizer
        self.tracker = StalenessTracker(workers)
        self.metrics = RunMetrics(policy=f"{policy.name}/shard{index}",
                                  n_workers=len(list(workers)))
        self.version = 0
        self.apply_mode = apply_mode
        self.shapes = [p.shape for p in pieces]
        self.dtypes = [p.dtype for p in pieces]
        if apply_mode == "fused":
            # Kernel imports stay local to the fused path so plain
            # `import repro.ps` never pulls in the Pallas kernel stack.
            from repro.kernels.fused_update import pack_shard
            # Params + momentum stay resident in the packed kernel layout;
            # unpacked pieces are a cache rebuilt at most once per version.
            self._packed_p = pack_shard(pieces)
            self._packed_m = jnp.zeros_like(self._packed_p)
            self._pieces: Optional[List[jax.Array]] = list(pieces)
        else:
            self._pieces = list(pieces)

    # -- weight access (call under self.cond) -------------------------------
    def pieces(self) -> List[jax.Array]:
        if self._pieces is None:  # fused mode, invalidated by an apply
            from repro.kernels.fused_update import unpack_shard
            self._pieces = unpack_shard(self._packed_p, self.shapes,
                                        self.dtypes)
        return self._pieces

    def apply(self, grad_pieces: List[jax.Array], staleness: int) -> None:
        if not grad_pieces:
            # Empty shard (more shards than pieces): the gate/version
            # bookkeeping stays uniform, there is just nothing to fold in
            # (a zero-row pallas_call would reject its (8, 512) tile).
            self.version += 1
            return
        if self.apply_mode == "fused":
            from repro.kernels import ops as kops
            from repro.kernels.fused_update import pack_shard
            opt = self.optimizer
            scale = (1.0 / (1.0 + staleness)
                     if opt.staleness_damping else 1.0)
            self._packed_p, self._packed_m = kops.fused_update(
                self._packed_p, self._packed_m, pack_shard(grad_pieces),
                lr=opt.lr, beta=opt.momentum, scale=scale)
            self._pieces = None
        else:
            self._pieces = self.optimizer.step(self.pieces(), grad_pieces,
                                               staleness)
        self.version += 1


class ShardedParameterServer:
    """Partitioned weight store + per-shard Algorithm-1 gating.

    Duck-compatible with ``ParameterServer`` for workers (``pull``,
    ``push``, ``record_loss``, ``add_worker``, ``remove_worker``,
    ``stop``, ``stopped``, ``params``, ``metrics``), so ``PSWorker`` and
    ``run_cluster`` drive it unchanged.
    """

    def __init__(self, params: Params, policy_factory: Callable[[], SyncPolicy],
                 optimizer_factory: Callable[[], ServerOptimizer],
                 n_workers: int, n_shards: int, *,
                 split_oversized: bool = True,
                 gating: str = "sharded",
                 apply_mode: str = "tree",
                 compressor: Optional[Compressor] = None,
                 clock: Callable[[], float] = time.monotonic):
        if gating not in ("sharded", "global"):
            raise ValueError(f"unknown gating mode {gating!r}")
        if apply_mode not in ("tree", "fused"):
            raise ValueError(f"unknown apply mode {apply_mode!r}")
        self.plan: ShardPlan = build_shard_plan(
            params, n_shards, split_oversized=split_oversized)
        self.gating = gating
        self.n_shards = n_shards
        workers = range(n_workers)
        pieces = self.plan.split(params)
        self.shards: List[_ShardState] = [
            _ShardState(j, pieces[j], policy_factory(), optimizer_factory(),
                        workers, apply_mode)
            for j in range(n_shards)]
        if gating == "global":
            self._gate_policy = policy_factory()
            self._gate_tracker = StalenessTracker(workers)
            self._gate_cond = threading.Condition()
        self.metrics = RunMetrics(
            policy=f"{self.shards[0].policy.name} xS{n_shards}[{gating}]",
            n_workers=n_workers)
        self._metrics_lock = threading.Lock()
        self.compressor = (compressor
                           if compressor is not None
                           and compressor.name != "none" else None)
        self._err: Dict[int, List[Any]] = {}   # worker -> per-shard err state
        self._clock = clock
        self._t0 = clock()
        self.stopped = False

    # -- worker API ----------------------------------------------------------
    def pull(self, worker: int) -> Params:
        """Reassemble the full pytree from per-shard snapshots.

        Each shard is snapshotted under its OWN lock; shards mutated
        concurrently with the pull may differ in version — exactly the
        per-shard consistency a partitioned PS offers (each shard's slice
        is internally consistent; cross-shard skew is bounded by the
        gating policies).
        """
        snaps = []
        for st in self.shards:
            with st.cond:
                snaps.append(list(st.pieces()))
        return self.plan.assemble(snaps)

    def push(self, worker: int, grads: Grads) -> None:
        """Split grads by the plan and push shard-by-shard.

        Every worker visits shards in the SAME canonical order 0..S-1:
        with blocking policies a per-worker rotated order deadlocks
        (worker A blocked at shard 0's barrier while worker B, whose push
        would release it, is blocked at shard 1's — a circular wait).  A
        total order keeps the wait-for graph acyclic while pushes to
        distinct shards still overlap in pipeline fashion.  Blocks until
        every shard's policy has released the worker (the ``global`` mode
        gates once, after all applies).
        """
        pieces_per_shard = self.plan.split(grads)
        if self.compressor is not None:
            pieces_per_shard = self._compress(worker, pieces_per_shard)
        order = range(self.n_shards)
        now = self._clock() - self._t0
        # Global mode: the gate decides FIRST (monolithic order — decide,
        # apply, then maybe block), and its decision governs every shard's
        # apply so update-dropping policies (backup workers) and credit
        # accounting match the monolithic server exactly.
        gate_dec = gate_stale = None
        if self.gating == "global":
            gate_dec, gate_stale = self._gate_decide(worker)
        max_stale, any_applied, any_credit = 0, False, False
        total_wait = 0.0
        for j in order:
            stale, applied, credit, waited = self._push_shard(
                j, worker, pieces_per_shard[j], gate_dec, gate_stale)
            max_stale = max(max_stale, stale)
            any_applied = any_applied or applied
            any_credit = any_credit or credit
            total_wait += waited
        if gate_dec is not None:
            total_wait += self._gate_wait(worker, gate_dec)
            max_stale = gate_stale
        with self._metrics_lock:
            self.metrics.record_push(worker, max_stale, applied=any_applied,
                                     credit=any_credit, time=now)
            if total_wait > 0:
                self.metrics.record_wait(worker, total_wait)

    def _push_shard(self, j: int, worker: int, grad_pieces: List[jax.Array],
                    gate_dec: Optional[Decision] = None,
                    gate_stale: Optional[int] = None):
        st = self.shards[j]
        with st.cond:
            now = self._clock() - self._t0
            rec = st.tracker.record_push(worker, now)
            if gate_dec is None:
                dec = st.policy.on_push(st.tracker, worker, now)
                apply_staleness = rec.staleness
            else:
                # Global gating: apply iff the gate said so, with the
                # gate's staleness (what the monolithic optimizer saw);
                # release decision belongs to the gate, not the shard.
                dec = Decision(apply_update=gate_dec.apply_update,
                               release_now=True,
                               credit_used=gate_dec.credit_used)
                apply_staleness = gate_stale
            if dec.apply_update:
                st.apply(grad_pieces, apply_staleness)
            st.metrics.record_push(worker, rec.staleness,
                                   applied=dec.apply_update,
                                   credit=dec.credit_used, time=now)
            st.cond.notify_all()
            waited = 0.0
            if not dec.release_now:
                arrival = self._clock()
                while (not self.stopped
                       and not st.policy.may_release(st.tracker, worker)):
                    st.cond.wait(timeout=0.5)
                waited = self._clock() - arrival
                rec.waited = waited
                st.metrics.record_wait(worker, waited)
            return rec.staleness, dec.apply_update, dec.credit_used, waited

    def _gate_decide(self, worker: int):
        """Global-gate bookkeeping + decision (no blocking yet)."""
        with self._gate_cond:
            now = self._clock() - self._t0
            rec = self._gate_tracker.record_push(worker, now)
            dec = self._gate_policy.on_push(self._gate_tracker, worker, now)
            self._gate_cond.notify_all()
            return dec, rec.staleness

    def _gate_wait(self, worker: int, dec: Decision) -> float:
        if dec.release_now:
            return 0.0
        with self._gate_cond:
            arrival = self._clock()
            while (not self.stopped
                   and not self._gate_policy.may_release(
                       self._gate_tracker, worker)):
                self._gate_cond.wait(timeout=0.5)
            return self._clock() - arrival

    def _compress(self, worker: int,
                  pieces_per_shard: List[List[jax.Array]]):
        err = self._err.get(worker)
        if err is None:
            err = [self.compressor.init_error(p) for p in pieces_per_shard]
        out = []
        for j, pieces in enumerate(pieces_per_shard):
            compressed, err[j] = self.compressor.apply(pieces, err[j])
            out.append(compressed)
        self._err[worker] = err
        return out

    def record_loss(self, step: int, loss: float) -> None:
        with self._metrics_lock:
            now = self._clock() - self._t0
            self.metrics.loss_trajectory.append((now, self.version,
                                                 float(loss)))

    # -- elastic membership ----------------------------------------------------
    def add_worker(self, worker: int) -> None:
        for st in self.shards:
            with st.cond:
                st.tracker.add_worker(worker)
                st.metrics.n_workers = len(st.tracker.workers)
                st.cond.notify_all()
        if self.gating == "global":
            with self._gate_cond:
                self._gate_tracker.add_worker(worker)
                self._gate_cond.notify_all()
        with self._metrics_lock:
            self.metrics.n_workers = len(self.shards[0].tracker.workers)
        self._err.pop(worker, None)

    def remove_worker(self, worker: int) -> None:
        """Departure must not stall ANY shard's barrier: drop the worker
        from every shard tracker, waking that shard's waiters."""
        for st in self.shards:
            with st.cond:
                st.tracker.remove_worker(worker)
                st.metrics.n_workers = len(st.tracker.workers)
                st.cond.notify_all()
        if self.gating == "global":
            with self._gate_cond:
                self._gate_tracker.remove_worker(worker)
                self._gate_cond.notify_all()
        with self._metrics_lock:
            self.metrics.n_workers = len(self.shards[0].tracker.workers)
        self._err.pop(worker, None)

    def stop(self) -> None:
        self.stopped = True
        for st in self.shards:
            with st.cond:
                st.cond.notify_all()
        if self.gating == "global":
            with self._gate_cond:
                self._gate_cond.notify_all()

    # -- inspection ------------------------------------------------------------
    @property
    def params(self) -> Params:
        return self.pull(-1)

    @property
    def version(self) -> int:
        """Total applied shard-updates.  At S=1 this equals the monolithic
        server's version (one applied update per released push)."""
        return sum(st.version for st in self.shards)

    def shard_versions(self) -> List[int]:
        return [st.version for st in self.shards]

    def staleness_profile(self) -> Dict[int, Dict[int, int]]:
        """shard -> worker -> current gap."""
        out = {}
        for st in self.shards:
            with st.cond:
                out[st.index] = st.tracker.staleness_profile()
        return out

    def shard_metrics(self) -> List[RunMetrics]:
        return [st.metrics for st in self.shards]
