"""Sharded (partitioned) parameter server — per-shard locks and gating.

The monolithic ``ParameterServer`` serializes *every* push on one lock
and one version counter: the exact single-machine bottleneck the PS
framework exists to avoid.  Here the weight pytree is partitioned by a
``ShardPlan`` into S size-balanced shards, and every shard owns its own

  * lock (condition variable)      — pushes to distinct shards overlap,
  * version counter                — per-shard applied-update count,
  * ``ServerOptimizer`` state      — momentum lives with its slice,
  * ``SyncPolicy`` + ``StalenessTracker`` — per-shard Algorithm-1 gating,
  * ``RunMetrics``                 — per-shard staleness/wait accounting.

Gating modes
------------
``sharded`` (default)  every shard gates independently with its own
    policy instance; a DSSP shard's Algorithm-2 controller reads that
    shard's interval table (table A), so skewed shard load produces
    per-shard credit schedules.  A worker's push returns when the LAST
    shard releases it.
``global``  one policy/tracker gates the worker exactly once per push
    (the monolithic semantics) while the weight store stays partitioned —
    the ablation that isolates lock-granularity wins from gating wins.

Wire compression (``optim/compression.py``) runs per shard with
per-(worker, shard) error-feedback state, emulating worker-side
compression of each shard RPC.

The apply path is pluggable: ``apply_mode='tree'`` steps the shard's
piece list through its ``ServerOptimizer`` (bitwise-identical to the
monolithic server), ``apply_mode='fused'`` keeps params+momentum packed
in one lane-aligned (rows, 512) buffer and folds the whole shard through
a single Pallas ``fused_update`` launch per push.

Packed wire format (the zero-repack hot path)
---------------------------------------------
``push``/``pull`` speak the *tree* wire format: per-leaf arrays, split
and reassembled on every hop.  ``push_packed``/``pull_packed`` speak the
plan's packed wire format instead — the worker packs its gradients once
(inside its jitted step) and every later hop is layout-preserving:

  * ``push_packed`` slices the incoming wire buffer into per-shard
    row-range *views* (``ShardPlan.shard_wire``) — zero host-side
    per-leaf concatenations on the server, asserted by the
    ``repro.perfcount`` probes,
  * each shard folds its region straight through ONE ``fused_update``
    launch (no ``pack_shard`` per push), plus at most one fused
    compression launch (``wire_compression=``) with per-(worker, shard)
    error-feedback buffers kept in wire layout,
  * ``pull_packed`` serves a version-keyed packed snapshot: per-shard
    buffers are reference-grabbed under their own locks, the full wire
    buffer is concatenated OUTSIDE any lock and cached until some shard
    version moves.

Tree-format ``pull`` in fused mode also rebuilds its per-shard piece
cache outside the shard lock, so a pull after an apply never stalls
concurrent pushes to that shard while it unpacks.

Coalesced apply + version-delta pulls (work ∝ rounds + change)
--------------------------------------------------------------
With W workers the paths above still do O(W) kernel launches per round
per shard and ship the full snapshot on every pull.  Two knobs make
server work scale with *rounds and changed state* instead:

  * ``coalesce=K`` arms a bounded micro-batching window per shard:
    contributions that arrive while a flush is in flight (or within a
    short linger, ``coalesce_wait``) are drained together through ONE
    ``fused_update_batched`` launch — an in-kernel sequential fold, so
    numerics match the uncoalesced path (bitwise for f32 state and for
    any window of one) while launches per round drop from S x W toward
    S.  The sync policy still sees, decides and releases every
    contributing worker individually: BSP/SSP/DSSP semantics are
    untouched.
  * ``pull_delta(worker, versions)`` returns only the shards whose
    version moved past the worker's last-seen vector (full-snapshot
    fallback on a vector mismatch), so steady-state pull bytes are
    proportional to what actually changed.

Live reshard (S -> S')
----------------------
``reshard(n_shards)`` migrates the packed parameter+momentum regions
into a new plan WITHOUT stopping training (protocol + migration map in
``repro.ft.reshard``).  Old shards are retired one at a time under
their own locks (the only per-shard pause, traced as
``reshard_shard``); pushes that land on a retired shard PARK their
packed region and are replayed through the migration map after the
atomic ``(plan, shards, n_shards)`` swap — applied exactly once,
accounted in ``WIRE.reshard_parked``/``reshard_replayed``.  Each swap
bumps ``reshard_epoch``; stale-epoch pushes (clients that packed
against the old layout) are translated through the retained migration
maps, and delta pulls carry the epoch so clients force the full-pull
fallback and rebuild.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro._compat import warn_legacy
from repro.api.protocol import DeltaPull, ParameterServerProtocol
from repro.core.policies import Decision, SyncPolicy
from repro.core.staleness import StalenessTracker
from repro.obs.trace import TRACE
from repro.optim.compression import Compressor
from repro.perfcount import WIRE
from repro.ps.metrics import RunMetrics
from repro.ps.server import (DEFAULT_COALESCE_WAIT_S, CoalesceWindow,
                             ServerOptimizer)
from repro.ps.sharded.plan import ShardPlan, build_shard_plan
from repro.wireformat import WIRE_LANES

Params = Any
Grads = Any


class _ShardState:
    """Everything one shard owns.  All mutation under ``self.cond``."""

    def __init__(self, index: int, plan: ShardPlan,
                 pieces: List[jax.Array],
                 policy: SyncPolicy, optimizer: ServerOptimizer,
                 workers: Sequence[int], apply_mode: str):
        self.index = index
        self.plan = plan
        self.cond = threading.Condition()
        self.policy = policy
        self.optimizer = optimizer
        self.tracker = StalenessTracker(workers)
        self.metrics = RunMetrics(policy=f"{policy.name}/shard{index}",
                                  n_workers=len(list(workers)))
        self.version = 0
        self.apply_mode = apply_mode
        #: set by the server when coalescing is armed (fused mode):
        #: the shard's ``CoalesceWindow`` over its packed buffers.
        self.window = None
        #: live-reshard state (see ``ShardedParameterServer.reshard``):
        #: a retired shard parks incoming applies for replay; an
        #: abandoned shard releases its barrier waiters (its peers now
        #: push to the new shards).
        self.retired = False
        self.abandoned = False
        self.parked: List[Any] = []   # (packed region, staleness) pairs
        if apply_mode == "fused":
            # Params + momentum stay resident in the plan's wire layout
            # (8-row-aligned (rows, 512) region), so an incoming packed
            # push folds in directly with zero re-packing; unpacked
            # pieces are a cache rebuilt at most once per version —
            # OUTSIDE the shard lock (see ``_shard_snapshot``).
            self._packed_p = plan.pack_shard_pieces(pieces, index)
            self._packed_m = jnp.zeros_like(self._packed_p)
            self._pieces: Optional[List[jax.Array]] = list(pieces)
        else:
            self._pieces = list(pieces)

    @classmethod
    def from_packed(cls, index: int, plan: ShardPlan,
                    packed_p: jax.Array, packed_m: jax.Array, version: int,
                    policy: SyncPolicy, optimizer: ServerOptimizer,
                    workers: Sequence[int]) -> "_ShardState":
        """A shard state born from migrated packed buffers (fused mode
        only): what a live reshard installs — params AND momentum carry
        over bitwise, the version is the redistributed share of the old
        sum."""
        st = cls.__new__(cls)
        st.index = index
        st.plan = plan
        st.cond = threading.Condition()
        st.policy = policy
        st.optimizer = optimizer
        st.tracker = StalenessTracker(workers)
        st.metrics = RunMetrics(policy=f"{policy.name}/shard{index}",
                                n_workers=len(list(workers)))
        st.version = int(version)
        st.apply_mode = "fused"
        st.window = None
        st.retired = False
        st.abandoned = False
        st.parked = []
        st._packed_p = packed_p
        st._packed_m = packed_m
        st._pieces = None
        return st

    # -- weight access (call under self.cond) -------------------------------
    def pieces(self) -> List[jax.Array]:
        if self._pieces is None:  # fused mode, invalidated by an apply
            self._pieces = self.plan.shard_pieces_from_wire(
                self._packed_p, self.index)
        return self._pieces

    def apply(self, grad_pieces: List[jax.Array], staleness: int) -> None:
        """Tree-wire apply: one piece list, optimizer step or pack+fold."""
        if not grad_pieces:
            # Empty shard (more shards than pieces): the gate/version
            # bookkeeping stays uniform, there is just nothing to fold in
            # (a zero-row pallas_call would reject its (8, 512) tile).
            self.version += 1
            return
        if self.apply_mode == "fused":
            self.apply_packed(
                self.plan.pack_shard_pieces(grad_pieces, self.index),
                staleness)
        else:
            self._pieces = self.optimizer.step(self.pieces(), grad_pieces,
                                               staleness)
            self.version += 1

    def apply_packed(self, wire_g: jax.Array, staleness: int) -> None:
        """Packed-wire apply: fold the shard's (rows, 512) gradient region
        straight through one ``fused_update`` launch — no per-leaf work.
        Fused mode only (``push_packed`` guards at the server boundary)."""
        if wire_g.shape[0] == 0:      # empty shard
            self.version += 1
            return
        # Kernel imports stay local to the fused path so plain
        # `import repro.ps` never pulls in the Pallas kernel stack.
        from repro.kernels import ops as kops
        opt = self.optimizer
        scale = (1.0 / (1.0 + staleness)
                 if opt.staleness_damping else 1.0)
        self._packed_p, self._packed_m = kops.fused_update(
            self._packed_p, self._packed_m, wire_g,
            lr=opt.lr, beta=opt.momentum, scale=scale)
        self._pieces = None
        self.version += 1


class ShardedParameterServer(ParameterServerProtocol):
    """Partitioned weight store + per-shard Algorithm-1 gating.

    Implements ``repro.api.protocol.ParameterServerProtocol`` — the
    same surface as the monolithic ``ParameterServer`` (plus the
    overridden per-shard variants), so workers, endpoints and sessions
    drive either server without a type branch.
    """

    def __init__(self, params: Params, policy_factory: Callable[[], SyncPolicy],
                 optimizer_factory: Callable[[], ServerOptimizer],
                 n_workers: int, n_shards: int, *,
                 split_oversized: bool = True,
                 gating: str = "sharded",
                 apply_mode: str = "tree",
                 compressor: Optional[Compressor] = None,
                 wire_compression: Optional[str] = None,
                 topk_fraction: float = 0.05,
                 coalesce: int = 1,
                 coalesce_wait: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        warn_legacy("ShardedParameterServer",
                    "repro.api.build_session(RunSpec(ps=ServerSpec("
                    "kind='sharded', ...)))")
        if gating not in ("sharded", "global"):
            raise ValueError(f"unknown gating mode {gating!r}")
        if apply_mode not in ("tree", "fused"):
            raise ValueError(f"unknown apply mode {apply_mode!r}")
        if wire_compression not in (None, "none", "", "int8", "topk"):
            raise ValueError(
                f"unknown wire compression {wire_compression!r}")
        if coalesce < 1:
            raise ValueError(f"coalesce window must be >= 1, got {coalesce}")
        if coalesce > 1 and apply_mode != "fused":
            raise ValueError("coalesce > 1 batches packed applies; it "
                             "requires apply_mode='fused'")
        self.coalesce = coalesce
        self.coalesce_wait = (coalesce_wait if coalesce_wait is not None
                              else (DEFAULT_COALESCE_WAIT_S
                                    if coalesce > 1 else 0.0))
        self.plan: ShardPlan = build_shard_plan(
            params, n_shards, split_oversized=split_oversized)
        self.gating = gating
        self.n_shards = n_shards
        self.apply_mode = apply_mode
        self._split_oversized = split_oversized
        # Factories are kept so a live reshard can mint policies and
        # optimizer state for the new shard set.
        self._policy_factory = policy_factory
        self._optimizer_factory = optimizer_factory
        # Live-reshard state: the epoch counts completed migrations and
        # rides HELLO/SUB/DELTA replies; ``_reshard_cond`` makes
        # ``(plan, shards, n_shards, epoch)`` reads/swaps atomic and
        # tracks in-flight pushes per epoch so parked regions are
        # replayed only once nothing can still append to them.
        self.reshard_epoch = 0
        self._reshard_lock = threading.Lock()     # one migration at a time
        self._reshard_cond = threading.Condition()
        self._inflight: Dict[int, int] = {}       # epoch -> active pushes
        self._retired_plans: Dict[int, ShardPlan] = {}
        self._migrations: Dict[int, Any] = {}     # epoch e -> map e -> e+1
        workers = range(n_workers)
        pieces = self.plan.split(params)
        self.shards: List[_ShardState] = [
            _ShardState(j, self.plan, pieces[j], policy_factory(),
                        optimizer_factory(), workers, apply_mode)
            for j in range(n_shards)]
        if apply_mode == "fused":
            for st in self.shards:
                st.window = self._make_window(st)
        if gating == "global":
            self._gate_policy = policy_factory()
            self._gate_tracker = StalenessTracker(workers)
            self._gate_cond = threading.Condition()
        self.metrics = RunMetrics(
            policy=f"{self.shards[0].policy.name} xS{n_shards}[{gating}]",
            n_workers=n_workers)
        self._metrics_lock = threading.Lock()
        self.compressor = (compressor
                           if compressor is not None
                           and compressor.name != "none" else None)
        self._err: Dict[int, List[Any]] = {}   # worker -> per-shard err state
        # Packed-path fused wire compression: per-(worker, shard) f32
        # error-feedback buffers, kept in wire layout.
        from repro.optim.compression import make_packed_compressor
        self.wire_compression = make_packed_compressor(
            wire_compression, fraction=topk_fraction)
        self._wire_err: Dict[int, Dict[int, jax.Array]] = {}
        # Version-keyed packed snapshot cache for ``pull_packed``.
        self._snap_lock = threading.Lock()
        self._snap_key: Optional[tuple] = None
        self._snap_wire: Optional[jax.Array] = None
        self._clock = clock
        self._t0 = clock()
        self.stopped = False

    # -- worker API ----------------------------------------------------------
    def _plan_state(self):
        """Mutually-consistent ``(plan, shards, epoch)``: a live reshard
        swaps all three under ``_reshard_cond``, so readers that touch
        more than one must grab them together."""
        with self._reshard_cond:
            return self.plan, self.shards, self.reshard_epoch

    def _plan_for_epoch(self, epoch: Optional[int]):
        """The plan a push was packed against.  ``None`` / the current
        epoch -> the live plan; an older epoch -> the retired plan kept
        for stale-push translation (raises once evicted — the client
        must re-pull, a retryable condition)."""
        with self._reshard_cond:
            cur = self.reshard_epoch
            if epoch is None or int(epoch) == cur:
                return self.plan, cur
            plan = self._retired_plans.get(int(epoch))
        if plan is None:
            raise ValueError(
                f"unknown reshard epoch {epoch} (server at {cur}); "
                "re-pull to resync")
        return plan, int(epoch)

    def _shard_snapshot(self, st: _ShardState) -> List[jax.Array]:
        """One shard's piece list, unpacking OUTSIDE the shard lock.

        In fused mode an apply invalidates the piece cache; rebuilding it
        while holding ``st.cond`` would stall every concurrent push to
        that shard for the full unpack.  Instead: grab the (immutable)
        packed buffer + version under the lock, unpack unlocked, and
        install the cache only if the shard has not moved meanwhile.
        """
        with st.cond:
            if st._pieces is not None:
                return list(st._pieces)
            packed, version = st._packed_p, st.version
        pieces = self.plan.shard_pieces_from_wire(packed, st.index)
        with st.cond:
            if st.version == version and st._pieces is None:
                st._pieces = list(pieces)
        return pieces

    def pull(self, worker: int) -> Params:
        """Reassemble the full pytree from per-shard snapshots.

        Each shard is snapshotted under its OWN lock; shards mutated
        concurrently with the pull may differ in version — exactly the
        per-shard consistency a partitioned PS offers (each shard's slice
        is internally consistent; cross-shard skew is bounded by the
        gating policies).
        """
        t0 = TRACE.now() if TRACE.enabled else 0.0
        plan, shards, _ = self._plan_state()
        params = plan.assemble(
            [self._shard_snapshot(st) for st in shards])
        if TRACE.enabled:
            TRACE.span("pull", t0, worker=worker)
        return params

    def pull_packed(self, worker: int = -1) -> jax.Array:
        """Full (total_rows, 512) wire snapshot of the parameters.

        Per-shard packed buffers are reference-grabbed under their own
        locks (jax arrays are immutable, so a reference IS a snapshot);
        the concatenation into one wire buffer happens OUTSIDE any shard
        lock and is cached keyed by the shard-version vector, so pulls
        between applies are a dictionary hit.
        """
        if self.apply_mode != "fused":
            raise ValueError("pull_packed requires apply_mode='fused' "
                             "(tree mode has no resident packed store)")
        t0 = TRACE.now() if TRACE.enabled else 0.0
        _, shards, epoch = self._plan_state()
        snaps, versions = [], []
        for st in shards:
            with st.cond:
                snaps.append(st._packed_p)
                versions.append(st.version)
        # The cache key leads with the reshard epoch: version vectors
        # from different epochs have different arity and are not
        # comparable — a newer epoch always wins.
        key = (epoch,) + tuple(versions)
        with self._snap_lock:
            if self._snap_key == key:
                wire = self._snap_wire
                if TRACE.enabled:
                    TRACE.span("pull", t0, worker=worker,
                               args={"packed": True, "cached": True})
                return wire
        bufs = [b for b in snaps if b.shape[0]]
        wire = bufs[0] if len(bufs) == 1 else jnp.concatenate(bufs)
        with self._snap_lock:
            # A slower concurrent pull may finish its concat AFTER a
            # fresher one: install only if this snapshot DOMINATES the
            # cached one (component-wise >=, somewhere >).  The old
            # any-newer guard let two pulls that interleaved their
            # per-shard grabs replace a cache entry with one that was
            # OLDER on some shard — never inconsistent (key and wire
            # install as a pair), but non-monotone.  The concurrency
            # regression test hammers push+pull and asserts the cached
            # key always matches the cached bytes and never regresses.
            cached = self._snap_key
            if cached is None or key[0] > cached[0] or (
                    key[0] == cached[0]
                    and all(n >= c for n, c in zip(key[1:], cached[1:]))
                    and any(n > c for n, c in zip(key[1:], cached[1:]))):
                self._snap_key, self._snap_wire = key, wire
        if TRACE.enabled:
            TRACE.span("pull", t0, worker=worker,
                       args={"packed": True, "cached": False})
        return wire

    def pull_packed_shard(self, shard: int, worker: int = -1) -> jax.Array:
        """One shard's resident (rows, 512) region — a reference IS a
        consistent snapshot (jax arrays are immutable).  The per-shard
        granularity the transport endpoints route on."""
        if self.apply_mode != "fused":
            raise ValueError("pull_packed_shard requires apply_mode='fused' "
                             "(tree mode has no resident packed store)")
        _, shards, _ = self._plan_state()
        st = shards[shard]
        with st.cond:
            return st._packed_p

    def pull_delta(self, worker: int,
                   versions: Optional[Sequence[int]]) -> DeltaPull:
        """Version-delta pull: ship only the shards that advanced.

        ``versions`` is the per-shard version vector the worker saw on
        its LAST pull; the reply carries the regions of shards whose
        version moved (each region reference-grabbed with its version
        under that shard's lock — the same per-shard consistency as
        ``pull_packed``) plus the fresh vector.  A vector of the wrong
        arity, or one claiming versions the server has not reached,
        cannot be diffed against — the reply falls back to a full
        snapshot (every non-empty shard, ``full=True``).  Bytes shipped
        and bytes avoided are accounted in ``repro.perfcount.WIRE``.
        """
        if self.apply_mode != "fused":
            raise ValueError("pull_delta requires apply_mode='fused' "
                             "(tree mode has no resident packed store)")
        t0 = TRACE.now() if TRACE.enabled else 0.0
        plan, shards, epoch = self._plan_state()
        n_shards = len(shards)
        snaps, cur = [], []
        for st in shards:
            with st.cond:
                snaps.append(st._packed_p)
                cur.append(st.version)
        cur_t = tuple(cur)
        layout = plan.wire_layout()
        itemsize = jnp.dtype(layout.dtype).itemsize
        full_bytes = layout.total_rows * WIRE_LANES * itemsize
        # An arity mismatch is exactly what a client sees after a live
        # reshard: its vector is from the old epoch and cannot be
        # diffed — the full-snapshot fallback IS the resync.
        mismatch = (versions is None or len(versions) != n_shards
                    or any(int(v) > c for v, c in zip(versions, cur)))
        if mismatch:
            changed = [j for j in range(n_shards)
                       if snaps[j].shape[0]]
        else:
            changed = [j for j, (v, c) in enumerate(zip(versions, cur))
                       if int(v) != c and snaps[j].shape[0]]
        regions = tuple(snaps[j] for j in changed)
        delta_bytes = sum(int(r.shape[0]) for r in regions) \
            * WIRE_LANES * itemsize
        WIRE.delta_bytes_tx += delta_bytes
        if not mismatch:
            WIRE.full_pull_bytes_avoided += full_bytes - delta_bytes
        if TRACE.enabled:
            TRACE.span("pull_delta", t0, worker=worker,
                       args={"shards": len(changed), "bytes": delta_bytes,
                             "full": mismatch})
        return DeltaPull(versions=cur_t, shards=tuple(changed),
                         regions=regions, full=mismatch, epoch=epoch)

    def push_packed_shard(self, worker: int, shard: int, buf) -> None:
        """Single-shard packed push: the unit of per-shard endpoint
        routing (``repro.transport``), where different shards of this
        server live behind different endpoints.

        Gating/apply semantics are the sharded ones — this shard's
        policy gates the worker independently.  ``gating='global'`` is
        rejected: the global gate's decision spans all shards of one
        logical push, which no longer exists once shards are routed to
        different endpoints.

        Accounting is per shard ONLY (``shard_metrics()``): one logical
        gradient routed across S endpoints is S of these calls, and
        folding each into the aggregate ``self.metrics`` would inflate
        ``total_pushes``/staleness S-fold versus the same gradient
        pushed through ``push_packed`` (which records the aggregate
        once, max-staleness folded).
        """
        if self.apply_mode != "fused":
            raise ValueError("push_packed_shard requires apply_mode='fused' "
                             "(tree mode has no resident packed store)")
        if self.gating == "global":
            raise ValueError(
                "per-shard routed pushes require gating='sharded' (the "
                "global gate must see one push spanning all shards)")
        with self._reshard_cond:
            plan, shards, epoch = self.plan, self.shards, self.reshard_epoch
            self._inflight[epoch] = self._inflight.get(epoch, 0) + 1
        try:
            layout = plan.wire_layout()
            if buf.shape != (layout.shard_rows[shard], WIRE_LANES):
                raise ValueError(
                    f"shard {shard}: buffer {buf.shape} does not match "
                    f"layout ({layout.shard_rows[shard]}, {WIRE_LANES})")
            if self.wire_compression is not None:
                buf = self._compress_packed_one(worker, shard, buf)
            self._push_shard(shards[shard], worker, buf, packed=True)
        finally:
            with self._reshard_cond:
                self._inflight[epoch] -= 1
                self._reshard_cond.notify_all()

    def push(self, worker: int, grads: Grads) -> None:
        """Split grads by the plan and push shard-by-shard.

        Every worker visits shards in the SAME canonical order 0..S-1:
        with blocking policies a per-worker rotated order deadlocks
        (worker A blocked at shard 0's barrier while worker B, whose push
        would release it, is blocked at shard 1's — a circular wait).  A
        total order keeps the wait-for graph acyclic while pushes to
        distinct shards still overlap in pipeline fashion.  Blocks until
        every shard's policy has released the worker (the ``global`` mode
        gates once, after all applies).
        """
        plan, _, epoch = self._plan_state()
        pieces_per_shard = plan.split(grads)
        if self.compressor is not None:
            pieces_per_shard = self._compress(worker, pieces_per_shard)
        self._push_payloads(worker, pieces_per_shard, packed=False,
                            epoch=epoch)

    def push_packed(self, worker: int, wire, epoch: Optional[int] = None
                    ) -> None:
        """Packed-wire push: the zero-repack hot path.

        ``wire`` is either the full (total_rows, 512) buffer (the worker
        packed once in its jitted step) or a list of per-shard regions.
        The server only takes row-range VIEWS — no per-leaf concatenate,
        no ``pack_shard`` — and each shard folds its region through one
        ``fused_update`` launch (plus one fused-compression launch when
        ``wire_compression`` is set).  Gating/metrics semantics are
        identical to ``push``.

        ``epoch`` is the reshard epoch the pusher packed against
        (transports carry it on the frame).  A stale epoch means the
        layout changed under the client: the push is validated against
        the RETIRED plan and translated through the migration map, so
        nothing a lagging client sent is lost.  ``None`` means "the
        layout this buffer matches" — inferred for in-heap callers that
        hold a plan reference rather than an epoch.
        """
        if self.apply_mode != "fused":
            raise ValueError("push_packed requires apply_mode='fused' "
                             "(tree mode has no resident packed store)")
        if epoch is None and not isinstance(wire, (list, tuple)):
            epoch = self._infer_epoch(int(wire.shape[0]))
        plan, epoch = self._plan_for_epoch(epoch)
        layout = plan.wire_layout()
        if isinstance(wire, (list, tuple)):
            shard_bufs = list(wire)
            if len(shard_bufs) != plan.n_shards:
                raise ValueError(f"got {len(shard_bufs)} shard buffers, "
                                 f"plan has {plan.n_shards} shards")
            for j, buf in enumerate(shard_bufs):
                if buf.shape != (layout.shard_rows[j], WIRE_LANES):
                    raise ValueError(
                        f"shard {j}: buffer {buf.shape} does not match "
                        f"layout ({layout.shard_rows[j]}, {WIRE_LANES})")
        else:
            # Python slicing clamps, so an undersized buffer would
            # silently hand trailing shards a (0, 512) "empty" region
            # and drop their updates — reject it up front.
            if wire.shape != (layout.total_rows, WIRE_LANES):
                raise ValueError(
                    f"wire buffer {wire.shape} does not match layout "
                    f"({layout.total_rows}, {WIRE_LANES})")
            shard_bufs = plan.shard_wires(wire)
        # Stale-epoch pushes skip wire compression: the per-(worker,
        # shard) error-feedback buffers were reset at the swap and are
        # shaped for the NEW plan — a lossless transition-window push
        # beats quantizing against mismatched feedback state.
        if self.wire_compression is not None and epoch == self.reshard_epoch:
            shard_bufs = self._compress_packed(worker, shard_bufs)
        self._push_payloads(worker, shard_bufs, packed=True, epoch=epoch)

    def _infer_epoch(self, rows: int) -> Optional[int]:
        """Map a full-buffer row count onto the epoch whose layout it
        matches — newest first, so in-heap callers still holding an old
        plan keep working across a reshard."""
        with self._reshard_cond:
            if self.plan.wire_layout().total_rows == rows:
                return self.reshard_epoch
            for e in sorted(self._retired_plans, reverse=True):
                if self._retired_plans[e].wire_layout().total_rows == rows:
                    return e
            return self.reshard_epoch   # let validation raise with detail

    def _push_payloads(self, worker: int, payloads: Sequence[Any],
                       packed: bool, epoch: Optional[int] = None) -> None:
        t_push = TRACE.now() if TRACE.enabled else 0.0
        # Atomically: which epoch's shard set does this push apply to,
        # and register it in flight — a live reshard replays parked
        # regions only after every push registered under the old epoch
        # has finished (nothing can still append to a parked list).
        with self._reshard_cond:
            cur = self.reshard_epoch
            shards = self.shards
            self._inflight[cur] = self._inflight.get(cur, 0) + 1
        try:
            if epoch is not None and epoch != cur:
                if not packed:
                    # A tree push that raced the swap: pack each piece
                    # list into its OLD-plan region, then translate like
                    # any other stale packed push.
                    old_plan = self._retired_plans.get(epoch)
                    if old_plan is None:
                        raise ValueError(
                            f"unknown reshard epoch {epoch}; re-pull")
                    payloads = [old_plan.pack_shard_pieces(p, j)
                                for j, p in enumerate(payloads)]
                    packed = True
                payloads = self._translate_stale(payloads, epoch, cur)
            now = self._clock() - self._t0
            # Global mode: the gate decides FIRST (monolithic order —
            # decide, apply, then maybe block), and its decision governs
            # every shard's apply so update-dropping policies (backup
            # workers) and credit accounting match the monolithic server
            # exactly.
            gate_dec = gate_stale = None
            if self.gating == "global":
                gate_dec, gate_stale = self._gate_decide(worker)
            max_stale, any_applied, any_credit = 0, False, False
            total_wait = 0.0
            for j, st in enumerate(shards):
                stale, applied, credit, waited = self._push_shard(
                    st, worker, payloads[j], packed, gate_dec, gate_stale)
                max_stale = max(max_stale, stale)
                any_applied = any_applied or applied
                any_credit = any_credit or credit
                total_wait += waited
            if gate_dec is not None:
                total_wait += self._gate_wait(worker, gate_dec)
                max_stale = gate_stale
            with self._metrics_lock:
                self.metrics.record_push(worker, max_stale,
                                         applied=any_applied,
                                         credit=any_credit, time=now)
                if total_wait > 0:
                    self.metrics.record_wait(worker, total_wait)
                clock = self.metrics.pushes.get(worker, -1)
            if TRACE.enabled:
                TRACE.span("push", t_push, worker=worker, clock=clock,
                           args={"staleness": max_stale,
                                 "applied": any_applied,
                                 "credit": any_credit})
        finally:
            with self._reshard_cond:
                self._inflight[cur] -= 1
                self._reshard_cond.notify_all()

    def _translate_stale(self, payloads: Sequence[Any], epoch: int,
                         cur: int) -> List[jax.Array]:
        """Re-slice per-shard gradient regions packed under a retired
        plan into the current plan's regions, chaining the retained
        migration maps epoch by epoch."""
        bufs = [np.asarray(b) for b in payloads]
        e = epoch
        while e < cur:
            mig = self._migrations.get(e)
            if mig is None:
                raise ValueError(
                    f"reshard epoch {epoch} predates the retained "
                    "migration maps; re-pull to resync")
            bufs = mig.migrate_grads(bufs)
            e += 1
        WIRE.reshard_translated += 1
        return [jnp.asarray(b) for b in bufs]

    def _push_shard(self, st: _ShardState, worker: int, payload: Any,
                    packed: bool = False,
                    gate_dec: Optional[Decision] = None,
                    gate_stale: Optional[int] = None):
        j = st.index
        with st.cond:
            now = self._clock() - self._t0
            rec = st.tracker.record_push(worker, now)
            if gate_dec is None:
                dec = st.policy.on_push(st.tracker, worker, now)
                apply_staleness = rec.staleness
            else:
                # Global gating: apply iff the gate said so, with the
                # gate's staleness (what the monolithic optimizer saw);
                # release decision belongs to the gate, not the shard.
                dec = Decision(apply_update=gate_dec.apply_update,
                               release_now=True,
                               credit_used=gate_dec.credit_used)
                apply_staleness = gate_stale
            if dec.apply_update:
                t_apply = TRACE.now() if TRACE.enabled else 0.0
                if st.retired:
                    # Mid-migration: the shard's packed state has been
                    # copied out.  Park the region; the reshard replays
                    # it through the migration map onto the NEW shards
                    # — applied exactly once, never lost.
                    self._park(st, payload, packed, apply_staleness)
                elif self.coalesce > 1:
                    self._apply_coalesced(st, payload, packed,
                                          apply_staleness)
                elif packed:
                    st.apply_packed(payload, apply_staleness)
                else:
                    st.apply(payload, apply_staleness)
                if TRACE.enabled:
                    TRACE.span("apply", t_apply, worker=worker, shard=j,
                               clock=rec.iteration)
            st.metrics.record_push(worker, rec.staleness,
                                   applied=dec.apply_update,
                                   credit=dec.credit_used, time=now)
            st.cond.notify_all()
            waited = 0.0
            if not dec.release_now:
                t_wait = TRACE.now() if TRACE.enabled else 0.0
                arrival = self._clock()
                # ``st.abandoned``: a live reshard swapped this shard
                # out — peers now push to the NEW shards, so this
                # barrier can never fill; release (the new trackers
                # were equalized, so gating stays consistent there).
                while (not self.stopped and not st.abandoned
                       and not st.policy.may_release(st.tracker, worker)):
                    st.cond.wait(timeout=0.5)
                waited = self._clock() - arrival
                rec.waited = waited
                st.metrics.record_wait(worker, waited)
                if TRACE.enabled:
                    TRACE.span("gate_wait", t_wait, worker=worker, shard=j,
                               clock=rec.iteration)
            return rec.staleness, dec.apply_update, dec.credit_used, waited

    def _make_window(self, st: _ShardState) -> CoalesceWindow:
        """One ``CoalesceWindow`` per shard (the shard's lock domain):
        ``install`` commits buffers + version together so a reader
        snapshotting (buffer, version) under ``st.cond`` never sees one
        without the other (the pull_packed cache is keyed by the
        vector)."""
        def get_pm():
            return st._packed_p, st._packed_m

        def install(p, m, n: int) -> None:
            st._packed_p, st._packed_m = p, m
            st._pieces = None
            st.version += n

        return CoalesceWindow(self, st.cond, st.optimizer, st.tracker,
                              get_pm, install)

    def _apply_coalesced(self, st: _ShardState, payload: Any,
                         packed: bool, staleness: int) -> None:
        """Route one contribution through the shard's coalescing window
        (``CoalesceWindow`` in ``repro.ps.server`` — the full flusher /
        linger / lock-release protocol lives there).  Called under
        ``st.cond``."""
        opt = st.optimizer
        scale = (1.0 / (1.0 + staleness)
                 if opt.staleness_damping else 1.0)
        if not packed:
            if not payload:              # empty shard: bookkeeping only
                st.version += 1
                return
            payload = st.plan.pack_shard_pieces(payload, st.index)
        if payload.shape[0] == 0:        # empty shard region
            st.version += 1
            return
        st.window.submit(payload, scale)

    # -- live reshard ----------------------------------------------------------
    def _park(self, st: _ShardState, payload: Any, packed: bool,
              staleness: int) -> None:
        """Called under ``st.cond`` on a retired shard: hold the packed
        gradient region for replay onto the new shards.  The retired
        shard's version does NOT move (its buffer does not change), so
        delta pulls stay truthful during the migration window."""
        if not packed:
            if not payload:
                return
            payload = st.plan.pack_shard_pieces(payload, st.index)
        if payload.shape[0] == 0:
            return
        st.parked.append((payload, int(staleness)))
        WIRE.reshard_parked += 1

    def reshard(self, n_shards: int, *, split_oversized: Optional[bool] = None,
                _mid_hook: Optional[Callable[[int], None]] = None) -> bool:
        """Live-migrate the packed store to a new shard count S'.

        Training continues throughout: each old shard is paused only for
        the copy-out under its own lock (traced as ``reshard_shard``),
        pushes racing the migration park-and-replay (see ``_park``), and
        everything else — pulls, serving, gating on not-yet-retired
        shards — proceeds.  The full protocol is documented in
        ``repro.ft.reshard``.

        Returns True if a migration ran; a same-plan call is a no-op.
        ``_mid_hook`` (tests/chaos only) fires after each shard's
        copy-out — ``FaultPlan.kill_mid_reshard`` SIGKILLs the server
        process there to exercise reshard x failover.
        """
        if self.apply_mode != "fused":
            raise ValueError("live reshard requires apply_mode='fused' "
                             "(the packed store is what migrates)")
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        from repro.ft.reshard import (build_migration, equalized_counts,
                                      spread_versions)
        from repro.ft.snapshot import (capture_policy_state,
                                       restore_policy_state)
        with self._reshard_lock:
            old_plan, old_shards, old_epoch = self._plan_state()
            if n_shards == len(old_shards):
                return False
            t0 = TRACE.now() if TRACE.enabled else 0.0
            new_plan = old_plan.rebuild(
                n_shards,
                split_oversized=(self._split_oversized
                                 if split_oversized is None
                                 else split_oversized))
            mig = build_migration(old_plan, new_plan)
            # Phase 1 — retire + copy, one shard at a time.  Marking the
            # shard retired FIRST means nothing new enters its coalesce
            # window while we drain it; the copy itself is a reference
            # grab (jax arrays are immutable).  The lock hold is the
            # shard's entire migration pause.
            copied_p, copied_m, copied_v = [], [], []
            counts_per, credits_per = [], []
            for st in old_shards:
                t_s = TRACE.now() if TRACE.enabled else 0.0
                with st.cond:
                    st.retired = True
                    while (st.window is not None and not self.stopped
                           and (st.window.applying or st.window.pending)):
                        st.cond.wait(timeout=0.1)
                    copied_p.append(st._packed_p)
                    copied_m.append(st._packed_m)
                    copied_v.append(st.version)
                    counts_per.append(dict(st.tracker.counts))
                    credits_per.append(dict(st.tracker.credits))
                if TRACE.enabled:
                    TRACE.span("reshard_shard", t_s, shard=st.index)
                if _mid_hook is not None:
                    _mid_hook(st.index)
            # Phase 2 — fold params + momentum through the migration map
            # (contiguous copies in both layouts; bitwise) with no locks
            # held.  Versions redistribute sum-preserving; tracker counts
            # and credits equalize to the per-worker minimum across old
            # shards (the failover clamp rule) so the new barriers are
            # mutually consistent.
            new_p = mig.migrate(copied_p)
            new_m = mig.migrate(copied_m)
            new_versions = spread_versions(sum(copied_v), n_shards)
            eq_counts = equalized_counts(counts_per)
            eq_credits = equalized_counts(credits_per)
            pol_state = capture_policy_state(old_shards[0].policy)
            workers = sorted(eq_counts)
            new_states: List[_ShardState] = []
            for k in range(n_shards):
                policy = self._policy_factory()
                restore_policy_state(policy, pol_state)
                st = _ShardState.from_packed(
                    k, new_plan, jnp.asarray(new_p[k]),
                    jnp.asarray(new_m[k]), new_versions[k], policy,
                    self._optimizer_factory(), workers)
                st.tracker.counts.update(eq_counts)
                st.tracker.credits.update(eq_credits)
                st.window = self._make_window(st)
                new_states.append(st)
            # Phase 3 — atomic swap + epoch bump.  The old plan and the
            # map are retained so stale-epoch pushes still translate.
            with self._reshard_cond:
                self.plan = new_plan
                self.shards = new_states
                self.n_shards = n_shards
                self._retired_plans[old_epoch] = old_plan
                self._migrations[old_epoch] = mig
                self.reshard_epoch = old_epoch + 1
                self._reshard_cond.notify_all()
            with self._snap_lock:
                self._snap_key = self._snap_wire = None
            # Error-feedback state is layout-shaped; reset it (the next
            # compressed push starts a fresh feedback loop).
            self._err.clear()
            self._wire_err.clear()
            # Phase 4 — release barrier waiters stranded on old shards:
            # their peers push to the new shards now, so those barriers
            # can never fill.
            for st in old_shards:
                with st.cond:
                    st.abandoned = True
                    st.cond.notify_all()
            # Phase 5 — once no push registered under the old epoch is
            # still in flight (none can append to a parked list any
            # more), replay every parked region onto the new shards.
            with self._reshard_cond:
                while (self._inflight.get(old_epoch, 0) > 0
                       and not self.stopped):
                    self._reshard_cond.wait(timeout=0.5)
                self._inflight.pop(old_epoch, None)
            replayed = 0
            for j, st in enumerate(old_shards):
                with st.cond:
                    parked, st.parked = st.parked, []
                for region, staleness in parked:
                    self._replay_region(mig, j, region, staleness)
                    replayed += 1
            WIRE.reshard_replayed += replayed
            if TRACE.enabled:
                TRACE.span("reshard", t0,
                           args={"from": len(old_shards), "to": n_shards,
                                 "epoch": old_epoch + 1,
                                 "replayed": replayed})
            return True

    def _replay_region(self, mig, old_shard: int, region,
                       staleness: int) -> None:
        """Apply one parked old-plan gradient region to the new shards.

        The momentum fold runs ONLY over the moved segments: every
        other element of the destination shards already saw this push's
        decay through its own old shard (applied or replayed there), so
        a whole-region ``fused_update`` with zero-padding would decay
        those elements twice.
        """
        flat = np.asarray(region).reshape(-1)
        by_new: Dict[int, List[Any]] = {}
        for mv in mig.moves_from(old_shard):
            by_new.setdefault(mv.new_shard, []).append(mv)
        for k, mvs in by_new.items():
            st = self.shards[k]
            with st.cond:
                opt = st.optimizer
                scale = (1.0 / (1.0 + staleness)
                         if opt.staleness_damping else 1.0)
                p = np.asarray(st._packed_p).reshape(-1).copy()
                m = np.asarray(st._packed_m).reshape(-1).copy()
                lr = p.dtype.type(opt.lr)
                beta = p.dtype.type(opt.momentum)
                scale = p.dtype.type(scale)
                for mv in mvs:
                    g = flat[mv.old_off:mv.old_off + mv.size]
                    sl = slice(mv.new_off, mv.new_off + mv.size)
                    seg = m[sl] * beta + g * scale
                    m[sl] = seg
                    p[sl] = p[sl] - lr * seg
                rows = p.size // WIRE_LANES
                st._packed_p = jnp.asarray(p.reshape(rows, WIRE_LANES))
                st._packed_m = jnp.asarray(m.reshape(rows, WIRE_LANES))
                st._pieces = None
                # The buffer changed, so the version MUST move (delta
                # pulls diff on it) — one bump per replayed contribution
                # per touched shard.
                st.version += 1
                st.cond.notify_all()

    def _gate_decide(self, worker: int):
        """Global-gate bookkeeping + decision (no blocking yet)."""
        with self._gate_cond:
            now = self._clock() - self._t0
            rec = self._gate_tracker.record_push(worker, now)
            dec = self._gate_policy.on_push(self._gate_tracker, worker, now)
            self._gate_cond.notify_all()
            return dec, rec.staleness

    def _gate_wait(self, worker: int, dec: Decision) -> float:
        if dec.release_now:
            return 0.0
        t_wait = TRACE.now() if TRACE.enabled else 0.0
        with self._gate_cond:
            arrival = self._clock()
            while (not self.stopped
                   and not self._gate_policy.may_release(
                       self._gate_tracker, worker)):
                self._gate_cond.wait(timeout=0.5)
            waited = self._clock() - arrival
        if TRACE.enabled:
            TRACE.span("gate_wait", t_wait, worker=worker)
        return waited

    def _compress(self, worker: int,
                  pieces_per_shard: List[List[jax.Array]]):
        err = self._err.get(worker)
        if err is None:
            err = [self.compressor.init_error(p) for p in pieces_per_shard]
        out = []
        for j, pieces in enumerate(pieces_per_shard):
            compressed, err[j] = self.compressor.apply(pieces, err[j])
            out.append(compressed)
        self._err[worker] = err
        return out

    def _compress_packed(self, worker: int,
                         shard_bufs: List[jax.Array]) -> List[jax.Array]:
        """Fused wire compression: ONE kernel launch per non-empty shard
        (quantize + dequant + error feedback in a single VMEM pass),
        with per-(worker, shard) f32 error buffers in wire layout."""
        return [self._compress_packed_one(worker, j, buf)
                for j, buf in enumerate(shard_bufs)]

    def _compress_packed_one(self, worker: int, shard: int,
                             buf: jax.Array) -> jax.Array:
        if buf.shape[0] == 0:
            return buf
        state = self._wire_err.setdefault(worker, {})
        err = state.get(shard)
        if err is None:
            err = jnp.zeros(buf.shape, jnp.float32)
        buf, err = self.wire_compression.apply(buf, err)
        state[shard] = err
        return buf

    def record_loss(self, step: int, loss: float) -> None:
        with self._metrics_lock:
            now = self._clock() - self._t0
            self.metrics.record_loss_point(now, self.version, float(loss))

    # -- elastic membership ----------------------------------------------------
    def add_worker(self, worker: int) -> None:
        for st in self.shards:
            with st.cond:
                st.tracker.add_worker(worker)
                st.metrics.n_workers = len(st.tracker.workers)
                st.cond.notify_all()
        if self.gating == "global":
            with self._gate_cond:
                self._gate_tracker.add_worker(worker)
                self._gate_cond.notify_all()
        with self._metrics_lock:
            self.metrics.n_workers = len(self.shards[0].tracker.workers)
        self._err.pop(worker, None)
        self._wire_err.pop(worker, None)

    def remove_worker(self, worker: int) -> None:
        """Departure must not stall ANY shard's barrier: drop the worker
        from every shard tracker, waking that shard's waiters."""
        for st in self.shards:
            with st.cond:
                st.tracker.remove_worker(worker)
                st.metrics.n_workers = len(st.tracker.workers)
                st.cond.notify_all()
        if self.gating == "global":
            with self._gate_cond:
                self._gate_tracker.remove_worker(worker)
                self._gate_cond.notify_all()
        with self._metrics_lock:
            self.metrics.n_workers = len(self.shards[0].tracker.workers)
        self._err.pop(worker, None)
        self._wire_err.pop(worker, None)

    def stop(self) -> None:
        self.stopped = True
        for st in self.shards:
            with st.cond:
                st.cond.notify_all()
        if self.gating == "global":
            with self._gate_cond:
                self._gate_cond.notify_all()

    # -- inspection ------------------------------------------------------------
    # (``params``/``snapshot``/``shutdown`` come from the protocol base.)
    @property
    def version(self) -> int:
        """Total applied shard-updates.  At S=1 this equals the monolithic
        server's version (one applied update per released push)."""
        return sum(st.version for st in self.shards)

    def shard_versions(self) -> List[int]:
        return [st.version for st in self.shards]

    def staleness_profile(self) -> Dict[int, Dict[int, int]]:
        """shard -> worker -> current gap."""
        out = {}
        for st in self.shards:
            with st.cond:
                out[st.index] = st.tracker.staleness_profile()
        return out

    def shard_metrics(self) -> List[RunMetrics]:
        return [st.metrics for st in self.shards]
