"""Sharded (partitioned) parameter server — per-shard locks and gating.

The monolithic ``ParameterServer`` serializes *every* push on one lock
and one version counter: the exact single-machine bottleneck the PS
framework exists to avoid.  Here the weight pytree is partitioned by a
``ShardPlan`` into S size-balanced shards, and every shard owns its own

  * lock (condition variable)      — pushes to distinct shards overlap,
  * version counter                — per-shard applied-update count,
  * ``ServerOptimizer`` state      — momentum lives with its slice,
  * ``SyncPolicy`` + ``StalenessTracker`` — per-shard Algorithm-1 gating,
  * ``RunMetrics``                 — per-shard staleness/wait accounting.

Gating modes
------------
``sharded`` (default)  every shard gates independently with its own
    policy instance; a DSSP shard's Algorithm-2 controller reads that
    shard's interval table (table A), so skewed shard load produces
    per-shard credit schedules.  A worker's push returns when the LAST
    shard releases it.
``global``  one policy/tracker gates the worker exactly once per push
    (the monolithic semantics) while the weight store stays partitioned —
    the ablation that isolates lock-granularity wins from gating wins.

Wire compression (``optim/compression.py``) runs per shard with
per-(worker, shard) error-feedback state, emulating worker-side
compression of each shard RPC.

The apply path is pluggable: ``apply_mode='tree'`` steps the shard's
piece list through its ``ServerOptimizer`` (bitwise-identical to the
monolithic server), ``apply_mode='fused'`` keeps params+momentum packed
in one lane-aligned (rows, 512) buffer and folds the whole shard through
a single Pallas ``fused_update`` launch per push.

Packed wire format (the zero-repack hot path)
---------------------------------------------
``push``/``pull`` speak the *tree* wire format: per-leaf arrays, split
and reassembled on every hop.  ``push_packed``/``pull_packed`` speak the
plan's packed wire format instead — the worker packs its gradients once
(inside its jitted step) and every later hop is layout-preserving:

  * ``push_packed`` slices the incoming wire buffer into per-shard
    row-range *views* (``ShardPlan.shard_wire``) — zero host-side
    per-leaf concatenations on the server, asserted by the
    ``repro.perfcount`` probes,
  * each shard folds its region straight through ONE ``fused_update``
    launch (no ``pack_shard`` per push), plus at most one fused
    compression launch (``wire_compression=``) with per-(worker, shard)
    error-feedback buffers kept in wire layout,
  * ``pull_packed`` serves a version-keyed packed snapshot: per-shard
    buffers are reference-grabbed under their own locks, the full wire
    buffer is concatenated OUTSIDE any lock and cached until some shard
    version moves.

Tree-format ``pull`` in fused mode also rebuilds its per-shard piece
cache outside the shard lock, so a pull after an apply never stalls
concurrent pushes to that shard while it unpacks.

Coalesced apply + version-delta pulls (work ∝ rounds + change)
--------------------------------------------------------------
With W workers the paths above still do O(W) kernel launches per round
per shard and ship the full snapshot on every pull.  Two knobs make
server work scale with *rounds and changed state* instead:

  * ``coalesce=K`` arms a bounded micro-batching window per shard:
    contributions that arrive while a flush is in flight (or within a
    short linger, ``coalesce_wait``) are drained together through ONE
    ``fused_update_batched`` launch — an in-kernel sequential fold, so
    numerics match the uncoalesced path (bitwise for f32 state and for
    any window of one) while launches per round drop from S x W toward
    S.  The sync policy still sees, decides and releases every
    contributing worker individually: BSP/SSP/DSSP semantics are
    untouched.
  * ``pull_delta(worker, versions)`` returns only the shards whose
    version moved past the worker's last-seen vector (full-snapshot
    fallback on a vector mismatch), so steady-state pull bytes are
    proportional to what actually changed.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro._compat import warn_legacy
from repro.api.protocol import DeltaPull, ParameterServerProtocol
from repro.core.policies import Decision, SyncPolicy
from repro.core.staleness import StalenessTracker
from repro.obs.trace import TRACE
from repro.optim.compression import Compressor
from repro.perfcount import WIRE
from repro.ps.metrics import RunMetrics
from repro.ps.server import (DEFAULT_COALESCE_WAIT_S, CoalesceWindow,
                             ServerOptimizer)
from repro.ps.sharded.plan import ShardPlan, build_shard_plan
from repro.wireformat import WIRE_LANES

Params = Any
Grads = Any


class _ShardState:
    """Everything one shard owns.  All mutation under ``self.cond``."""

    def __init__(self, index: int, plan: ShardPlan,
                 pieces: List[jax.Array],
                 policy: SyncPolicy, optimizer: ServerOptimizer,
                 workers: Sequence[int], apply_mode: str):
        self.index = index
        self.plan = plan
        self.cond = threading.Condition()
        self.policy = policy
        self.optimizer = optimizer
        self.tracker = StalenessTracker(workers)
        self.metrics = RunMetrics(policy=f"{policy.name}/shard{index}",
                                  n_workers=len(list(workers)))
        self.version = 0
        self.apply_mode = apply_mode
        #: set by the server when coalescing is armed (fused mode):
        #: the shard's ``CoalesceWindow`` over its packed buffers.
        self.window = None
        if apply_mode == "fused":
            # Params + momentum stay resident in the plan's wire layout
            # (8-row-aligned (rows, 512) region), so an incoming packed
            # push folds in directly with zero re-packing; unpacked
            # pieces are a cache rebuilt at most once per version —
            # OUTSIDE the shard lock (see ``_shard_snapshot``).
            self._packed_p = plan.pack_shard_pieces(pieces, index)
            self._packed_m = jnp.zeros_like(self._packed_p)
            self._pieces: Optional[List[jax.Array]] = list(pieces)
        else:
            self._pieces = list(pieces)

    # -- weight access (call under self.cond) -------------------------------
    def pieces(self) -> List[jax.Array]:
        if self._pieces is None:  # fused mode, invalidated by an apply
            self._pieces = self.plan.shard_pieces_from_wire(
                self._packed_p, self.index)
        return self._pieces

    def apply(self, grad_pieces: List[jax.Array], staleness: int) -> None:
        """Tree-wire apply: one piece list, optimizer step or pack+fold."""
        if not grad_pieces:
            # Empty shard (more shards than pieces): the gate/version
            # bookkeeping stays uniform, there is just nothing to fold in
            # (a zero-row pallas_call would reject its (8, 512) tile).
            self.version += 1
            return
        if self.apply_mode == "fused":
            self.apply_packed(
                self.plan.pack_shard_pieces(grad_pieces, self.index),
                staleness)
        else:
            self._pieces = self.optimizer.step(self.pieces(), grad_pieces,
                                               staleness)
            self.version += 1

    def apply_packed(self, wire_g: jax.Array, staleness: int) -> None:
        """Packed-wire apply: fold the shard's (rows, 512) gradient region
        straight through one ``fused_update`` launch — no per-leaf work.
        Fused mode only (``push_packed`` guards at the server boundary)."""
        if wire_g.shape[0] == 0:      # empty shard
            self.version += 1
            return
        # Kernel imports stay local to the fused path so plain
        # `import repro.ps` never pulls in the Pallas kernel stack.
        from repro.kernels import ops as kops
        opt = self.optimizer
        scale = (1.0 / (1.0 + staleness)
                 if opt.staleness_damping else 1.0)
        self._packed_p, self._packed_m = kops.fused_update(
            self._packed_p, self._packed_m, wire_g,
            lr=opt.lr, beta=opt.momentum, scale=scale)
        self._pieces = None
        self.version += 1


class ShardedParameterServer(ParameterServerProtocol):
    """Partitioned weight store + per-shard Algorithm-1 gating.

    Implements ``repro.api.protocol.ParameterServerProtocol`` — the
    same surface as the monolithic ``ParameterServer`` (plus the
    overridden per-shard variants), so workers, endpoints and sessions
    drive either server without a type branch.
    """

    def __init__(self, params: Params, policy_factory: Callable[[], SyncPolicy],
                 optimizer_factory: Callable[[], ServerOptimizer],
                 n_workers: int, n_shards: int, *,
                 split_oversized: bool = True,
                 gating: str = "sharded",
                 apply_mode: str = "tree",
                 compressor: Optional[Compressor] = None,
                 wire_compression: Optional[str] = None,
                 topk_fraction: float = 0.05,
                 coalesce: int = 1,
                 coalesce_wait: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        warn_legacy("ShardedParameterServer",
                    "repro.api.build_session(RunSpec(ps=ServerSpec("
                    "kind='sharded', ...)))")
        if gating not in ("sharded", "global"):
            raise ValueError(f"unknown gating mode {gating!r}")
        if apply_mode not in ("tree", "fused"):
            raise ValueError(f"unknown apply mode {apply_mode!r}")
        if wire_compression not in (None, "none", "", "int8", "topk"):
            raise ValueError(
                f"unknown wire compression {wire_compression!r}")
        if coalesce < 1:
            raise ValueError(f"coalesce window must be >= 1, got {coalesce}")
        if coalesce > 1 and apply_mode != "fused":
            raise ValueError("coalesce > 1 batches packed applies; it "
                             "requires apply_mode='fused'")
        self.coalesce = coalesce
        self.coalesce_wait = (coalesce_wait if coalesce_wait is not None
                              else (DEFAULT_COALESCE_WAIT_S
                                    if coalesce > 1 else 0.0))
        self.plan: ShardPlan = build_shard_plan(
            params, n_shards, split_oversized=split_oversized)
        self.gating = gating
        self.n_shards = n_shards
        self.apply_mode = apply_mode
        workers = range(n_workers)
        pieces = self.plan.split(params)
        self.shards: List[_ShardState] = [
            _ShardState(j, self.plan, pieces[j], policy_factory(),
                        optimizer_factory(), workers, apply_mode)
            for j in range(n_shards)]
        if apply_mode == "fused":
            for st in self.shards:
                st.window = self._make_window(st)
        if gating == "global":
            self._gate_policy = policy_factory()
            self._gate_tracker = StalenessTracker(workers)
            self._gate_cond = threading.Condition()
        self.metrics = RunMetrics(
            policy=f"{self.shards[0].policy.name} xS{n_shards}[{gating}]",
            n_workers=n_workers)
        self._metrics_lock = threading.Lock()
        self.compressor = (compressor
                           if compressor is not None
                           and compressor.name != "none" else None)
        self._err: Dict[int, List[Any]] = {}   # worker -> per-shard err state
        # Packed-path fused wire compression: per-(worker, shard) f32
        # error-feedback buffers, kept in wire layout.
        from repro.optim.compression import make_packed_compressor
        self.wire_compression = make_packed_compressor(
            wire_compression, fraction=topk_fraction)
        self._wire_err: Dict[int, Dict[int, jax.Array]] = {}
        # Version-keyed packed snapshot cache for ``pull_packed``.
        self._snap_lock = threading.Lock()
        self._snap_key: Optional[tuple] = None
        self._snap_wire: Optional[jax.Array] = None
        self._clock = clock
        self._t0 = clock()
        self.stopped = False

    # -- worker API ----------------------------------------------------------
    def _shard_snapshot(self, st: _ShardState) -> List[jax.Array]:
        """One shard's piece list, unpacking OUTSIDE the shard lock.

        In fused mode an apply invalidates the piece cache; rebuilding it
        while holding ``st.cond`` would stall every concurrent push to
        that shard for the full unpack.  Instead: grab the (immutable)
        packed buffer + version under the lock, unpack unlocked, and
        install the cache only if the shard has not moved meanwhile.
        """
        with st.cond:
            if st._pieces is not None:
                return list(st._pieces)
            packed, version = st._packed_p, st.version
        pieces = self.plan.shard_pieces_from_wire(packed, st.index)
        with st.cond:
            if st.version == version and st._pieces is None:
                st._pieces = list(pieces)
        return pieces

    def pull(self, worker: int) -> Params:
        """Reassemble the full pytree from per-shard snapshots.

        Each shard is snapshotted under its OWN lock; shards mutated
        concurrently with the pull may differ in version — exactly the
        per-shard consistency a partitioned PS offers (each shard's slice
        is internally consistent; cross-shard skew is bounded by the
        gating policies).
        """
        t0 = TRACE.now() if TRACE.enabled else 0.0
        params = self.plan.assemble(
            [self._shard_snapshot(st) for st in self.shards])
        if TRACE.enabled:
            TRACE.span("pull", t0, worker=worker)
        return params

    def pull_packed(self, worker: int = -1) -> jax.Array:
        """Full (total_rows, 512) wire snapshot of the parameters.

        Per-shard packed buffers are reference-grabbed under their own
        locks (jax arrays are immutable, so a reference IS a snapshot);
        the concatenation into one wire buffer happens OUTSIDE any shard
        lock and is cached keyed by the shard-version vector, so pulls
        between applies are a dictionary hit.
        """
        if self.apply_mode != "fused":
            raise ValueError("pull_packed requires apply_mode='fused' "
                             "(tree mode has no resident packed store)")
        t0 = TRACE.now() if TRACE.enabled else 0.0
        snaps, versions = [], []
        for st in self.shards:
            with st.cond:
                snaps.append(st._packed_p)
                versions.append(st.version)
        key = tuple(versions)
        with self._snap_lock:
            if self._snap_key == key:
                wire = self._snap_wire
                if TRACE.enabled:
                    TRACE.span("pull", t0, worker=worker,
                               args={"packed": True, "cached": True})
                return wire
        bufs = [b for b in snaps if b.shape[0]]
        wire = bufs[0] if len(bufs) == 1 else jnp.concatenate(bufs)
        with self._snap_lock:
            # A slower concurrent pull may finish its concat AFTER a
            # fresher one: install only if this snapshot DOMINATES the
            # cached one (component-wise >=, somewhere >).  The old
            # any-newer guard let two pulls that interleaved their
            # per-shard grabs replace a cache entry with one that was
            # OLDER on some shard — never inconsistent (key and wire
            # install as a pair), but non-monotone.  The concurrency
            # regression test hammers push+pull and asserts the cached
            # key always matches the cached bytes and never regresses.
            cached = self._snap_key
            if cached is None or (
                    all(n >= c for n, c in zip(key, cached))
                    and any(n > c for n, c in zip(key, cached))):
                self._snap_key, self._snap_wire = key, wire
        if TRACE.enabled:
            TRACE.span("pull", t0, worker=worker,
                       args={"packed": True, "cached": False})
        return wire

    def pull_packed_shard(self, shard: int, worker: int = -1) -> jax.Array:
        """One shard's resident (rows, 512) region — a reference IS a
        consistent snapshot (jax arrays are immutable).  The per-shard
        granularity the transport endpoints route on."""
        if self.apply_mode != "fused":
            raise ValueError("pull_packed_shard requires apply_mode='fused' "
                             "(tree mode has no resident packed store)")
        st = self.shards[shard]
        with st.cond:
            return st._packed_p

    def pull_delta(self, worker: int,
                   versions: Optional[Sequence[int]]) -> DeltaPull:
        """Version-delta pull: ship only the shards that advanced.

        ``versions`` is the per-shard version vector the worker saw on
        its LAST pull; the reply carries the regions of shards whose
        version moved (each region reference-grabbed with its version
        under that shard's lock — the same per-shard consistency as
        ``pull_packed``) plus the fresh vector.  A vector of the wrong
        arity, or one claiming versions the server has not reached,
        cannot be diffed against — the reply falls back to a full
        snapshot (every non-empty shard, ``full=True``).  Bytes shipped
        and bytes avoided are accounted in ``repro.perfcount.WIRE``.
        """
        if self.apply_mode != "fused":
            raise ValueError("pull_delta requires apply_mode='fused' "
                             "(tree mode has no resident packed store)")
        t0 = TRACE.now() if TRACE.enabled else 0.0
        snaps, cur = [], []
        for st in self.shards:
            with st.cond:
                snaps.append(st._packed_p)
                cur.append(st.version)
        cur_t = tuple(cur)
        layout = self.plan.wire_layout()
        itemsize = jnp.dtype(layout.dtype).itemsize
        full_bytes = layout.total_rows * WIRE_LANES * itemsize
        mismatch = (versions is None or len(versions) != self.n_shards
                    or any(int(v) > c for v, c in zip(versions, cur)))
        if mismatch:
            changed = [j for j in range(self.n_shards)
                       if snaps[j].shape[0]]
        else:
            changed = [j for j, (v, c) in enumerate(zip(versions, cur))
                       if int(v) != c and snaps[j].shape[0]]
        regions = tuple(snaps[j] for j in changed)
        delta_bytes = sum(int(r.shape[0]) for r in regions) \
            * WIRE_LANES * itemsize
        WIRE.delta_bytes_tx += delta_bytes
        if not mismatch:
            WIRE.full_pull_bytes_avoided += full_bytes - delta_bytes
        if TRACE.enabled:
            TRACE.span("pull_delta", t0, worker=worker,
                       args={"shards": len(changed), "bytes": delta_bytes,
                             "full": mismatch})
        return DeltaPull(versions=cur_t, shards=tuple(changed),
                         regions=regions, full=mismatch)

    def push_packed_shard(self, worker: int, shard: int, buf) -> None:
        """Single-shard packed push: the unit of per-shard endpoint
        routing (``repro.transport``), where different shards of this
        server live behind different endpoints.

        Gating/apply semantics are the sharded ones — this shard's
        policy gates the worker independently.  ``gating='global'`` is
        rejected: the global gate's decision spans all shards of one
        logical push, which no longer exists once shards are routed to
        different endpoints.

        Accounting is per shard ONLY (``shard_metrics()``): one logical
        gradient routed across S endpoints is S of these calls, and
        folding each into the aggregate ``self.metrics`` would inflate
        ``total_pushes``/staleness S-fold versus the same gradient
        pushed through ``push_packed`` (which records the aggregate
        once, max-staleness folded).
        """
        if self.apply_mode != "fused":
            raise ValueError("push_packed_shard requires apply_mode='fused' "
                             "(tree mode has no resident packed store)")
        if self.gating == "global":
            raise ValueError(
                "per-shard routed pushes require gating='sharded' (the "
                "global gate must see one push spanning all shards)")
        layout = self.plan.wire_layout()
        if buf.shape != (layout.shard_rows[shard], WIRE_LANES):
            raise ValueError(
                f"shard {shard}: buffer {buf.shape} does not match "
                f"layout ({layout.shard_rows[shard]}, {WIRE_LANES})")
        if self.wire_compression is not None:
            buf = self._compress_packed_one(worker, shard, buf)
        self._push_shard(shard, worker, buf, packed=True)

    def push(self, worker: int, grads: Grads) -> None:
        """Split grads by the plan and push shard-by-shard.

        Every worker visits shards in the SAME canonical order 0..S-1:
        with blocking policies a per-worker rotated order deadlocks
        (worker A blocked at shard 0's barrier while worker B, whose push
        would release it, is blocked at shard 1's — a circular wait).  A
        total order keeps the wait-for graph acyclic while pushes to
        distinct shards still overlap in pipeline fashion.  Blocks until
        every shard's policy has released the worker (the ``global`` mode
        gates once, after all applies).
        """
        pieces_per_shard = self.plan.split(grads)
        if self.compressor is not None:
            pieces_per_shard = self._compress(worker, pieces_per_shard)
        self._push_payloads(worker, pieces_per_shard, packed=False)

    def push_packed(self, worker: int, wire) -> None:
        """Packed-wire push: the zero-repack hot path.

        ``wire`` is either the full (total_rows, 512) buffer (the worker
        packed once in its jitted step) or a list of per-shard regions.
        The server only takes row-range VIEWS — no per-leaf concatenate,
        no ``pack_shard`` — and each shard folds its region through one
        ``fused_update`` launch (plus one fused-compression launch when
        ``wire_compression`` is set).  Gating/metrics semantics are
        identical to ``push``.
        """
        if self.apply_mode != "fused":
            raise ValueError("push_packed requires apply_mode='fused' "
                             "(tree mode has no resident packed store)")
        layout = self.plan.wire_layout()
        if isinstance(wire, (list, tuple)):
            shard_bufs = list(wire)
            if len(shard_bufs) != self.n_shards:
                raise ValueError(f"got {len(shard_bufs)} shard buffers, "
                                 f"plan has {self.n_shards} shards")
            for j, buf in enumerate(shard_bufs):
                if buf.shape != (layout.shard_rows[j], WIRE_LANES):
                    raise ValueError(
                        f"shard {j}: buffer {buf.shape} does not match "
                        f"layout ({layout.shard_rows[j]}, {WIRE_LANES})")
        else:
            # Python slicing clamps, so an undersized buffer would
            # silently hand trailing shards a (0, 512) "empty" region
            # and drop their updates — reject it up front.
            if wire.shape != (layout.total_rows, WIRE_LANES):
                raise ValueError(
                    f"wire buffer {wire.shape} does not match layout "
                    f"({layout.total_rows}, {WIRE_LANES})")
            shard_bufs = self.plan.shard_wires(wire)
        if self.wire_compression is not None:
            shard_bufs = self._compress_packed(worker, shard_bufs)
        self._push_payloads(worker, shard_bufs, packed=True)

    def _push_payloads(self, worker: int, payloads: Sequence[Any],
                       packed: bool) -> None:
        t_push = TRACE.now() if TRACE.enabled else 0.0
        order = range(self.n_shards)
        now = self._clock() - self._t0
        # Global mode: the gate decides FIRST (monolithic order — decide,
        # apply, then maybe block), and its decision governs every shard's
        # apply so update-dropping policies (backup workers) and credit
        # accounting match the monolithic server exactly.
        gate_dec = gate_stale = None
        if self.gating == "global":
            gate_dec, gate_stale = self._gate_decide(worker)
        max_stale, any_applied, any_credit = 0, False, False
        total_wait = 0.0
        for j in order:
            stale, applied, credit, waited = self._push_shard(
                j, worker, payloads[j], packed, gate_dec, gate_stale)
            max_stale = max(max_stale, stale)
            any_applied = any_applied or applied
            any_credit = any_credit or credit
            total_wait += waited
        if gate_dec is not None:
            total_wait += self._gate_wait(worker, gate_dec)
            max_stale = gate_stale
        with self._metrics_lock:
            self.metrics.record_push(worker, max_stale, applied=any_applied,
                                     credit=any_credit, time=now)
            if total_wait > 0:
                self.metrics.record_wait(worker, total_wait)
            clock = self.metrics.pushes.get(worker, -1)
        if TRACE.enabled:
            TRACE.span("push", t_push, worker=worker, clock=clock,
                       args={"staleness": max_stale, "applied": any_applied,
                             "credit": any_credit})

    def _push_shard(self, j: int, worker: int, payload: Any,
                    packed: bool = False,
                    gate_dec: Optional[Decision] = None,
                    gate_stale: Optional[int] = None):
        st = self.shards[j]
        with st.cond:
            now = self._clock() - self._t0
            rec = st.tracker.record_push(worker, now)
            if gate_dec is None:
                dec = st.policy.on_push(st.tracker, worker, now)
                apply_staleness = rec.staleness
            else:
                # Global gating: apply iff the gate said so, with the
                # gate's staleness (what the monolithic optimizer saw);
                # release decision belongs to the gate, not the shard.
                dec = Decision(apply_update=gate_dec.apply_update,
                               release_now=True,
                               credit_used=gate_dec.credit_used)
                apply_staleness = gate_stale
            if dec.apply_update:
                t_apply = TRACE.now() if TRACE.enabled else 0.0
                if self.coalesce > 1:
                    self._apply_coalesced(st, payload, packed,
                                          apply_staleness)
                elif packed:
                    st.apply_packed(payload, apply_staleness)
                else:
                    st.apply(payload, apply_staleness)
                if TRACE.enabled:
                    TRACE.span("apply", t_apply, worker=worker, shard=j,
                               clock=rec.iteration)
            st.metrics.record_push(worker, rec.staleness,
                                   applied=dec.apply_update,
                                   credit=dec.credit_used, time=now)
            st.cond.notify_all()
            waited = 0.0
            if not dec.release_now:
                t_wait = TRACE.now() if TRACE.enabled else 0.0
                arrival = self._clock()
                while (not self.stopped
                       and not st.policy.may_release(st.tracker, worker)):
                    st.cond.wait(timeout=0.5)
                waited = self._clock() - arrival
                rec.waited = waited
                st.metrics.record_wait(worker, waited)
                if TRACE.enabled:
                    TRACE.span("gate_wait", t_wait, worker=worker, shard=j,
                               clock=rec.iteration)
            return rec.staleness, dec.apply_update, dec.credit_used, waited

    def _make_window(self, st: _ShardState) -> CoalesceWindow:
        """One ``CoalesceWindow`` per shard (the shard's lock domain):
        ``install`` commits buffers + version together so a reader
        snapshotting (buffer, version) under ``st.cond`` never sees one
        without the other (the pull_packed cache is keyed by the
        vector)."""
        def get_pm():
            return st._packed_p, st._packed_m

        def install(p, m, n: int) -> None:
            st._packed_p, st._packed_m = p, m
            st._pieces = None
            st.version += n

        return CoalesceWindow(self, st.cond, st.optimizer, st.tracker,
                              get_pm, install)

    def _apply_coalesced(self, st: _ShardState, payload: Any,
                         packed: bool, staleness: int) -> None:
        """Route one contribution through the shard's coalescing window
        (``CoalesceWindow`` in ``repro.ps.server`` — the full flusher /
        linger / lock-release protocol lives there).  Called under
        ``st.cond``."""
        opt = st.optimizer
        scale = (1.0 / (1.0 + staleness)
                 if opt.staleness_damping else 1.0)
        if not packed:
            if not payload:              # empty shard: bookkeeping only
                st.version += 1
                return
            payload = st.plan.pack_shard_pieces(payload, st.index)
        if payload.shape[0] == 0:        # empty shard region
            st.version += 1
            return
        st.window.submit(payload, scale)

    def _gate_decide(self, worker: int):
        """Global-gate bookkeeping + decision (no blocking yet)."""
        with self._gate_cond:
            now = self._clock() - self._t0
            rec = self._gate_tracker.record_push(worker, now)
            dec = self._gate_policy.on_push(self._gate_tracker, worker, now)
            self._gate_cond.notify_all()
            return dec, rec.staleness

    def _gate_wait(self, worker: int, dec: Decision) -> float:
        if dec.release_now:
            return 0.0
        t_wait = TRACE.now() if TRACE.enabled else 0.0
        with self._gate_cond:
            arrival = self._clock()
            while (not self.stopped
                   and not self._gate_policy.may_release(
                       self._gate_tracker, worker)):
                self._gate_cond.wait(timeout=0.5)
            waited = self._clock() - arrival
        if TRACE.enabled:
            TRACE.span("gate_wait", t_wait, worker=worker)
        return waited

    def _compress(self, worker: int,
                  pieces_per_shard: List[List[jax.Array]]):
        err = self._err.get(worker)
        if err is None:
            err = [self.compressor.init_error(p) for p in pieces_per_shard]
        out = []
        for j, pieces in enumerate(pieces_per_shard):
            compressed, err[j] = self.compressor.apply(pieces, err[j])
            out.append(compressed)
        self._err[worker] = err
        return out

    def _compress_packed(self, worker: int,
                         shard_bufs: List[jax.Array]) -> List[jax.Array]:
        """Fused wire compression: ONE kernel launch per non-empty shard
        (quantize + dequant + error feedback in a single VMEM pass),
        with per-(worker, shard) f32 error buffers in wire layout."""
        return [self._compress_packed_one(worker, j, buf)
                for j, buf in enumerate(shard_bufs)]

    def _compress_packed_one(self, worker: int, shard: int,
                             buf: jax.Array) -> jax.Array:
        if buf.shape[0] == 0:
            return buf
        state = self._wire_err.setdefault(worker, {})
        err = state.get(shard)
        if err is None:
            err = jnp.zeros(buf.shape, jnp.float32)
        buf, err = self.wire_compression.apply(buf, err)
        state[shard] = err
        return buf

    def record_loss(self, step: int, loss: float) -> None:
        with self._metrics_lock:
            now = self._clock() - self._t0
            self.metrics.record_loss_point(now, self.version, float(loss))

    # -- elastic membership ----------------------------------------------------
    def add_worker(self, worker: int) -> None:
        for st in self.shards:
            with st.cond:
                st.tracker.add_worker(worker)
                st.metrics.n_workers = len(st.tracker.workers)
                st.cond.notify_all()
        if self.gating == "global":
            with self._gate_cond:
                self._gate_tracker.add_worker(worker)
                self._gate_cond.notify_all()
        with self._metrics_lock:
            self.metrics.n_workers = len(self.shards[0].tracker.workers)
        self._err.pop(worker, None)
        self._wire_err.pop(worker, None)

    def remove_worker(self, worker: int) -> None:
        """Departure must not stall ANY shard's barrier: drop the worker
        from every shard tracker, waking that shard's waiters."""
        for st in self.shards:
            with st.cond:
                st.tracker.remove_worker(worker)
                st.metrics.n_workers = len(st.tracker.workers)
                st.cond.notify_all()
        if self.gating == "global":
            with self._gate_cond:
                self._gate_tracker.remove_worker(worker)
                self._gate_cond.notify_all()
        with self._metrics_lock:
            self.metrics.n_workers = len(self.shards[0].tracker.workers)
        self._err.pop(worker, None)
        self._wire_err.pop(worker, None)

    def stop(self) -> None:
        self.stopped = True
        for st in self.shards:
            with st.cond:
                st.cond.notify_all()
        if self.gating == "global":
            with self._gate_cond:
                self._gate_cond.notify_all()

    # -- inspection ------------------------------------------------------------
    # (``params``/``snapshot``/``shutdown`` come from the protocol base.)
    @property
    def version(self) -> int:
        """Total applied shard-updates.  At S=1 this equals the monolithic
        server's version (one applied update per released push)."""
        return sum(st.version for st in self.shards)

    def shard_versions(self) -> List[int]:
        return [st.version for st in self.shards]

    def staleness_profile(self) -> Dict[int, Dict[int, int]]:
        """shard -> worker -> current gap."""
        out = {}
        for st in self.shards:
            with st.cond:
                out[st.index] = st.tracker.staleness_profile()
        return out

    def shard_metrics(self) -> List[RunMetrics]:
        return [st.metrics for st in self.shards]
