"""Shard planning: partition a parameter pytree across S server shards.

A ``ShardPlan`` is a static, deterministic description of which pieces of
which pytree leaves live on which shard.  The plan format (also in
``ps/sharded/README.md``):

  * the pytree is flattened once (``jax.tree_util.tree_flatten`` order is
    the canonical leaf numbering),
  * every leaf is cut into one or more ``LeafSlice``s.  A slice is either
    the *whole* leaf, or a contiguous ``[start, stop)`` range along the
    leaf's **leading axis** (only leaves bigger than the per-shard target
    are split, and scalars / single-row leaves are never split),
  * slices are greedily bin-packed into ``n_shards`` size-balanced
    ``Shard``s: largest piece first, always into the currently lightest
    shard (ties toward the lowest shard index) — the classic LPT
    heuristic, ≤ 4/3·OPT imbalance,
  * within a shard, slices are kept sorted by ``(leaf, start)`` so the
    shard's wire layout is deterministic and reproducible across runs.

The plan is pure metadata: ``split`` / ``assemble`` do the actual data
movement (slicing on push, ``jnp.concatenate`` on pull) and are each
other's inverse for any tree matching the plan's structure.

Packed wire format
------------------
``split``/``assemble`` are the *tree* wire format: per-shard lists of
arrays, one host-side op per piece.  The *packed* wire format makes the
lane-aligned ``(rows, 512)`` buffer the native representation instead:
the whole tree lives in ONE flat buffer laid out shard-by-shard (each
shard's slices contiguous in ``(leaf, start)`` order, each shard region
zero-padded to a multiple of 8 rows so a Pallas ``(8, 512)`` tile grid
lands exactly), and a precomputed index permutation converts between
canonical flat order and wire order in a single gather:

    ``pack(tree)``      1 concatenate (all leaves -> canonical flat)
                        + 1 gather (canonical -> wire)        [jittable]
    ``unpack(wire)``    1 gather (wire -> canonical flat)
                        + per-leaf slice *views*               [jittable]
    ``shard_wire``      a row-slice view — NO per-leaf work at all.

A worker packs its gradients once inside its jitted step; every later
hop (push, per-shard apply, snapshot, pull) stays in wire layout.  The
layout is cached per wire dtype on the plan (``wire_layout``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.perfcount import WIRE
from repro.wireformat import (WIRE_LANES, WIRE_ROWS, pack_flat,
                              resolve_wire_dtype)

Tree = Any


@dataclasses.dataclass(frozen=True)
class LeafSlice:
    """One contiguous piece of one pytree leaf."""

    leaf: int        # index into the canonical flattened-leaf list
    start: int       # leading-axis start row (0 for whole leaves)
    stop: int        # leading-axis stop row (shape[0], or 1 for scalars)
    whole: bool      # the entire leaf (no slicing needed on the wire)
    size: int        # element count of the piece


@dataclasses.dataclass(frozen=True)
class Shard:
    index: int
    slices: Tuple[LeafSlice, ...]
    size: int        # total element count

    def __len__(self) -> int:
        return len(self.slices)


@dataclasses.dataclass(frozen=True)
class WireLayout:
    """Precomputed flat offsets of a plan's packed wire format.

    One layout per wire dtype (cached on the plan).  All fields are
    host-side metadata; the two index arrays are jit constants, so
    ``pack``/``unpack`` trace to a single fused gather each.
    """

    dtype: Any                                # wire buffer dtype
    total_elems: int                          # real elements (no padding)
    total_rows: int                           # wire buffer rows (512 lanes)
    shard_row_start: Tuple[int, ...]          # first wire row of each shard
    shard_rows: Tuple[int, ...]               # rows per shard (8-aligned)
    slice_offsets: Tuple[Tuple[int, ...], ...]  # per shard: element offset
                                              # of each slice in the shard's
                                              # flat region
    pack_idx: jax.Array                       # (total_rows*512,) wire pos ->
                                              # canonical flat pos; padding
                                              # points at slot total_elems
    unpack_idx: jax.Array                     # (total_elems,) canonical flat
                                              # pos -> wire pos

    @property
    def total_wire(self) -> int:
        return self.total_rows * WIRE_LANES


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    n_shards: int
    treedef: Any
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    shards: Tuple[Shard, ...]
    leaf_dtypes: Tuple[Any, ...] = ()
    _wire_layouts: Dict[Any, WireLayout] = dataclasses.field(
        default_factory=dict, compare=False, repr=False)

    # -- data movement -----------------------------------------------------
    def split(self, tree: Tree) -> List[List[jax.Array]]:
        """Cut ``tree`` (params or grads) into per-shard piece lists."""
        leaves = jax.tree_util.tree_leaves(tree)
        if len(leaves) != len(self.leaf_shapes):
            raise ValueError(
                f"tree has {len(leaves)} leaves, plan was built for "
                f"{len(self.leaf_shapes)}")
        out: List[List[jax.Array]] = []
        for shard in self.shards:
            pieces = []
            for sl in shard.slices:
                leaf = leaves[sl.leaf]
                pieces.append(leaf if sl.whole else leaf[sl.start:sl.stop])
            out.append(pieces)
        return out

    def shard_pieces(self, tree: Tree, shard: int) -> List[jax.Array]:
        """``split`` restricted to one shard (what a worker pushes to it)."""
        leaves = jax.tree_util.tree_leaves(tree)
        return [leaves[sl.leaf] if sl.whole
                else leaves[sl.leaf][sl.start:sl.stop]
                for sl in self.shards[shard].slices]

    def assemble(self, pieces_per_shard: Sequence[Sequence[jax.Array]]) -> Tree:
        """Inverse of ``split``: rebuild the full pytree from shard pieces."""
        parts: Dict[int, Dict[int, jax.Array]] = {}
        for shard, pieces in zip(self.shards, pieces_per_shard):
            if len(pieces) != len(shard.slices):
                raise ValueError(
                    f"shard {shard.index}: got {len(pieces)} pieces, "
                    f"plan has {len(shard.slices)} slices")
            for sl, piece in zip(shard.slices, pieces):
                parts.setdefault(sl.leaf, {})[sl.start] = piece
        leaves = []
        for i, shape in enumerate(self.leaf_shapes):
            by_start = parts.get(i)
            if by_start is None:
                raise ValueError(f"leaf {i} missing from shard pieces")
            if len(by_start) == 1:
                (leaf,) = by_start.values()
            else:
                WIRE.leaf_concats += 1
                leaf = jnp.concatenate(
                    [by_start[s] for s in sorted(by_start)], axis=0)
            leaves.append(leaf)
        return self.treedef.unflatten(leaves)

    # -- packed wire format --------------------------------------------------
    def piece_shape(self, sl: LeafSlice) -> Tuple[int, ...]:
        """Array shape of one slice as it travels on the wire."""
        shape = self.leaf_shapes[sl.leaf]
        if sl.whole:
            return shape
        return (sl.stop - sl.start,) + shape[1:]

    def _resolve_wire_dtype(self, dtype) -> Any:
        """None -> the shared ``repro.wireformat`` rule: a uniform tree
        keeps its dtype on the wire, mixed trees promote to f32."""
        if dtype is not None:
            return jnp.dtype(dtype)
        return resolve_wire_dtype((jnp.dtype(d) for d in self.leaf_dtypes),
                                  default=jnp.dtype(jnp.float32))

    def wire_layout(self, dtype=None) -> WireLayout:
        """The (cached) packed layout for one wire dtype."""
        wdt = self._resolve_wire_dtype(dtype)
        layout = self._wire_layouts.get(wdt)
        if layout is None:
            layout = self._build_wire_layout(wdt)
            self._wire_layouts[wdt] = layout
        return layout

    def _build_wire_layout(self, wdt) -> WireLayout:
        sizes = [math.prod(s) if s else 1 for s in self.leaf_shapes]
        leaf_off = np.concatenate([[0], np.cumsum(sizes)])
        total = int(leaf_off[-1])
        def shard_region_rows(n_elems: int) -> int:
            if n_elems == 0:
                return 0
            raw = -(-n_elems // WIRE_LANES)              # ceil to full lanes
            return -(-raw // WIRE_ROWS) * WIRE_ROWS      # ceil to 8-row tiles

        rows = tuple(shard_region_rows(s.size) for s in self.shards)
        row_start = tuple(int(x) for x in
                          np.concatenate([[0], np.cumsum(rows)])[:-1])
        total_rows = int(sum(rows))
        pack_idx = np.full(total_rows * WIRE_LANES, total, np.int32)
        unpack_idx = np.empty(total, np.int32)
        slice_offsets: List[Tuple[int, ...]] = []
        for j, shard in enumerate(self.shards):
            base = row_start[j] * WIRE_LANES
            off = 0
            offs = []
            for sl in shard.slices:
                shape = self.leaf_shapes[sl.leaf]
                row_elems = math.prod(shape[1:]) if len(shape) > 1 else 1
                canon0 = int(leaf_off[sl.leaf]) + sl.start * row_elems
                span = np.arange(canon0, canon0 + sl.size, dtype=np.int32)
                pack_idx[base + off:base + off + sl.size] = span
                unpack_idx[span] = np.arange(base + off,
                                             base + off + sl.size,
                                             dtype=np.int32)
                offs.append(off)
                off += sl.size
            slice_offsets.append(tuple(offs))
        return WireLayout(dtype=wdt, total_elems=total,
                          total_rows=total_rows,
                          shard_row_start=row_start, shard_rows=rows,
                          slice_offsets=tuple(slice_offsets),
                          pack_idx=jnp.asarray(pack_idx),
                          unpack_idx=jnp.asarray(unpack_idx))

    def pack(self, tree: Tree, dtype=None) -> jax.Array:
        """Tree -> one (total_rows, 512) wire buffer.

        One concatenate (canonical flat order) + one precomputed gather
        (wire order, zero-padded shard regions).  Jittable; inside a jit
        the whole thing fuses into a single pass over the data.
        """
        layout = self.wire_layout(dtype)
        leaves = jax.tree_util.tree_leaves(tree)
        if len(leaves) != len(self.leaf_shapes):
            raise ValueError(
                f"tree has {len(leaves)} leaves, plan was built for "
                f"{len(self.leaf_shapes)}")
        for i, (x, shape) in enumerate(zip(leaves, self.leaf_shapes)):
            # Size mismatches must not reach the gather: jnp.take's
            # default clip mode would silently clamp out-of-range
            # indices into a well-shaped but garbage wire buffer.
            if tuple(x.shape) != shape:
                raise ValueError(f"leaf {i}: shape {tuple(x.shape)} does "
                                 f"not match plan shape {shape}")
        WIRE.packs += 1
        WIRE.gathers += 1
        flats = [x.reshape(-1).astype(layout.dtype) for x in leaves]
        flats.append(jnp.zeros((1,), layout.dtype))   # padding slot
        if len(flats) > 2:
            WIRE.leaf_concats += 1
        flat = jnp.concatenate(flats)
        wire = jnp.take(flat, layout.pack_idx, axis=0)
        return wire.reshape(layout.total_rows, WIRE_LANES)

    def unpack(self, wire: jax.Array, dtype=None) -> Tree:
        """Inverse of ``pack``: one gather + per-leaf slice views."""
        layout = self.wire_layout(dtype)
        if wire.shape != (layout.total_rows, WIRE_LANES):
            raise ValueError(
                f"wire buffer {wire.shape} does not match layout "
                f"({layout.total_rows}, {WIRE_LANES})")
        WIRE.unpacks += 1
        WIRE.gathers += 1
        flat = jnp.take(wire.reshape(-1), layout.unpack_idx, axis=0)
        leaves = []
        off = 0
        dtypes = self.leaf_dtypes or (jnp.float32,) * len(self.leaf_shapes)
        for shape, dt in zip(self.leaf_shapes, dtypes):
            size = math.prod(shape) if shape else 1
            leaves.append(flat[off:off + size].reshape(shape).astype(dt))
            off += size
        return self.treedef.unflatten(leaves)

    def shard_wire(self, wire: jax.Array, shard: int, dtype=None) -> jax.Array:
        """Shard ``shard``'s (rows, 512) region — a pure row-slice view."""
        layout = self.wire_layout(dtype)
        start = layout.shard_row_start[shard]
        return wire[start:start + layout.shard_rows[shard]]

    def shard_wires(self, wire: jax.Array, dtype=None) -> List[jax.Array]:
        return [self.shard_wire(wire, j, dtype) for j in range(self.n_shards)]

    def split_packed(self, tree: Tree, dtype=None) -> List[jax.Array]:
        """``pack`` + per-shard views: the packed analogue of ``split``."""
        return self.shard_wires(self.pack(tree, dtype), dtype)

    def assemble_packed(self, shard_bufs: Sequence[jax.Array],
                        dtype=None) -> Tree:
        """Inverse of ``split_packed``: concat shard regions + ``unpack``."""
        layout = self.wire_layout(dtype)
        for j, buf in enumerate(shard_bufs):
            if buf.shape != (layout.shard_rows[j], WIRE_LANES):
                raise ValueError(
                    f"shard {j}: buffer {buf.shape} does not match layout "
                    f"({layout.shard_rows[j]}, {WIRE_LANES})")
        bufs = [b for b in shard_bufs if b.shape[0]]
        wire = bufs[0] if len(bufs) == 1 else jnp.concatenate(bufs)
        return self.unpack(wire, dtype)

    def shard_pieces_from_wire(self, buf: jax.Array, shard: int,
                               dtype=None) -> List[jax.Array]:
        """One shard's piece list (tree wire format) out of its packed
        region — per-slice views, no concatenation."""
        layout = self.wire_layout(dtype)
        WIRE.unpacks += 1
        flat = buf.reshape(-1)
        dtypes = self.leaf_dtypes or (jnp.float32,) * len(self.leaf_shapes)
        out = []
        for sl, off in zip(self.shards[shard].slices,
                           layout.slice_offsets[shard]):
            shape = self.piece_shape(sl)
            out.append(flat[off:off + sl.size].reshape(shape)
                       .astype(dtypes[sl.leaf]))
        return out

    def pack_shard_pieces(self, pieces: Sequence[jax.Array], shard: int,
                          dtype=None) -> jax.Array:
        """One shard's piece list -> its (rows, 512) packed region."""
        layout = self.wire_layout(dtype)
        rows = layout.shard_rows[shard]
        if not pieces:
            return jnp.zeros((rows, WIRE_LANES), layout.dtype)
        return pack_flat(pieces, layout.dtype, rows=rows)

    def rebuild(self, n_shards: int, *,
                split_oversized: bool = True) -> "ShardPlan":
        """The SAME tree re-planned at a new arity — metadata only.

        ``build_shard_plan`` touches nothing but ``.shape``/``.dtype``,
        so a tree of ``jax.ShapeDtypeStruct``s suffices: a live reshard
        (``repro.ft.reshard``) re-plans without materializing params.
        Note the per-shard size target depends on ``n_shards``, so the
        new plan may slice leaves differently — the migration map, not
        slice identity, is what relates the two layouts.
        """
        dtypes = self.leaf_dtypes or (jnp.float32,) * len(self.leaf_shapes)
        structs = [jax.ShapeDtypeStruct(s, d)
                   for s, d in zip(self.leaf_shapes, dtypes)]
        tree = jax.tree_util.tree_unflatten(self.treedef, structs)
        return build_shard_plan(tree, n_shards,
                                split_oversized=split_oversized)

    # -- introspection -------------------------------------------------------
    @property
    def total_size(self) -> int:
        return sum(s.size for s in self.shards)

    def imbalance(self) -> float:
        """max shard size / mean shard size (1.0 = perfectly balanced)."""
        sizes = [s.size for s in self.shards]
        mean = sum(sizes) / len(sizes)
        return max(sizes) / mean if mean else 1.0

    def describe(self) -> str:
        lines = [f"ShardPlan: {self.n_shards} shards, "
                 f"{len(self.leaf_shapes)} leaves, "
                 f"{self.total_size:,} elements, "
                 f"imbalance {self.imbalance():.3f}"]
        for s in self.shards:
            split = sum(1 for sl in s.slices if not sl.whole)
            lines.append(f"  shard {s.index}: {s.size:,} elements in "
                         f"{len(s.slices)} pieces ({split} split)")
        return "\n".join(lines)


def _leaf_pieces(leaf_idx: int, shape: Tuple[int, ...], target: int,
                 split_oversized: bool) -> List[LeafSlice]:
    size = math.prod(shape) if shape else 1
    lead = shape[0] if shape else 1
    row = size // lead if lead else size
    can_split = (split_oversized and len(shape) >= 1 and lead > 1
                 and size > target and row > 0)
    if not can_split:
        return [LeafSlice(leaf_idx, 0, lead, whole=True, size=size)]
    rows_per_piece = max(1, target // row)
    pieces = []
    for start in range(0, lead, rows_per_piece):
        stop = min(lead, start + rows_per_piece)
        pieces.append(LeafSlice(leaf_idx, start, stop,
                                whole=False, size=(stop - start) * row))
    return pieces


def build_shard_plan(tree: Tree, n_shards: int, *,
                     split_oversized: bool = True) -> ShardPlan:
    """Greedy LPT bin-packing of pytree leaves into size-balanced shards."""
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot shard an empty pytree")
    shapes = tuple(tuple(x.shape) for x in leaves)
    total = sum(math.prod(s) if s else 1 for s in shapes)
    target = max(1, -(-total // n_shards))  # ceil

    pieces: List[LeafSlice] = []
    for i, shape in enumerate(shapes):
        pieces.extend(_leaf_pieces(i, shape, target, split_oversized))

    # Largest-first into the lightest shard; deterministic tie-breaks.
    pieces.sort(key=lambda sl: (-sl.size, sl.leaf, sl.start))
    bins: List[List[LeafSlice]] = [[] for _ in range(n_shards)]
    sizes = [0] * n_shards
    for sl in pieces:
        j = min(range(n_shards), key=lambda k: (sizes[k], k))
        bins[j].append(sl)
        sizes[j] += sl.size

    shards = tuple(
        Shard(index=j,
              slices=tuple(sorted(bins[j], key=lambda sl: (sl.leaf, sl.start))),
              size=sizes[j])
        for j in range(n_shards))
    return ShardPlan(n_shards=n_shards, treedef=treedef,
                     leaf_shapes=shapes, shards=shards,
                     leaf_dtypes=tuple(jnp.dtype(x.dtype) for x in leaves))
