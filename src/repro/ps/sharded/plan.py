"""Shard planning: partition a parameter pytree across S server shards.

A ``ShardPlan`` is a static, deterministic description of which pieces of
which pytree leaves live on which shard.  The plan format (also in
``ps/sharded/README.md``):

  * the pytree is flattened once (``jax.tree_util.tree_flatten`` order is
    the canonical leaf numbering),
  * every leaf is cut into one or more ``LeafSlice``s.  A slice is either
    the *whole* leaf, or a contiguous ``[start, stop)`` range along the
    leaf's **leading axis** (only leaves bigger than the per-shard target
    are split, and scalars / single-row leaves are never split),
  * slices are greedily bin-packed into ``n_shards`` size-balanced
    ``Shard``s: largest piece first, always into the currently lightest
    shard (ties toward the lowest shard index) — the classic LPT
    heuristic, ≤ 4/3·OPT imbalance,
  * within a shard, slices are kept sorted by ``(leaf, start)`` so the
    shard's wire layout is deterministic and reproducible across runs.

The plan is pure metadata: ``split`` / ``assemble`` do the actual data
movement (slicing on push, ``jnp.concatenate`` on pull) and are each
other's inverse for any tree matching the plan's structure.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

Tree = Any


@dataclasses.dataclass(frozen=True)
class LeafSlice:
    """One contiguous piece of one pytree leaf."""

    leaf: int        # index into the canonical flattened-leaf list
    start: int       # leading-axis start row (0 for whole leaves)
    stop: int        # leading-axis stop row (shape[0], or 1 for scalars)
    whole: bool      # the entire leaf (no slicing needed on the wire)
    size: int        # element count of the piece


@dataclasses.dataclass(frozen=True)
class Shard:
    index: int
    slices: Tuple[LeafSlice, ...]
    size: int        # total element count

    def __len__(self) -> int:
        return len(self.slices)


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    n_shards: int
    treedef: Any
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    shards: Tuple[Shard, ...]

    # -- data movement -----------------------------------------------------
    def split(self, tree: Tree) -> List[List[jax.Array]]:
        """Cut ``tree`` (params or grads) into per-shard piece lists."""
        leaves = jax.tree_util.tree_leaves(tree)
        if len(leaves) != len(self.leaf_shapes):
            raise ValueError(
                f"tree has {len(leaves)} leaves, plan was built for "
                f"{len(self.leaf_shapes)}")
        out: List[List[jax.Array]] = []
        for shard in self.shards:
            pieces = []
            for sl in shard.slices:
                leaf = leaves[sl.leaf]
                pieces.append(leaf if sl.whole else leaf[sl.start:sl.stop])
            out.append(pieces)
        return out

    def shard_pieces(self, tree: Tree, shard: int) -> List[jax.Array]:
        """``split`` restricted to one shard (what a worker pushes to it)."""
        leaves = jax.tree_util.tree_leaves(tree)
        return [leaves[sl.leaf] if sl.whole
                else leaves[sl.leaf][sl.start:sl.stop]
                for sl in self.shards[shard].slices]

    def assemble(self, pieces_per_shard: Sequence[Sequence[jax.Array]]) -> Tree:
        """Inverse of ``split``: rebuild the full pytree from shard pieces."""
        parts: Dict[int, Dict[int, jax.Array]] = {}
        for shard, pieces in zip(self.shards, pieces_per_shard):
            if len(pieces) != len(shard.slices):
                raise ValueError(
                    f"shard {shard.index}: got {len(pieces)} pieces, "
                    f"plan has {len(shard.slices)} slices")
            for sl, piece in zip(shard.slices, pieces):
                parts.setdefault(sl.leaf, {})[sl.start] = piece
        leaves = []
        for i, shape in enumerate(self.leaf_shapes):
            by_start = parts.get(i)
            if by_start is None:
                raise ValueError(f"leaf {i} missing from shard pieces")
            if len(by_start) == 1:
                (leaf,) = by_start.values()
            else:
                leaf = jnp.concatenate(
                    [by_start[s] for s in sorted(by_start)], axis=0)
            leaves.append(leaf)
        return self.treedef.unflatten(leaves)

    # -- introspection -------------------------------------------------------
    @property
    def total_size(self) -> int:
        return sum(s.size for s in self.shards)

    def imbalance(self) -> float:
        """max shard size / mean shard size (1.0 = perfectly balanced)."""
        sizes = [s.size for s in self.shards]
        mean = sum(sizes) / len(sizes)
        return max(sizes) / mean if mean else 1.0

    def describe(self) -> str:
        lines = [f"ShardPlan: {self.n_shards} shards, "
                 f"{len(self.leaf_shapes)} leaves, "
                 f"{self.total_size:,} elements, "
                 f"imbalance {self.imbalance():.3f}"]
        for s in self.shards:
            split = sum(1 for sl in s.slices if not sl.whole)
            lines.append(f"  shard {s.index}: {s.size:,} elements in "
                         f"{len(s.slices)} pieces ({split} split)")
        return "\n".join(lines)


def _leaf_pieces(leaf_idx: int, shape: Tuple[int, ...], target: int,
                 split_oversized: bool) -> List[LeafSlice]:
    size = math.prod(shape) if shape else 1
    lead = shape[0] if shape else 1
    row = size // lead if lead else size
    can_split = (split_oversized and len(shape) >= 1 and lead > 1
                 and size > target and row > 0)
    if not can_split:
        return [LeafSlice(leaf_idx, 0, lead, whole=True, size=size)]
    rows_per_piece = max(1, target // row)
    pieces = []
    for start in range(0, lead, rows_per_piece):
        stop = min(lead, start + rows_per_piece)
        pieces.append(LeafSlice(leaf_idx, start, stop,
                                whole=False, size=(stop - start) * row))
    return pieces


def build_shard_plan(tree: Tree, n_shards: int, *,
                     split_oversized: bool = True) -> ShardPlan:
    """Greedy LPT bin-packing of pytree leaves into size-balanced shards."""
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot shard an empty pytree")
    shapes = tuple(tuple(x.shape) for x in leaves)
    total = sum(math.prod(s) if s else 1 for s in shapes)
    target = max(1, -(-total // n_shards))  # ceil

    pieces: List[LeafSlice] = []
    for i, shape in enumerate(shapes):
        pieces.extend(_leaf_pieces(i, shape, target, split_oversized))

    # Largest-first into the lightest shard; deterministic tie-breaks.
    pieces.sort(key=lambda sl: (-sl.size, sl.leaf, sl.start))
    bins: List[List[LeafSlice]] = [[] for _ in range(n_shards)]
    sizes = [0] * n_shards
    for sl in pieces:
        j = min(range(n_shards), key=lambda k: (sizes[k], k))
        bins[j].append(sl)
        sizes[j] += sl.size

    shards = tuple(
        Shard(index=j,
              slices=tuple(sorted(bins[j], key=lambda sl: (sl.leaf, sl.start))),
              size=sizes[j])
        for j in range(n_shards))
    return ShardPlan(n_shards=n_shards, treedef=treedef,
                     leaf_shapes=shapes, shards=shards)
