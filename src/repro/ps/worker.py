"""Worker threads for the parameter-server layer.

Each worker runs the paper's loop (Alg. 1 worker block):

    pull w_s  →  replace local weights  →  compute grads on a mini-batch
    →  push grads  →  (blocked until the server sends OK)

``step_fn`` is any jitted ``(params, batch) -> (grads, aux)`` function;
batches come from a per-worker data shard (data parallelism, §I).  A
``speed_factor > 1`` makes the worker proportionally slower by sleeping
``(speed_factor − 1) × measured_compute`` per iteration — this emulates
the paper's heterogeneous cluster (GTX1060 vs GTX1080Ti) on one machine
without depending on scheduler noise.

``wire_format='packed'`` switches the worker onto the zero-repack hot
path: it pulls the server's packed (rows, 512) wire buffer
(``pull_packed``), hands it to ``step_fn`` unchanged (the jitted step
unpacks, differentiates and re-packs in one fused program — see
``repro.launch.train.train_ps``), and pushes the packed gradient buffer
back (``push_packed``).  The pytree<->wire boundary is crossed exactly
once per direction, inside the worker's jit.

``delta_pull=True`` (packed only) replaces the full-snapshot pull with
``server.pull_delta``: the worker keeps a resident packed buffer plus
the per-shard version vector from its last pull and patches in only
the shard regions that advanced — pull bytes proportional to change.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Iterator, Optional

from repro._compat import warn_legacy
from repro.api.protocol import ParameterServerProtocol
from repro.obs.trace import TRACE

StepFn = Callable[[Any, Any], Any]  # (params, batch) -> (grads, aux)


class PSWorker(threading.Thread):
    def __init__(self, worker_id: int, server: ParameterServerProtocol,
                 step_fn: StepFn, batches: Iterator[Any], n_iterations: int,
                 *, speed_factor: float = 1.0,
                 loss_from_aux: Optional[Callable[[Any], float]] = None,
                 wire_format: str = "tree",
                 delta_pull: bool = False,
                 reconnect: Optional[Callable[[], Any]] = None,
                 name: Optional[str] = None):
        super().__init__(name=name or f"ps-worker-{worker_id}", daemon=True)
        warn_legacy("PSWorker",
                    "repro.api.build_session (sessions construct and "
                    "join their own workers)")
        if wire_format not in ("tree", "packed"):
            raise ValueError(f"unknown wire format {wire_format!r}")
        if delta_pull and wire_format != "packed":
            raise ValueError("delta_pull tracks per-shard versions of "
                             "the packed snapshot; it requires "
                             "wire_format='packed'")
        self.worker_id = worker_id
        self.server = server
        self.step_fn = step_fn
        self.batches = batches
        self.n_iterations = n_iterations
        self.speed_factor = speed_factor
        self.loss_from_aux = loss_from_aux
        self.wire_format = wire_format
        self.delta_pull = delta_pull
        #: Failover hook (``repro.ft``): a zero-arg callable returning a
        #: fresh server handle after the current one dies with a
        #: ``ConnectionError`` — the worker rebinds pull/push against it
        #: and retries the interrupted iteration.  ``None`` = die.
        self.reconnect = reconnect
        self.reconnects = 0
        self.iterations_done = 0
        self.failure: Optional[BaseException] = None
        self._abort = threading.Event()

    def abort(self) -> None:
        """Simulate a node failure: the worker exits before its next pull."""
        self._abort.set()

    def _delta_puller(self):
        """Version-delta pulls: keep a resident HOST buffer and patch
        only the shard regions whose version advanced since the last
        pull, in place (the bootstrap vector of -1s makes the first
        delta carry every shard).  An empty delta returns the previous
        device buffer untouched — zero copies; a non-empty one costs
        one device upload of the patched buffer (per-region ``.at[]``
        scatters would copy the whole buffer once per region).  Returns
        a drop-in replacement for ``server.pull_packed``."""
        import jax.numpy as jnp
        import numpy as np

        from repro.wireformat import WIRE_LANES
        layout = self.server.plan.wire_layout()
        state = {
            "layout": layout,
            "host": np.zeros((layout.total_rows, WIRE_LANES),
                             layout.dtype),
            "wire": None,
            "versions": (-1,) * getattr(self.server, "n_shards", 1),
        }

        def pull(worker_id: int):
            d = self.server.pull_delta(worker_id, state["versions"])
            while len(d.versions) != len(state["versions"]):
                # Live reshard: the server's arity moved under us.
                # Rebuild the resident buffer against the server's
                # CURRENT plan and re-bootstrap; if the plan moves yet
                # again between reply and rebuild, the loop resyncs
                # once more.  (In-heap workers share the plan object
                # graph with the server, so ``server.plan`` IS the new
                # plan.)
                lay = self.server.plan.wire_layout()
                state["layout"] = lay
                state["host"] = np.zeros((lay.total_rows, WIRE_LANES),
                                         lay.dtype)
                state["wire"] = None
                state["versions"] = (-1,) * len(lay.shard_row_start)
                d = self.server.pull_delta(worker_id, state["versions"])
            state["versions"] = d.versions
            if state["wire"] is not None and d.empty:
                return state["wire"]
            for j, region in zip(d.shards, d.regions):
                start = state["layout"].shard_row_start[j]
                state["host"][start:start + region.shape[0]] = \
                    np.asarray(region)
            # jnp.array COPIES (asarray may alias on CPU, and the host
            # buffer mutates in place on the next pull)
            state["wire"] = jnp.array(state["host"])
            return state["wire"]

        return pull

    def _bind(self):
        """(pull, push) against the CURRENT ``self.server`` — re-run
        after a reconnect swaps the handle."""
        packed = self.wire_format == "packed"
        pull = (self._delta_puller() if packed and self.delta_pull
                else self.server.pull_packed if packed
                else self.server.pull)
        push = self.server.push_packed if packed else self.server.push
        return pull, push

    def run(self) -> None:
        pull, push = self._bind()
        try:
            it = 0
            while it < self.n_iterations:
                if self._abort.is_set() or self.server.stopped:
                    break
                try:
                    params = pull(self.worker_id)
                    t_tr = TRACE.now() if TRACE.enabled else 0.0
                    t0 = time.monotonic()
                    grads, aux = self.step_fn(params, next(self.batches))
                    grads = _block(grads)
                    compute = time.monotonic() - t0
                    if self.speed_factor > 1.0:
                        # The sleep IS the emulated (slower-device)
                        # compute, so the compute_step span includes it.
                        time.sleep(compute * (self.speed_factor - 1.0))
                    if TRACE.enabled:
                        TRACE.span("compute_step", t_tr,
                                   worker=self.worker_id, clock=it)
                    if self.loss_from_aux is not None:
                        self.server.record_loss(it,
                                                self.loss_from_aux(aux))
                    push(self.worker_id, grads)
                except ConnectionError:
                    # The server handle died mid-iteration.  With a
                    # failover hook: swap in a fresh handle, rebind, and
                    # retry the SAME iteration (its push may double —
                    # ordinary async-SGD noise, never lost progress).
                    if self.reconnect is None:
                        raise
                    self.server = self.reconnect()
                    pull, push = self._bind()
                    self.reconnects += 1
                    continue
                self.iterations_done += 1
                it += 1
        except BaseException as e:  # surfaced by join_all
            self.failure = e
        finally:
            # Leave the barrier group on ANY exit — completion, abort or
            # crash.  A departed worker must not gate survivors (fault
            # tolerance) nor stall late joiners (elasticity).
            self.server.remove_worker(self.worker_id)


def _block(tree: Any) -> Any:
    import jax
    return jax.block_until_ready(tree)


def run_cluster(server: ParameterServerProtocol, workers: list[PSWorker],
                timeout: float = 600.0) -> None:
    """Start all workers, join them, re-raise the first worker failure."""
    for w in workers:
        w.start()
    deadline = time.monotonic() + timeout
    for w in workers:
        w.join(timeout=max(0.0, deadline - time.monotonic()))
    server.stop()
    for w in workers:
        w.join(timeout=5.0)
        if w.failure is not None:
            raise w.failure
    alive = [w.name for w in workers if w.is_alive()]
    if alive:
        raise TimeoutError(f"workers did not finish: {alive}")
