"""Continuous-batching request queue for the serving replicas.

One thread-safe queue per replica: producers ``submit`` decode
requests; the replica's serve loop calls ``next_batch`` which blocks
for the first request, then lingers up to ``window_s`` collecting more
(to ``max_batch``) before handing the batch to the decoder — classic
continuous batching, sized so a burst amortizes one jitted decode call
while a lone request never waits longer than the window.

Stdlib-only on purpose: the queue runs inside spawned replica
processes next to the transport client, with no jax on the path until
the decoder takes over.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class DecodeRequest:
    """One prompt in, one greedy continuation out.

    The submit-side fills ``request_id``/``prompt``/``enqueue_t``; the
    replica fills the completion fields when the batch it rode in
    finishes decoding.
    """

    request_id: int
    prompt: np.ndarray                    # (prompt_len,) int32 token ids
    enqueue_t: float = 0.0                # perf_counter at submit
    # -- completion (filled by the replica) ------------------------------
    tokens: Optional[np.ndarray] = None   # (max_new,) generated ids
    latency_s: float = 0.0                # enqueue -> decode done
    staleness: int = -1                   # admitted at this staleness
    version: int = -1                     # resident version served from
    done: threading.Event = field(default_factory=threading.Event,
                                  repr=False)


class BatchQueue:
    """Blocking submit/next_batch pair with a linger window.

    ``next_batch`` returns ``None`` exactly once the queue is closed
    AND drained — the replica's serve-loop sentinel.  ``close`` wakes
    every waiter; requests already queued still get served.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._items: List[DecodeRequest] = []
        self._closed = False
        self.submitted = 0

    def submit(self, request: DecodeRequest) -> None:
        with self._cond:
            if self._closed:
                raise RuntimeError("queue is closed")
            self._items.append(request)
            self.submitted += 1
            self._cond.notify_all()

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def next_batch(self, max_batch: int,
                   window_s: float) -> Optional[List[DecodeRequest]]:
        """Block for the first request, linger up to ``window_s`` for
        more, return at most ``max_batch`` in FIFO order.  ``None``
        means closed-and-drained: stop serving."""
        with self._cond:
            while not self._items:
                if self._closed:
                    return None
                self._cond.wait(timeout=0.25)
            if len(self._items) < max_batch and window_s > 0:
                # Linger: one bounded wait is enough — either more
                # arrivals topped the batch up (notify fired) or the
                # window elapsed and we serve what we have.
                self._cond.wait(timeout=window_s)
            batch = self._items[:max_batch]
            del self._items[:len(batch)]
            return batch

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()


__all__ = ["BatchQueue", "DecodeRequest"]
