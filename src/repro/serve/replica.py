"""Replica-side parameter subscription: the consumer half of the
version-vector protocol.

A serving replica never pushes — it *subscribes* to the live parameter
server and keeps a resident host-side copy of the packed (rows, 512)
wire buffer fresh through version-delta pulls: each refresh sends the
per-shard version vector of the resident copy and receives only the
shard regions that advanced (full-snapshot fallback on dominance
mismatch — the exact PR-5 protocol the training workers already ride).

Freshness is the SSP bound, mirrored to the consumer side.  Every
reply carries the server's aggregate version (the applied-update
count) in its clock field, so the replica always knows how far its
resident copy trails:

    staleness = last_seen_server_version - sum(resident version vector)

``wait_fresh(bound)`` is the admission gate: while staleness exceeds
``serve.staleness_bound`` the caller blocks (a ``staleness_block`` obs
span), an immediate refresh is forced, and admission proceeds only
once the resident buffer is within the bound again — a replica can
never serve weights more than ``bound`` applied updates behind the
server it last heard from.  A stopped server freezes the final
weights, which are then fresh by definition.

Two subscription backends share the protocol: ``TransportSubscription``
speaks frames over tcp/shmem from a replica process,
``DirectSubscription`` reads an in-heap server from a replica thread
(the inproc engine and the property tests, where "last heard from" is
a live read).
"""

from __future__ import annotations

import threading
from typing import Sequence, Tuple

import numpy as np

from repro.obs.trace import TRACE
from repro.wireformat import WIRE_LANES


class Subscription:
    """One refresh channel to the parameter server.

    ``refresh(versions)`` returns ``(versions', patches, server_version,
    full)`` where ``patches`` is ``[(shard, region), ...]`` for the
    shards that advanced — or ``None`` once the server has stopped.
    """

    n_shards: int = 1
    rows: int = 0

    def refresh(self, versions: Sequence[int]):
        raise NotImplementedError

    def close(self) -> None:
        pass


class TransportSubscription(Subscription):
    """Frames over a live transport: MSG_SUB once, PULL_DELTA forever.

    ``client`` is a ``PSTransportClient`` (tcp/shmem/inproc loopback);
    ``subscribe()`` — NOT ``hello()`` — registers it, so the replica
    never takes a barrier seat and the training gate never waits on a
    consumer."""

    def __init__(self, client, n_shards: int):
        self.client = client
        self.n_shards = int(n_shards)
        self.rows = client.subscribe()
        # The SUB reply's clock is the server version at registration —
        # the subscriber's starting freshness reference.
        self.initial_version = int(client.clock)

    def refresh(self, versions: Sequence[int]):
        d = self.client.pull_delta(versions)
        if d is None:
            return None  # STOP reply: training over, weights frozen
        # Every reply's clock is the server version at reply time —
        # the freshest bound the replica can know over a transport.
        return d.versions, list(zip(d.shards, d.regions)), \
            int(self.client.clock), d.full

    def close(self) -> None:
        self.client.close()


class DirectSubscription(Subscription):
    """In-heap server access for replica threads (inproc engine)."""

    def __init__(self, server, replica_id: int):
        self.server = server
        self.replica_id = int(replica_id)
        self.n_shards = int(getattr(server, "n_shards", 1))
        self.rows = server.plan.wire_layout().total_rows

    def refresh(self, versions: Sequence[int]):
        server = self.server
        if server.stopped \
                and tuple(versions) == tuple(server.shard_versions()):
            # Caught up with the FINAL weights — only now is "stopped"
            # allowed to freeze the replica (stopping at an older
            # vector would serve pre-final parameters forever).
            return None
        d = server.pull_delta(self.replica_id, tuple(versions))
        regions = [(int(j), np.asarray(r))
                   for j, r in zip(d.shards, d.regions)]
        return tuple(d.versions), regions, int(server.version), d.full

    def live_version(self) -> int:
        """The server's version RIGHT NOW (in-heap read) — what the
        freshness property tests measure admission staleness against."""
        return int(self.server.version)


class ParamSubscriber:
    """The resident packed buffer + its freshness state machine.

    Thread-safe: the background ``Refresher`` patches the buffer while
    decode threads snapshot it and block in ``wait_fresh``.  The
    resident copy starts at the bootstrap vector ``(-1,) * n_shards``
    (dominated by everything, so the first refresh is the full
    snapshot) and is patched region-by-region in place — steady-state
    refresh bytes are proportional to what changed, never model size.
    """

    def __init__(self, subscription: Subscription, layout, *,
                 replica_id: int = -1):
        self.sub = subscription
        self.replica_id = int(replica_id)
        self.layout = layout
        self._buf = np.zeros((layout.total_rows, WIRE_LANES), layout.dtype)
        self._row_start = layout.shard_row_start
        self._cond = threading.Condition()
        self.versions: Tuple[int, ...] = (-1,) * subscription.n_shards
        #: Server version at the LAST reply (what staleness trails).
        self.server_version = int(getattr(subscription,
                                          "initial_version", 0))
        self.stopped = False
        self.refreshes = 0
        self.full_refreshes = 0
        self.blocks = 0
        #: Set by ``wait_fresh`` to demand an out-of-cadence refresh.
        self.refresh_needed = threading.Event()

    # -- refresh (Refresher thread / admission-forced) -------------------
    def refresh(self) -> bool:
        """One delta pull into the resident buffer.  Returns False once
        the server has stopped (the resident copy is then final)."""
        t0 = TRACE.now() if TRACE.enabled else 0.0
        try:
            out = self.sub.refresh(self.versions)
        except Exception:
            out = None  # dead transport == stopped server for a replica
        with self._cond:
            if out is None:
                self.stopped = True
                self._cond.notify_all()
                return False
            versions, patches, server_version, full = out
            if len(versions) != len(self.versions):
                # Live reshard: the server's shard arity changed, and
                # the reply is a full snapshot in the NEW wire layout.
                # Rebuild the resident buffer and the row starts from
                # the reply itself — regions arrive in shard order, so
                # the running sum of their row counts IS the new
                # ``shard_row_start`` (a shard absent from a full
                # reply is empty: zero rows).
                n = len(versions)
                rows_by_shard = [0] * n
                for j, region in patches:
                    rows_by_shard[int(j)] = int(region.shape[0])
                starts, acc = [], 0
                for r in rows_by_shard:
                    starts.append(acc)
                    acc += r
                self._row_start = tuple(starts)
                self._buf = np.zeros((acc, WIRE_LANES),
                                     self._buf.dtype)
            for j, region in patches:
                r0 = self._row_start[j]
                self._buf[r0:r0 + region.shape[0]] = region
            self.versions = tuple(int(v) for v in versions)
            self.server_version = max(self.server_version,
                                      int(server_version))
            self.refreshes += 1
            if full:
                self.full_refreshes += 1
            self._cond.notify_all()
        if TRACE.enabled:
            TRACE.span("replica_refresh", t0, worker=self.replica_id,
                       args={"shards": len(patches), "full": bool(full),
                             "staleness": self.staleness()})
        return True

    # -- freshness -------------------------------------------------------
    #: Staleness of a never-refreshed replica: no bound admits it, so
    #: the first decode always waits for the bootstrap full snapshot.
    UNBOOTSTRAPPED = 1 << 30

    def _stale_locked(self) -> int:
        if self.versions and min(self.versions) < 0:
            return self.UNBOOTSTRAPPED
        return max(0, self.server_version - sum(self.versions))

    def staleness(self) -> int:
        """Applied updates the resident copy trails the last-heard
        server version by.  A never-refreshed replica reports
        ``UNBOOTSTRAPPED`` — no bound admits an all-zeros buffer."""
        live = getattr(self.sub, "live_version", None)
        with self._cond:
            if live is not None:
                # In-heap subscription: measure against the server NOW.
                self.server_version = max(self.server_version, live())
            return self._stale_locked()

    def wait_fresh(self, bound: int, timeout: float = 60.0) -> int:
        """The admission gate: block until the resident buffer is
        within ``bound`` applied updates of the server (or the server
        stopped — frozen weights are final, hence fresh).  Returns the
        staleness admitted at.  The serving mirror of the training
        SSP gate: there a too-fast worker blocks until stragglers
        catch up; here a too-stale replica blocks until its own
        refresh does."""
        stale = self.staleness()
        if stale <= bound or self.stopped:
            return 0 if self.stopped else stale
        t0 = TRACE.now() if TRACE.enabled else 0.0
        self.blocks += 1
        deadline = timeout
        with self._cond:
            while not self.stopped:
                self.refresh_needed.set()  # nudge the Refresher NOW
                stale = self._stale_locked()
                if stale <= bound:
                    break
                if not self._cond.wait(timeout=0.25):
                    deadline -= 0.25
                    if deadline <= 0:
                        raise TimeoutError(
                            f"replica {self.replica_id} stale by "
                            f"{stale} > bound {bound} and no refresh "
                            f"landed within {timeout}s")
            admitted = 0 if self.stopped else stale
        if TRACE.enabled:
            TRACE.span("staleness_block", t0, worker=self.replica_id,
                       args={"bound": bound, "admitted": admitted})
        return admitted

    def snapshot(self):
        """A consistent ``(buffer copy, aggregate version)`` pair taken
        under the lock (the refresher patches in place, so decode must
        not alias the live buffer — and the version must describe THIS
        copy, not whatever landed after)."""
        with self._cond:
            return self._buf.copy(), max(0, sum(self.versions))

    @property
    def version(self) -> int:
        """Aggregate version of the resident copy (sum of the vector,
        clamped at 0 pre-bootstrap)."""
        return max(0, sum(self.versions))


class Refresher(threading.Thread):
    """Background refresh loop: one delta pull every
    ``refresh_every_s``, sooner whenever the admission gate demands
    one.  Exits when the server stops or ``stop()`` is called."""

    def __init__(self, subscriber: ParamSubscriber,
                 refresh_every_s: float):
        super().__init__(daemon=True,
                         name=f"replica-refresh-{subscriber.replica_id}")
        self.subscriber = subscriber
        self.every = float(refresh_every_s)
        # NOT named _stop: threading.Thread owns a private _stop method
        # that join() calls internally.
        self._halt = threading.Event()

    def run(self) -> None:
        sub = self.subscriber
        while not self._halt.is_set():
            if not sub.refresh():
                return  # server stopped: the resident copy is final
            sub.refresh_needed.clear()
            # Sleep the cadence, but wake immediately on demand.
            if sub.refresh_needed.wait(timeout=self.every):
                continue

    def stop(self, join: bool = True) -> None:
        self._halt.set()
        self.subscriber.refresh_needed.set()
        if join and self.is_alive():
            self.join(timeout=10.0)


def bootstrap_versions(n_shards: int) -> Tuple[int, ...]:
    """The pre-subscription vector: dominated by any server state, so
    the first refresh is always the full snapshot."""
    return (-1,) * int(n_shards)


__all__ = [
    "DirectSubscription",
    "ParamSubscriber",
    "Refresher",
    "Subscription",
    "TransportSubscription",
    "bootstrap_versions",
]
