"""repro.serve — online serving replicas fed by version-delta pulls.

Train and serve the SAME parameters: N replica processes subscribe to
the live parameter server (``MSG_SUB`` — no barrier seat), keep a
resident packed wire buffer fresh through ``MSG_PULL_DELTA`` refreshes
(bytes proportional to change), and decode continuously-batched
requests behind an SSP-style admission gate — a replica trailing the
server by more than ``serve.staleness_bound`` applied updates blocks
until its refresh lands.

Drive it declaratively through ``repro.api`` (the ``serve`` block on
``RunSpec``) or assemble the pieces directly:

    from repro.serve import (BatchQueue, Decoder, ParamSubscriber,
                             Refresher, ReplicaWorker)

Protocol and contract details: ``src/repro/serve/README.md``.
"""

from repro.serve.batching import BatchQueue, DecodeRequest
from repro.serve.engine import (
    Decoder,
    ReplicaPool,
    ReplicaResult,
    ReplicaTask,
    ReplicaWorker,
    aggregate_serve,
    drive_replica,
    legal_fraction,
    raise_on_replica_failure,
)
from repro.serve.replica import (
    DirectSubscription,
    ParamSubscriber,
    Refresher,
    Subscription,
    TransportSubscription,
    bootstrap_versions,
)

__all__ = [
    "BatchQueue",
    "DecodeRequest",
    "Decoder",
    "DirectSubscription",
    "ParamSubscriber",
    "Refresher",
    "ReplicaPool",
    "ReplicaResult",
    "ReplicaTask",
    "ReplicaWorker",
    "Subscription",
    "TransportSubscription",
    "aggregate_serve",
    "bootstrap_versions",
    "drive_replica",
    "legal_fraction",
    "raise_on_replica_failure",
]
