"""The serving engine: decode requests against the live resident buffer.

Three layers, composed the same way for process replicas (transport)
and thread replicas (in-heap):

  * ``Decoder`` — greedy continuation over the packed wire buffer.
    All jit objects are built ONCE per replica (fixed ``max_batch`` /
    ``prompt_len`` / ``max_new`` shapes, short batches padded up), so
    after the first batch every decode is compile-free — the seed-era
    driver re-jitted per call and paid tracing on every request.
  * ``ReplicaWorker`` — the serve loop: take a batch from the
    ``BatchQueue``, hold it at the ``wait_fresh`` admission gate until
    the resident buffer is within ``serve.staleness_bound`` of the
    server, snapshot buffer+version atomically, decode, complete each
    request with its latency / admitted staleness / served version.
  * ``ReplicaPool`` / ``_replica_main`` — spawn-and-join plumbing that
    mirrors ``launch.proc_pool``: replica ids start at
    ``n_workers`` (their transport slots sit after the trainers'), a
    ``ReplicaTask`` crosses the spawn boundary, weights never do.

Replicas drive themselves closed-loop: each generates its own Markov
prompts (deterministic in ``(data_seed, replica_id, request)``) and
scores the legal-successor fraction of what it decoded — the same
language-quality probe the training e2e tests use, now measured on
parameters that are mutating underneath the decoder.
"""

from __future__ import annotations

import dataclasses
import multiprocessing
import os
import threading
import time
import traceback
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.obs.trace import TRACE
from repro.serve.batching import BatchQueue, DecodeRequest
from repro.serve.replica import ParamSubscriber, Refresher
from repro.wireformat import WIRE_LANES


class Decoder:
    """Greedy decode over a packed wire buffer, jitted once.

    ``decode(wire_host, prompts)`` unpacks the buffer into the model
    tree and continues every prompt by ``max_new`` greedy tokens.
    Shapes are pinned at construction: prompts are ``(max_batch,
    prompt_len)`` (short batches padded by repeating the last row) and
    every jit call sees identical shapes, so compilation happens
    exactly once per replica lifetime.
    """

    def __init__(self, cfg, plan, *, prompt_len: int, max_new: int,
                 max_batch: int):
        import jax
        import jax.numpy as jnp

        from repro.models import registry, transformer

        if cfg.family == "audio":
            raise ValueError(
                "audio family serving is not supported: its decode "
                "path needs encoder frames, not token prompts")
        self.cfg = cfg
        self.plan = plan
        self.rows = plan.wire_layout().total_rows
        self.prompt_len = int(prompt_len)
        self.max_new = int(max_new)
        self.max_batch = int(max_batch)
        self._jnp = jnp
        fam = registry.family(cfg)
        total = self.prompt_len + self.max_new

        self._unpack = jax.jit(lambda w: plan.unpack(w))
        self._recurrent = cfg.family not in ("dense", "moe", "vlm")
        if not self._recurrent:
            def _prefill(p, toks):
                logits, cache = transformer.forward_prefill(cfg, p, toks)
                cache = {k: jnp.pad(
                    v, ((0, 0), (0, 0), (0, total - v.shape[2]),
                        (0, 0), (0, 0)))
                    for k, v in cache.items()}
                return logits[:, -1], cache
            self._prefill = jax.jit(_prefill)
        else:
            self._init_state = lambda b: fam.init_state(cfg, b, total)
        self._step = jax.jit(
            lambda p, t, c, i: fam.decode_fn(cfg, p, t, c, i))

    def rebuilt(self, n_shards: int) -> "Decoder":
        """A fresh decoder for the same model at a new shard arity —
        the serve loop swaps to this when a live reshard changes the
        resident buffer's wire layout.  Only ``_unpack`` genuinely
        re-traces; the prefill/step jits hit the compile cache."""
        return Decoder(self.cfg, self.plan.rebuild(n_shards),
                       prompt_len=self.prompt_len, max_new=self.max_new,
                       max_batch=self.max_batch)

    def warmup(self) -> None:
        """Compile every jit against a zeros buffer BEFORE the serve
        loop opens: request latency then measures decode, not trace
        time (the compile would otherwise land on the first batch's
        p99)."""
        layout = self.plan.wire_layout()
        wire = np.zeros((layout.total_rows, WIRE_LANES), layout.dtype)
        prompts = np.zeros((self.max_batch, self.prompt_len), np.int32)
        self.decode(wire, prompts)

    def decode(self, wire_host: np.ndarray,
               prompts: np.ndarray) -> np.ndarray:
        """(b, prompt_len) int32 prompts -> (b, max_new) greedy ids."""
        jnp = self._jnp
        b = prompts.shape[0]
        if prompts.shape != (b, self.prompt_len) or b > self.max_batch:
            raise ValueError(
                f"prompts {prompts.shape} do not fit this decoder "
                f"(<= {self.max_batch} rows of {self.prompt_len})")
        if b < self.max_batch:  # pad: jit shapes stay pinned
            pad = np.repeat(prompts[-1:], self.max_batch - b, axis=0)
            prompts = np.concatenate([prompts, pad], axis=0)
        toks = jnp.asarray(prompts, jnp.int32)
        # jnp.array COPIES — the resident buffer mutates under the
        # refresher, and on CPU asarray may alias host memory.
        params = self._unpack(jnp.array(wire_host))

        if not self._recurrent:
            last, cache = self._prefill(params, toks)
            pos = self.prompt_len
        else:
            cache = self._init_state(self.max_batch)
            last = None
            for i in range(self.prompt_len):
                last, cache = self._step(params, toks[:, i:i + 1], cache,
                                         jnp.int32(i))
                last = last[:, -1]
            pos = self.prompt_len
        next_tok = jnp.argmax(last, axis=-1)[:, None]
        out = [next_tok]
        for j in range(self.max_new - 1):
            logits, cache = self._step(params, next_tok, cache,
                                       jnp.int32(pos + j))
            next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(next_tok)
        return np.asarray(jnp.concatenate(out, axis=1))[:b]


@dataclasses.dataclass
class ReplicaResult:
    """What one replica hands back when its serve loop drains."""

    replica_id: int
    served: int = 0                 # requests completed
    batches: int = 0                # decode calls
    violations: int = 0             # admissions with staleness > bound
    blocks: int = 0                 # admission-gate stalls
    refreshes: int = 0              # delta pulls that landed
    full_refreshes: int = 0         # of which carried the full snapshot
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    staleness_values: List[int] = dataclasses.field(default_factory=list)
    served_versions: List[int] = dataclasses.field(default_factory=list)
    legal_fraction: float = 0.0     # Markov-legal generated transitions
    span_s: float = 0.0             # first submit -> last completion
    error: Optional[str] = None
    exitcode: Optional[int] = None


class ReplicaWorker:
    """The serve loop around one queue + one subscriber + one decoder."""

    def __init__(self, replica_id: int, subscriber: ParamSubscriber,
                 queue: BatchQueue, decoder: Decoder, *,
                 staleness_bound: int, batch_window_ms: float,
                 max_batch: int):
        self.replica_id = int(replica_id)
        self.subscriber = subscriber
        self.queue = queue
        self.decoder = decoder
        self.staleness_bound = int(staleness_bound)
        self.window_s = float(batch_window_ms) / 1e3
        self.max_batch = int(max_batch)

    def serve(self) -> ReplicaResult:
        res = ReplicaResult(self.replica_id)
        sub = self.subscriber
        t_start = time.perf_counter()
        while True:
            batch = self.queue.next_batch(self.max_batch, self.window_s)
            if batch is None:
                break
            # The admission gate: blocks until the resident buffer is
            # within bound (or the server stopped — frozen weights).
            staleness = sub.wait_fresh(self.staleness_bound)
            wire, version = sub.snapshot()
            for _ in range(4):  # bounded: re-snapshot if a reshard races
                if wire.shape[0] == self.decoder.rows:
                    break
                # Live reshard landed between batches: the resident
                # buffer is now in a new wire layout.  Re-derive the
                # decode plan at the subscriber's new arity; weights
                # occupy the same canonical element space, so the
                # rebuilt unpack yields the same parameter tree.
                self.decoder = self.decoder.rebuilt(len(sub.versions))
                wire, version = sub.snapshot()
            t0 = TRACE.now() if TRACE.enabled else 0.0
            prompts = np.stack([r.prompt for r in batch]).astype(np.int32)
            tokens = self.decoder.decode(wire, prompts)
            if TRACE.enabled:
                TRACE.span("decode_batch", t0, worker=self.replica_id,
                           args={"batch": len(batch),
                                 "staleness": staleness,
                                 "version": version})
            done_t = time.perf_counter()
            for i, r in enumerate(batch):
                r.tokens = tokens[i]
                r.latency_s = done_t - r.enqueue_t
                r.staleness = staleness
                r.version = version
                r.done.set()
                res.latencies_s.append(r.latency_s)
            res.served += len(batch)
            res.batches += 1
            res.staleness_values.append(staleness)
            res.served_versions.append(version)
            if staleness > self.staleness_bound:
                res.violations += 1  # the gate failed: count it loudly
        res.blocks = sub.blocks
        res.refreshes = sub.refreshes
        res.full_refreshes = sub.full_refreshes
        res.span_s = time.perf_counter() - t_start
        return res


def legal_fraction(chain, prompts: np.ndarray,
                   generated: np.ndarray) -> float:
    """Fraction of generated transitions that are legal successors in
    the Markov chain — 1.0 for a trained model, ~branching/vocab for
    random weights."""
    succ = [set(row) for row in np.asarray(chain.successors)]
    legal = total = 0
    for p_row, g_row in zip(prompts, generated):
        prev = int(p_row[-1])
        for tok in g_row:
            tok = int(tok)
            legal += tok in succ[prev]
            total += 1
            prev = tok
    return legal / max(1, total)


def drive_replica(worker: ReplicaWorker, chain, *, requests: int,
                  prompt_len: int, pace_s: float = 0.0,
                  start_at_version: int = 0) -> ReplicaResult:
    """Run one replica closed-loop: a producer thread submits
    ``requests`` deterministic Markov prompts (lightly paced so the
    linger window sees arrivals, not one pre-filled queue), the serve
    loop drains them, and the result is scored for language legality.

    ``start_at_version`` holds the request stream back until the
    server has applied that many updates (or stopped) — how a run
    guarantees serving genuinely overlaps training instead of draining
    against the initial weights while the trainers are still
    compiling."""
    queue = worker.queue
    rid = worker.replica_id
    sub = worker.subscriber
    while sub.server_version < start_at_version and not sub.stopped:
        sub.staleness()  # refreshes the live view on in-heap subs
        time.sleep(0.02)
    reqs: List[DecodeRequest] = []

    def produce() -> None:
        for i in range(requests):
            row = chain.sample_rows(i, np.array([rid]))[0]
            r = DecodeRequest(request_id=i,
                              prompt=row[:prompt_len].astype(np.int32),
                              enqueue_t=time.perf_counter())
            reqs.append(r)
            queue.submit(r)
            if pace_s > 0:
                time.sleep(pace_s)
        queue.close()

    producer = threading.Thread(target=produce, daemon=True,
                                name=f"replica-driver-{rid}")
    producer.start()
    result = worker.serve()
    producer.join(timeout=30.0)
    done = [r for r in reqs if r.tokens is not None]
    if done:
        result.legal_fraction = legal_fraction(
            chain,
            np.stack([r.prompt for r in done]),
            np.stack([r.tokens for r in done]))
    return result


# -- spawn plumbing (mirrors launch.proc_pool) ---------------------------

@dataclasses.dataclass(frozen=True)
class ReplicaTask:
    """Everything a spawned replica needs; picklable and small —
    weights arrive over the transport, never the spawn boundary."""

    arch: str
    n_shards: int
    smoke: bool = True
    kernels: str = "auto"
    compress: str = "none"
    requests: int = 32
    request_every_ms: float = 0.0
    start_at_version: int = 0
    prompt_len: int = 16
    max_new: int = 8
    max_batch: int = 8
    batch_window_ms: float = 2.0
    staleness_bound: int = 4
    refresh_every_s: float = 0.05
    data_seed: int = 0
    trace: bool = False
    trace_spill: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_spec(cls, spec, *, trace_spill: str = "") -> "ReplicaTask":
        return cls(arch=spec.model.arch,
                   n_shards=max(1, spec.ps.shards),
                   smoke=spec.model.smoke,
                   kernels=spec.model.kernels,
                   compress=("int8" if spec.wire.compression == "int8"
                             else "none"),
                   requests=spec.serve.requests,
                   request_every_ms=spec.serve.request_every_ms,
                   start_at_version=spec.serve.start_at_version,
                   prompt_len=spec.serve.prompt_len,
                   max_new=spec.serve.max_new,
                   max_batch=spec.serve.max_batch,
                   batch_window_ms=spec.serve.batch_window_ms,
                   staleness_bound=spec.serve.staleness_bound,
                   refresh_every_s=spec.serve.refresh_every_s,
                   data_seed=spec.data.seed,
                   trace=bool(getattr(spec, "obs", None)
                              and spec.obs.trace),
                   trace_spill=trace_spill)


def _replica_main(task: Dict[str, Any], address, replica_id: int,
                  queue) -> None:
    """Entry point of one spawned serving replica process."""
    result = ReplicaResult(replica_id)
    try:
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import jax

        from repro.configs import get_config, get_smoke_config
        from repro.data.synthetic import DataConfig, MarkovLM
        from repro.models import registry
        from repro.ps.sharded.plan import build_shard_plan
        from repro.serve.replica import TransportSubscription
        from repro.transport import connect

        cfg = (get_smoke_config(task["arch"]) if task["smoke"]
               else get_config(task["arch"]))
        if task.get("kernels", "auto") != cfg.kernels:
            cfg = dataclasses.replace(cfg, kernels=task["kernels"])
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        plan = build_shard_plan(params, task["n_shards"])
        layout = plan.wire_layout()
        del params  # the live weights come over the wire

        tracer = spill_fh = None
        if task.get("trace"):
            from repro.obs.trace import TRACE as tracer
            tracer.enable(source=f"w{replica_id}")
            if task.get("trace_spill"):
                os.makedirs(task["trace_spill"], exist_ok=True)
                spill_fh = open(os.path.join(task["trace_spill"],
                                             f"w{replica_id}.jsonl"),
                                "a", encoding="utf-8")

        client = connect(address, replica_id, compress=task["compress"])
        sub = TransportSubscription(client, task["n_shards"])
        if sub.rows != layout.total_rows:
            raise ValueError(
                f"server wire layout has {sub.rows} rows, local plan "
                f"derives {layout.total_rows} — replica task out of "
                "sync with server")
        subscriber = ParamSubscriber(sub, layout, replica_id=replica_id)
        refresher = Refresher(subscriber, task["refresh_every_s"])
        refresher.start()

        decoder = Decoder(cfg, plan, prompt_len=task["prompt_len"],
                          max_new=task["max_new"],
                          max_batch=task["max_batch"])
        decoder.warmup()  # compile before the first real request
        chain = MarkovLM(DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=task["prompt_len"] + task["max_new"],
            global_batch=1,
            seed=task["data_seed"] + 1000 + replica_id))
        worker = ReplicaWorker(
            replica_id, subscriber, BatchQueue(), decoder,
            staleness_bound=task["staleness_bound"],
            batch_window_ms=task["batch_window_ms"],
            max_batch=task["max_batch"])
        try:
            result = drive_replica(
                worker, chain, requests=task["requests"],
                prompt_len=task["prompt_len"],
                pace_s=task.get("request_every_ms", 0.0) / 1e3,
                start_at_version=task.get("start_at_version", 0))
        finally:
            refresher.stop()
            if tracer is not None:
                events = tracer.drain()
                if events and spill_fh is not None:
                    import json
                    for e in events:
                        spill_fh.write(json.dumps(e,
                                                  separators=(",", ":")))
                        spill_fh.write("\n")
                    spill_fh.flush()
                if events:
                    try:
                        client.send_trace(events)
                    except Exception:
                        pass  # server gone — the spill still has them
            sub.close()
            if spill_fh is not None:
                spill_fh.close()
        queue.put(result)
    except BaseException:
        result.error = traceback.format_exc()
        queue.put(result)
        raise


class ReplicaPool:
    """Spawn/join R serving replicas on transport slots starting at
    ``first_id`` (= the trainer count: workers take 0..W-1, replicas
    W..W+R-1 — one shmem segment / tcp connection each)."""

    def __init__(self, address, task: ReplicaTask, n_replicas: int, *,
                 first_id: int, mp_context: str = "spawn"):
        self.address = address
        self.task = task
        self.n_replicas = int(n_replicas)
        self.first_id = int(first_id)
        self._ctx = multiprocessing.get_context(mp_context)
        self._queue = self._ctx.Queue()
        self.procs: List[multiprocessing.Process] = []

    def start(self) -> None:
        task = self.task.to_dict()
        for i in range(self.n_replicas):
            rid = self.first_id + i
            p = self._ctx.Process(
                target=_replica_main,
                args=(task, self.address, rid, self._queue),
                name=f"ps-serve-replica-{rid}", daemon=True)
            p.start()
            self.procs.append(p)

    def join(self, timeout: float = 900.0, *,
             endpoint=None) -> List[ReplicaResult]:
        deadline = time.monotonic() + timeout
        reported = set()
        while time.monotonic() < deadline:
            alive = False
            for i, p in enumerate(self.procs):
                rid = self.first_id + i
                if p.is_alive():
                    alive = True
                elif p.exitcode not in (0, None) and rid not in reported:
                    if endpoint is not None:
                        endpoint.on_disconnect(rid)  # unsubscribe only
                    reported.add(rid)
            if not alive:
                break
            time.sleep(0.05)
        by_id: Dict[int, ReplicaResult] = {}
        while not self._queue.empty():
            r = self._queue.get_nowait()
            by_id[r.replica_id] = r
        results = []
        for i, p in enumerate(self.procs):
            rid = self.first_id + i
            if p.is_alive():
                p.terminate()
                p.join(timeout=5.0)
            r = by_id.get(rid) or ReplicaResult(
                rid, error="no result (killed or timed out)")
            r.exitcode = p.exitcode
            results.append(r)
        return results

    def terminate(self) -> None:
        for p in self.procs:
            if p.is_alive():
                p.terminate()
        for p in self.procs:
            p.join(timeout=5.0)


def raise_on_replica_failure(results: Sequence[ReplicaResult]) -> None:
    failed = [r for r in results if r.error]
    if failed:
        msgs = "\n".join(f"-- replica {r.replica_id} "
                         f"(exit {r.exitcode}) --\n{r.error}"
                         for r in failed)
        raise RuntimeError(f"{len(failed)} replica process(es) failed:\n"
                           f"{msgs}")


# -- aggregation ----------------------------------------------------------

def _percentile(values: Sequence[float], q: float) -> float:
    if not values:
        return 0.0
    xs = sorted(values)
    return xs[min(len(xs) - 1, int(round(q * (len(xs) - 1))))]


def aggregate_serve(results: Sequence[ReplicaResult]) -> Dict[str, Any]:
    """One uniform serve-metrics dict from per-replica results — the
    shape ``session.metrics()['serve']``, the e2e tests, and
    ``benchmarks/serving.py`` all share."""
    results = [r for r in results if r is not None]
    lat = [s for r in results for s in r.latencies_s]
    stale = [s for r in results for s in r.staleness_values]
    versions = [v for r in results for v in r.served_versions]
    hist: Dict[str, int] = {}
    for s in stale:
        hist[str(s)] = hist.get(str(s), 0) + 1
    span = max((r.span_s for r in results), default=0.0)
    served = sum(r.served for r in results)
    return {
        "replicas": len(results),
        "requests": served,
        "batches": sum(r.batches for r in results),
        "violations": sum(r.violations for r in results),
        "blocks": sum(r.blocks for r in results),
        "refreshes": sum(r.refreshes for r in results),
        "full_refreshes": sum(r.full_refreshes for r in results),
        "requests_per_s": served / span if span > 0 else 0.0,
        "p50_ms": _percentile(lat, 0.50) * 1e3,
        "p99_ms": _percentile(lat, 0.99) * 1e3,
        "staleness_hist": hist,
        "staleness_max": max(stale, default=0),
        "version_min": min(versions, default=-1),
        "version_max": max(versions, default=-1),
        "legal_fraction": (sum(r.legal_fraction for r in results)
                           / len(results)) if results else 0.0,
    }


__all__ = [
    "Decoder",
    "ReplicaPool",
    "ReplicaResult",
    "ReplicaTask",
    "ReplicaWorker",
    "aggregate_serve",
    "drive_replica",
    "legal_fraction",
    "raise_on_replica_failure",
]
