"""Checkpointing: atomic, async, keep-K, restart."""

from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
