"""Checkpoint manager: atomic, async, keep-K, restart-friendly.

Layout:
    <dir>/step_000123/           (atomic: written as .tmp_, then renamed)
        manifest.json            leaf paths + shapes + dtypes + extras
        arr_00000.npy ...        one .npy per pytree leaf

Guarantees:
  * atomicity — a crash mid-save never corrupts the latest checkpoint
    (readers only see fully-renamed directories); leftover ``.tmp_``
    directories from a crash are garbage-collected on construction and
    ``steps()``/``latest_step`` skip torn snapshots,
  * async — ``save`` returns immediately; the writer thread serializes
    host-transferred arrays so the train loop never blocks on disk; a
    failed async write re-raises on the NEXT ``save()``/``wait()``
    (synchronous saves raise at the call site),
  * keep-K garbage collection,
  * restart — ``latest_step`` + ``restore`` rebuild (params, opt_state,
    DSSP pipeline state, data cursor, controller state) exactly.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return named, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)
        self._gc_tmp()

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree: Any,
             extras: Optional[Dict[str, Any]] = None) -> None:
        named, _ = _flatten(tree)
        # transfer to host *now* (cheap np views) so the step can proceed
        host = [(name, np.asarray(leaf)) for name, leaf in named]
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write_async, args=(step, host, extras or {}),
                daemon=True)
            self._thread.start()
        else:
            # Sync saves fail AT THE CALL SITE — routing them through
            # self._error would swallow the exception until a later
            # wait() a synchronous caller has no reason to make.
            self._write(step, host, extras or {})

    def wait(self) -> None:
        """Block until the in-flight save lands (and re-raise its error)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write_async(self, step: int, host,
                     extras: Dict[str, Any]) -> None:
        """Writer-thread wrapper: park the failure for the next
        ``save()``/``wait()`` to re-raise on the caller's thread."""
        try:
            self._write(step, host, extras)
        except BaseException as e:
            self._error = e

    def _write(self, step: int, host, extras: Dict[str, Any]) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp_"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extras": extras, "leaves": []}
        for i, (name, arr) in enumerate(host):
            fname = f"arr_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"name": name, "file": fname,
                 "shape": list(arr.shape), "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # the atomic commit point
        self._gc()

    # -------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            if not d.startswith("step_") or d.endswith(".tmp_"):
                continue
            # The rename commit point makes a manifest-less step_ dir
            # impossible in normal operation, but a restore must never
            # pick a torn snapshot some foreign writer left behind.
            if not os.path.exists(os.path.join(self.directory, d,
                                               "manifest.json")):
                continue
            try:
                out.append(int(d[5:]))
            except ValueError:
                pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def peek_extras(self, step: int) -> Dict[str, Any]:
        """The extras dict of one snapshot WITHOUT loading its arrays —
        restore decisions (e.g. "must the server reshard to this
        snapshot's arity first?") read this before building the
        template tree that ``restore`` validates shapes against."""
        with open(os.path.join(self._step_dir(step),
                               "manifest.json")) as f:
            return json.load(f)["extras"]

    def restore(self, step: int, like: Any,
                ) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``like`` (names must match)."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {l["name"]: l for l in manifest["leaves"]}
        named, treedef = _flatten(like)
        leaves = []
        for name, ref_leaf in named:
            entry = by_name.get(name)
            if entry is None:
                raise KeyError(f"checkpoint {step} missing leaf {name}")
            arr = np.load(os.path.join(d, entry["file"]))
            if list(arr.shape) != list(np.shape(ref_leaf)):
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != "
                    f"expected {np.shape(ref_leaf)}")
            leaves.append(arr)
        return treedef.unflatten(leaves), manifest["extras"]

    def restore_latest(self, like: Any,
                       ) -> Optional[Tuple[int, Any, Dict[str, Any]]]:
        step = self.latest_step()
        if step is None:
            return None
        tree, extras = self.restore(step, like)
        return step, tree, extras

    # ------------------------------------------------------------------ gc
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def _gc_tmp(self) -> None:
        """Drop ``.tmp_`` directories a crash-mid-save left behind: they
        are torn by construction (the rename never happened) and must
        never shadow or outlive real snapshots."""
        for d in os.listdir(self.directory):
            if d.startswith("step_") and d.endswith(".tmp_"):
                shutil.rmtree(os.path.join(self.directory, d),
                              ignore_errors=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:09d}")
