"""Hot-path event counters for the push/pull wire format.

The packed-wire acceptance contract ("one packed push performs zero
host-side per-leaf concatenations on the server, and at most one
``pallas_call`` per shard for apply plus one for compression") is
asserted by counting the events themselves, not by timing: wall time on
a CPU interpret-mode container says nothing about HBM traffic, but the
*number* of pack/unpack/concat/launch events per push is
backend-independent and exactly the quantity the packed format
eliminates.

Instrumented sites:

  * ``leaf_concats``  — every ``jnp.concatenate`` over per-leaf pieces
    (``ShardPlan.assemble``, ``pack_shard`` with >1 leaf),
  * ``packs`` / ``unpacks`` — pytree <-> packed-buffer boundary
    crossings (``pack_shard`` / ``unpack_shard`` and the plan-level
    ``pack`` / ``unpack``),
  * ``gathers``       — wire-permutation gathers (one per plan-level
    pack/unpack; the packed path's only data-movement op),
  * ``pallas_calls``  — kernel launches (``fused_update``, the fused
    compressors),
  * ``apply_launches_saved`` — contributions folded into an already-
    counted ``fused_update_batched`` launch by the coalescing window,
  * ``delta_bytes_tx`` / ``full_pull_bytes_avoided`` — version-delta
    pull accounting (``pull_delta`` on either server).

Counters are plain ints bumped under the GIL — cheap enough to stay on
permanently, precise enough for the single-threaded benchmark and test
probes that read them (multi-threaded runs should treat the numbers as
approximate).
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class _CounterBase:
    """Shared reset/snapshot/delta over a dataclass of int fields."""

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)

    def snapshot(self) -> Dict[str, int]:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}

    def delta(self, before: Dict[str, int]) -> Dict[str, int]:
        return {k: v - before.get(k, 0) for k, v in self.snapshot().items()}


@dataclasses.dataclass
class HotPathCounters(_CounterBase):
    leaf_concats: int = 0
    packs: int = 0
    unpacks: int = 0
    gathers: int = 0
    pallas_calls: int = 0
    #: Launches the coalesced server apply amortized away: a window of
    #: K contributors folded in ONE ``fused_update_batched`` launch
    #: bumps this by K - 1 (the coalescing contract — launches per
    #: round scale with shards, not shards x workers — is asserted on
    #: ``pallas_calls`` + this).
    apply_launches_saved: int = 0
    #: Bytes actually shipped by version-delta pulls (changed shard
    #: regions only; a full-snapshot fallback counts its full size).
    delta_bytes_tx: int = 0
    #: Bytes a full ``pull_packed`` snapshot would have shipped minus
    #: what the delta actually shipped — the tentpole's "bytes
    #: proportional to change" win, directly benchmarkable.
    full_pull_bytes_avoided: int = 0
    #: Live-reshard accounting (``repro.ft.reshard``): contributions
    #: parked against a mid-migration shard, contributions replayed
    #: onto the new shards after the swap, and whole pushes translated
    #: from a stale epoch's layout.  Zero-loss is asserted as
    #: ``reshard_parked == reshard_replayed`` once a migration settles.
    reshard_parked: int = 0
    reshard_replayed: int = 0
    reshard_translated: int = 0


#: Process-global counters — reset + snapshot around the region of
#: interest (see ``benchmarks/push_pull_latency.py``).
WIRE = HotPathCounters()


@dataclasses.dataclass
class TransportCounters(_CounterBase):
    """Bytes-on-the-wire accounting for the frame codec + transports.

    Bumped at the ``repro.wireformat`` encode/decode boundary, so every
    backend (tcp, shmem, the in-memory loopback) is counted the same
    way.  ``header_rejects`` counts frames refused by header validation
    (bad magic/version/dtype, length mismatch, truncation) — the
    failure-path tests and the throughput benchmark read it.
    Per-process like ``WIRE``: a worker process has its own counters.
    """

    frames_tx: int = 0
    frames_rx: int = 0
    bytes_tx: int = 0
    bytes_rx: int = 0
    header_rejects: int = 0


#: Process-global transport counters (see ``repro.wireformat``).
TRANSPORT = TransportCounters()


def snapshot_all() -> Dict[str, Dict[str, int]]:
    """One combined view of every process-global counter group —
    ``session.metrics()`` and the obs metrics snapshots both read this
    instead of enumerating the globals themselves."""
    return {"wire": WIRE.snapshot(), "transport": TRANSPORT.snapshot()}
