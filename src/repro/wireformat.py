"""Shared constants, dtype policy and pack body of the packed wire format.

One source of truth for the lane-aligned (rows, 512) layout that
``ps/sharded/plan.py`` (kernel-free) and the Pallas kernels
(``kernels/fused_update.py``, ``kernels/fused_compress.py``) both
speak — keeping the two sides here means the wire dtype rule, the tile
geometry and the flatten/concat/pad pipeline cannot drift apart between
the tree-split and packed paths.

Kept free of pallas imports so the ps layer stays importable without
the kernel stack (plain jax.numpy is fine — ps already depends on it).
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.perfcount import WIRE

#: Lane width of the packed wire buffer — the Pallas tile's last dim.
WIRE_LANES = 512
#: Sublane multiple shard regions pad to: (8, 512) f32 tiles land exactly.
WIRE_ROWS = 8


def pack_flat(leaves: Sequence[jax.Array], dtype,
              rows: Optional[int] = None) -> jax.Array:
    """Flatten + concatenate ``leaves`` into a (rows, WIRE_LANES) buffer.

    ``rows=None`` pads to the next full lane row (the per-leaf-list
    ``pack_shard`` contract); an explicit ``rows`` pads/pins to that row
    count (a plan's 8-aligned shard region).  Bumps the perfcount
    pack/concat probes — this is THE instrumented pytree->wire crossing.
    """
    WIRE.packs += 1
    flats = [x.reshape(-1).astype(dtype) for x in leaves]
    if len(flats) > 1:
        WIRE.leaf_concats += 1
    flat = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
    target = (rows * WIRE_LANES if rows is not None
              else flat.size + (-flat.size) % WIRE_LANES)
    if target < flat.size:
        raise ValueError(f"{flat.size} elements do not fit in "
                         f"{rows} x {WIRE_LANES} rows")
    if target > flat.size:
        flat = jnp.pad(flat, (0, target - flat.size))
    return flat.reshape(-1, WIRE_LANES)


def resolve_wire_dtype(dtypes: Iterable, default=None) -> Optional[object]:
    """The wire dtype for a collection of leaf dtypes.

    A uniform collection keeps its dtype on the wire (bf16 stays bf16
    bitwise — no silent f32 round-trip); mixed collections promote to
    ``default`` (the caller passes f32, the widest dtype the kernels
    accumulate in).  Empty collections also yield ``default``.
    """
    dts = set(dtypes)
    return dts.pop() if len(dts) == 1 else default
