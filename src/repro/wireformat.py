"""Shared constants, dtype policy, pack body and FRAME codec of the
packed wire format.

One source of truth for the lane-aligned (rows, 512) layout that
``ps/sharded/plan.py`` (kernel-free) and the Pallas kernels
(``kernels/fused_update.py``, ``kernels/fused_compress.py``) both
speak — keeping the two sides here means the wire dtype rule, the tile
geometry and the flatten/concat/pad pipeline cannot drift apart between
the tree-split and packed paths.

This module is also where the packed buffer grows its *serialization
header* for the process-boundary transports (``repro.transport``): a
fixed 44-byte little-endian struct carrying version, message kind,
dtype, flags, worker id, shard id, clock, row count, payload length and
an aux float (loss value / int8 quantization scale).  The same (rows,
512) buffer that a worker's jitted step emits is the frame body — the
one representation from worker JIT step to server Pallas launch, now
across processes.

Import cost matters here: spawned worker/benchmark processes frame
bytes long before they touch an accelerator, so ``jax`` is imported
lazily inside the two functions that need it and the frame codec is
pure ``numpy`` + ``struct``.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.obs.trace import TRACE
from repro.perfcount import TRANSPORT, WIRE

#: Lane width of the packed wire buffer — the Pallas tile's last dim.
WIRE_LANES = 512
#: Sublane multiple shard regions pad to: (8, 512) f32 tiles land exactly.
WIRE_ROWS = 8


def pack_flat(leaves: Sequence, dtype, rows: Optional[int] = None):
    """Flatten + concatenate ``leaves`` into a (rows, WIRE_LANES) buffer.

    ``rows=None`` pads to the next full lane row (the per-leaf-list
    ``pack_shard`` contract); an explicit ``rows`` pads/pins to that row
    count (a plan's 8-aligned shard region).  Bumps the perfcount
    pack/concat probes — this is THE instrumented pytree->wire crossing.
    """
    import jax.numpy as jnp

    WIRE.packs += 1
    flats = [x.reshape(-1).astype(dtype) for x in leaves]
    if len(flats) > 1:
        WIRE.leaf_concats += 1
    flat = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
    target = (rows * WIRE_LANES if rows is not None
              else flat.size + (-flat.size) % WIRE_LANES)
    if target < flat.size:
        raise ValueError(f"{flat.size} elements do not fit in "
                         f"{rows} x {WIRE_LANES} rows")
    if target > flat.size:
        flat = jnp.pad(flat, (0, target - flat.size))
    return flat.reshape(-1, WIRE_LANES)


def resolve_wire_dtype(dtypes: Iterable, default=None) -> Optional[object]:
    """The wire dtype for a collection of leaf dtypes.

    A uniform collection keeps its dtype on the wire (bf16 stays bf16
    bitwise — no silent f32 round-trip); mixed collections promote to
    ``default`` (the caller passes f32, the widest dtype the kernels
    accumulate in).  Empty collections also yield ``default``.
    """
    dts = set(dtypes)
    return dts.pop() if len(dts) == 1 else default


# ======================================================================
# Frame codec — the process-boundary serialization of the packed buffer.
# ======================================================================

#: First bytes of every frame; rejects cross-protocol garbage cheaply.
FRAME_MAGIC = b"DSPW"
#: Bump on any incompatible header/payload change.
FRAME_VERSION = 1

#: Header layout, little-endian, 44 bytes:
#:   magic(4s) version(B) kind(B) dtype(B) flags(B)
#:   worker(i32) shard(i32) clock(i64) rows(u32) payload_len(u64) aux(f64)
HEADER = struct.Struct("<4sBBBBiiqIQd")
HEADER_SIZE = HEADER.size

# -- message kinds ------------------------------------------------------
MSG_HELLO = 1   # worker joins; reply OK carries clock=version, aux=rows
MSG_PULL = 2    # request packed params; reply OK carries the buffer
MSG_PUSH = 3    # packed gradient push; blocks until the policy releases
MSG_LOSS = 4    # record_loss(clock, aux)
MSG_BYE = 5     # worker leaves the barrier group
MSG_STOP = 6    # server-side stop reply (training over / shutdown)
MSG_OK = 7      # generic success reply
MSG_ERR = 8     # error reply; body is a utf-8 message
MSG_ECHO = 9    # payload round-trip diagnostic (health checks + tests)
MSG_PULL_DELTA = 10  # request: body = client's per-shard version vector
MSG_DELTA = 11  # reply: advanced shards' regions + fresh version vector
MSG_TRACE = 12  # worker ring-buffer flush: body = utf-8 JSON event list
MSG_SUB = 13    # replica subscription: like HELLO (reply OK carries
                # clock=version, aux=rows) but takes NO barrier seat —
                # a serving replica must never gate training workers

_KINDS = frozenset((MSG_HELLO, MSG_PULL, MSG_PUSH, MSG_LOSS, MSG_BYE,
                    MSG_STOP, MSG_OK, MSG_ERR, MSG_ECHO,
                    MSG_PULL_DELTA, MSG_DELTA, MSG_TRACE, MSG_SUB))

#: Kinds whose body is NOT one (rows, 512) buffer: MSG_ERR carries a
#: utf-8 message, MSG_PULL_DELTA an int64 version vector, MSG_DELTA the
#: structured multi-region delta body (see ``_encode_delta_body``),
#: MSG_TRACE a JSON-encoded drained event batch (``repro.obs``).
_STRUCTURED_KINDS = frozenset((MSG_ERR, MSG_PULL_DELTA, MSG_DELTA,
                               MSG_TRACE))

# -- flags --------------------------------------------------------------
#: Payload is int8-quantized; dequant scale travels in ``aux`` and the
#: logical (pre-quantization) dtype stays in the header dtype field.
FLAG_INT8 = 0x01
#: DELTA reply is a full-snapshot fallback (client's version vector
#: mismatched) — every non-empty shard's region is in the body.
FLAG_FULL = 0x02

_KNOWN_FLAGS = FLAG_INT8 | FLAG_FULL

# -- dtype codes --------------------------------------------------------
_DTYPE_NAMES = {0: "float32", 1: "bfloat16", 2: "float16", 3: "int8"}
_DTYPE_CODES = {v: k for k, v in _DTYPE_NAMES.items()}

#: Transports size shared buffers / reject hostile lengths with this.
MAX_PAYLOAD = 1 << 31


def np_wire_dtype(name: str) -> np.dtype:
    """Numpy dtype for a wire dtype name (bf16 comes from ml_dtypes,
    which jax depends on — but importing it does not pull in jax)."""
    if name == "bfloat16":
        import ml_dtypes

        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


class FrameError(ValueError):
    """Malformed / truncated / cross-version frame."""


@dataclasses.dataclass
class Frame:
    """One decoded transport message.

    ``payload`` is a host (rows, WIRE_LANES) array in the *logical*
    dtype (int8 frames are dequantized on decode); ``error`` is set for
    ``MSG_ERR`` frames instead.
    """

    kind: int
    worker: int = -1
    shard: int = -1      # -1 = the full wire buffer (no shard routing)
    clock: int = 0
    flags: int = 0
    aux: float = 0.0
    payload: Optional[np.ndarray] = None
    error: str = ""
    #: PULL_DELTA request / DELTA reply: per-shard version vector.
    versions: Optional[Tuple[int, ...]] = None
    #: DELTA reply: [(shard_id, (rows, 512) region), ...] for the
    #: shards that advanced past the request's version vector.
    delta: Optional[Sequence[Tuple[int, np.ndarray]]] = None
    #: TRACE flush: raw utf-8 JSON bytes of a drained event batch (kept
    #: opaque here — the obs collector parses it, the codec just moves it).
    blob: Optional[bytes] = None


def _quantize_int8(arr: np.ndarray) -> Tuple[np.ndarray, float]:
    """Symmetric per-frame linear quantization (transport-level, no
    error feedback — the lossy-but-bitwise-reproducible wire encoding;
    server-side error-feedback compression is ``optim/compression``)."""
    f = np.asarray(arr, np.float32)
    scale = float(max(np.max(np.abs(f)), 1e-12) / 127.0)
    q = np.clip(np.round(f / scale), -127, 127).astype(np.int8)
    return q, scale


def _encode_delta_body(frame: Frame) -> Tuple[bytes, int, int]:
    """DELTA reply body: ``<u32 n_shards> <u32 n_entries>
    <i64 versions[n_shards]>`` then per entry ``<u32 shard> <u32 rows>``
    + the shard's (rows, 512) region bytes.  All regions share one wire
    dtype (the header dtype field); header ``rows`` is the summed
    region rows, so byte accounting stays comparable with full pulls.
    """
    vers = np.asarray(frame.versions if frame.versions is not None
                      else (), "<i8")
    entries = list(frame.delta or ())
    chunks = [struct.pack("<II", vers.size, len(entries)), vers.tobytes()]
    rows, name = 0, None
    for sid, region in entries:
        arr = np.ascontiguousarray(region)
        if arr.ndim != 2 or arr.shape[1] != WIRE_LANES:
            raise FrameError(f"delta region {arr.shape} is not a "
                             f"(rows, {WIRE_LANES}) wire buffer")
        n = np.dtype(arr.dtype).name
        if n not in _DTYPE_CODES:
            raise FrameError(f"dtype {n} has no wire code")
        if name is None:
            name = n
        elif n != name:
            raise FrameError(f"mixed dtypes in delta body ({name}, {n})")
        chunks.append(struct.pack("<II", int(sid), arr.shape[0]))
        chunks.append(arr.tobytes())
        rows += arr.shape[0]
    dtype_code = _DTYPE_CODES[name if name is not None else "float32"]
    return b"".join(chunks), rows, dtype_code


def encode_frame(frame: Frame, compress: str = "none") -> bytes:
    """Frame -> header + body bytes (the length-prefixed unit every
    transport moves).  ``compress='int8'`` quantizes the payload."""
    if frame.kind not in _KINDS:
        raise FrameError(f"unknown message kind {frame.kind}")
    flags = frame.flags
    aux = frame.aux
    if frame.kind == MSG_ERR:
        body = frame.error.encode("utf-8")
        rows, dtype_code = 0, _DTYPE_CODES["int8"]
    elif frame.kind == MSG_PULL_DELTA:
        body = np.asarray(frame.versions if frame.versions is not None
                          else (), "<i8").tobytes()
        rows, dtype_code = 0, _DTYPE_CODES["float32"]
    elif frame.kind == MSG_DELTA:
        body, rows, dtype_code = _encode_delta_body(frame)
    elif frame.kind == MSG_TRACE:
        body = frame.blob or b""
        rows, dtype_code = 0, _DTYPE_CODES["int8"]
    elif frame.payload is None:
        body = b""
        rows, dtype_code = 0, _DTYPE_CODES["float32"]
    else:
        arr = np.ascontiguousarray(frame.payload)
        if arr.ndim != 2 or arr.shape[1] != WIRE_LANES:
            raise FrameError(f"payload {arr.shape} is not a "
                             f"(rows, {WIRE_LANES}) wire buffer")
        name = np.dtype(arr.dtype).name
        if name not in _DTYPE_CODES:
            raise FrameError(f"dtype {name} has no wire code")
        rows, dtype_code = arr.shape[0], _DTYPE_CODES[name]
        if compress not in ("int8", "none", "", None):
            raise FrameError(f"unknown frame compression {compress!r}")
        if compress == "int8" and name != "int8":
            q, aux = _quantize_int8(arr)
            flags |= FLAG_INT8
            body = q.tobytes()
        else:
            # already-int8 buffers ship as-is (dtype code says int8, no
            # FLAG_INT8 — nothing to dequantize on the far side)
            body = arr.tobytes()
    header = HEADER.pack(FRAME_MAGIC, FRAME_VERSION, frame.kind,
                         dtype_code, flags, frame.worker, frame.shard,
                         frame.clock, rows, len(body), aux)
    TRANSPORT.frames_tx += 1
    TRANSPORT.bytes_tx += HEADER_SIZE + len(body)
    if TRACE.enabled and frame.kind != MSG_TRACE:
        # TRACE flushes are not themselves traced — a flush that
        # recorded an event per flush would feed its own ring forever.
        TRACE.instant("frame_tx", worker=frame.worker, shard=frame.shard,
                      args={"kind": frame.kind,
                            "bytes": HEADER_SIZE + len(body)})
    return header + body


def decode_header(buf: bytes) -> Tuple[Frame, int]:
    """Parse + validate the 44-byte header; returns the (payload-less)
    frame and the body length the framing layer must read next.

    Every reject bumps ``TRANSPORT.header_rejects`` — the counter the
    truncated-frame tests and the throughput benchmark read.
    """
    if len(buf) != HEADER_SIZE:
        TRANSPORT.header_rejects += 1
        raise FrameError(f"short header: {len(buf)} of {HEADER_SIZE} bytes")
    (magic, version, kind, dtype_code, flags, worker, shard, clock,
     rows, payload_len, aux) = HEADER.unpack(buf)
    try:
        if magic != FRAME_MAGIC:
            raise FrameError(f"bad magic {magic!r}")
        if version != FRAME_VERSION:
            raise FrameError(f"frame version {version}, "
                             f"expected {FRAME_VERSION}")
        if kind not in _KINDS:
            raise FrameError(f"unknown message kind {kind}")
        if dtype_code not in _DTYPE_NAMES:
            raise FrameError(f"unknown dtype code {dtype_code}")
        if flags & ~_KNOWN_FLAGS:
            raise FrameError(f"unknown flags 0x{flags:02x}")
        if payload_len > MAX_PAYLOAD:
            raise FrameError(f"payload length {payload_len} exceeds "
                             f"{MAX_PAYLOAD}")
        if kind == MSG_PULL_DELTA and payload_len % 8:
            raise FrameError(
                f"PULL_DELTA body of {payload_len} bytes is not an "
                "int64 version vector")
        if kind == MSG_DELTA and payload_len < 8:
            raise FrameError(
                f"DELTA body of {payload_len} bytes is shorter than "
                "its counts header")
        if kind not in _STRUCTURED_KINDS:
            itemsize = (1 if flags & FLAG_INT8
                        else np_wire_dtype(_DTYPE_NAMES[dtype_code]).itemsize)
            if payload_len != rows * WIRE_LANES * itemsize:
                raise FrameError(
                    f"payload length {payload_len} does not match "
                    f"{rows} x {WIRE_LANES} rows of "
                    f"{_DTYPE_NAMES[dtype_code]}"
                    f"{' (int8 on the wire)' if flags & FLAG_INT8 else ''}")
    except FrameError:
        TRANSPORT.header_rejects += 1
        raise
    frame = Frame(kind=kind, worker=worker, shard=shard, clock=clock,
                  flags=flags, aux=aux)
    frame._dtype_name = _DTYPE_NAMES[dtype_code]  # type: ignore[attr-defined]
    frame._rows = rows                            # type: ignore[attr-defined]
    return frame, payload_len


def decode_body(frame: Frame, body) -> Frame:
    """Attach the body to a ``decode_header`` frame.

    ``body`` may be any buffer (bytes or a shared-memory view — parsing
    is in place, no copy for uncompressed frames); int8 frames are
    dequantized into the logical dtype here.
    """
    TRANSPORT.frames_rx += 1
    TRANSPORT.bytes_rx += HEADER_SIZE + len(body)
    if TRACE.enabled and frame.kind != MSG_TRACE:
        TRACE.instant("frame_rx", worker=frame.worker, shard=frame.shard,
                      args={"kind": frame.kind,
                            "bytes": HEADER_SIZE + len(body)})
    if frame.kind == MSG_ERR:
        frame.error = bytes(body).decode("utf-8", "replace")
        return frame
    if frame.kind == MSG_TRACE:
        frame.blob = bytes(body)
        return frame
    if frame.kind == MSG_PULL_DELTA:
        frame.versions = tuple(
            int(v) for v in np.frombuffer(body, "<i8"))
        return frame
    if frame.kind == MSG_DELTA:
        return _decode_delta_body(frame, body)
    rows = frame._rows  # type: ignore[attr-defined]
    if rows == 0:
        return frame
    name = frame._dtype_name  # type: ignore[attr-defined]
    if frame.flags & FLAG_INT8:
        q = np.frombuffer(body, np.int8).reshape(rows, WIRE_LANES)
        frame.payload = (q.astype(np.float32) * np.float32(frame.aux)
                         ).astype(np_wire_dtype(name))
    else:
        frame.payload = np.frombuffer(
            body, np_wire_dtype(name)).reshape(rows, WIRE_LANES)
    return frame


def _decode_delta_body(frame: Frame, body) -> Frame:
    """Parse a DELTA body (see ``_encode_delta_body``).  Regions are
    ``np.frombuffer`` views into ``body`` — in-place for shmem/tcp
    receive buffers, valid as long as the underlying buffer (same
    contract as an uncompressed pull payload)."""
    view = memoryview(body)
    if len(view) < 8:
        raise FrameError("truncated DELTA body: no counts header")
    n_shards, n_entries = struct.unpack_from("<II", view, 0)
    off = 8
    vec_bytes = n_shards * 8
    if len(view) < off + vec_bytes:
        raise FrameError(f"truncated DELTA body: version vector of "
                         f"{n_shards} entries does not fit")
    frame.versions = tuple(
        int(v) for v in np.frombuffer(view[off:off + vec_bytes], "<i8"))
    off += vec_bytes
    dt = np_wire_dtype(frame._dtype_name)  # type: ignore[attr-defined]
    entries = []
    total_rows = 0
    for _ in range(n_entries):
        if len(view) < off + 8:
            raise FrameError("truncated DELTA body: entry header")
        sid, rows = struct.unpack_from("<II", view, off)
        off += 8
        nbytes = rows * WIRE_LANES * dt.itemsize
        if len(view) < off + nbytes:
            raise FrameError(f"truncated DELTA body: shard {sid} region "
                             f"of {rows} rows does not fit")
        entries.append((int(sid),
                        np.frombuffer(view[off:off + nbytes],
                                      dt).reshape(rows, WIRE_LANES)))
        off += nbytes
        total_rows += rows
    if off != len(view):
        raise FrameError(f"DELTA body has {len(view) - off} trailing "
                         "bytes")
    if total_rows != frame._rows:  # type: ignore[attr-defined]
        raise FrameError(
            f"DELTA body rows {total_rows} do not match header rows "
            f"{frame._rows}")  # type: ignore[attr-defined]
    frame.delta = entries
    return frame


def decode_frame(data) -> Frame:
    """One-shot decode of a contiguous header+body buffer."""
    view = memoryview(data)
    frame, payload_len = decode_header(bytes(view[:HEADER_SIZE]))
    if len(view) - HEADER_SIZE != payload_len:
        TRANSPORT.header_rejects += 1
        raise FrameError(f"truncated frame: {len(view) - HEADER_SIZE} of "
                         f"{payload_len} payload bytes")
    return decode_body(frame, view[HEADER_SIZE:])
