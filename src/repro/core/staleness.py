"""Staleness bookkeeping shared by every synchronization policy.

This is the server-side state of Algorithm 1 in the paper:

  * ``t_i``       — number of push requests received from worker ``i`` so far
                    (the worker's *iteration count* as seen by the server).
  * ``A[i][0..1]``— timestamps of the two latest push requests per worker
                    (Algorithm 2's table A).
  * ``r_i``       — extra-iteration credit granted to worker ``i`` beyond the
                    staleness lower bound ``s_L`` (DSSP only).

The tracker is policy-agnostic: BSP/ASP/SSP/DSSP all read from it, only
DSSP writes credits.  All methods are O(#workers) or better and are called
under the server lock, so no internal synchronization is needed.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Tuple


@dataclasses.dataclass
class PushRecord:
    """One push request as seen by the server (for metrics/replay)."""

    worker: int
    iteration: int          # t_p after increment
    timestamp: float        # server-side arrival clock
    staleness: int          # t_p - t_slowest at arrival
    waited: float = 0.0     # seconds the worker was blocked before OK
    credit_used: bool = False   # released via a pre-granted r_p credit


class StalenessTracker:
    """Server-side iteration counts + two-latest-push timestamp table."""

    def __init__(self, workers: Iterable[int]):
        self.workers: List[int] = list(workers)
        if not self.workers:
            raise ValueError("StalenessTracker needs at least one worker")
        self.counts: Dict[int, int] = {w: 0 for w in self.workers}
        # A[i] = (latest_ts, second_latest_ts); NaN = not yet observed.
        self.table: Dict[int, Tuple[float, float]] = {
            w: (math.nan, math.nan) for w in self.workers
        }
        self.credits: Dict[int, int] = {w: 0 for w in self.workers}
        self.history: List[PushRecord] = []

    # -- membership (elastic clusters: workers may join/leave) -------------
    def add_worker(self, w: int) -> None:
        if w in self.counts:
            return
        self.workers.append(w)
        # A joining worker starts at the *slowest* count so it does not
        # stall everyone (it is "caught up by definition" on arrival).
        self.counts[w] = self.slowest_count()
        self.table[w] = (math.nan, math.nan)
        self.credits[w] = 0

    def remove_worker(self, w: int) -> None:
        if w not in self.counts:
            return  # already departed (idempotent for crash paths)
        self.workers.remove(w)
        del self.counts[w], self.table[w], self.credits[w]

    # -- Algorithm 1 bookkeeping -------------------------------------------
    def record_push(self, worker: int, timestamp: float) -> PushRecord:
        """t_p += 1; shift table A; return the record (staleness filled in)."""
        if worker not in self.counts:
            self.add_worker(worker)
        self.counts[worker] += 1
        latest, _ = self.table[worker]
        self.table[worker] = (timestamp, latest)
        rec = PushRecord(
            worker=worker,
            iteration=self.counts[worker],
            timestamp=timestamp,
            staleness=self.counts[worker] - self.slowest_count(),
        )
        self.history.append(rec)
        return rec

    # -- queries -------------------------------------------------------------
    def slowest_count(self) -> int:
        return min(self.counts.values(), default=0)

    def fastest_count(self) -> int:
        return max(self.counts.values(), default=0)

    def slowest_worker(self) -> int:
        return min(self.workers, key=lambda w: (self.counts[w], w))

    def fastest_worker(self) -> int:
        return max(self.workers, key=lambda w: (self.counts[w], -w))

    def is_fastest(self, worker: int) -> bool:
        return self.counts[worker] == self.fastest_count()

    def gap(self, worker: int) -> int:
        """t_p - t_slowest (the staleness of worker's next iteration)."""
        return self.counts[worker] - self.slowest_count()

    def latest_interval(self, worker: int) -> Optional[float]:
        """Length of the latest iteration interval of ``worker`` (Alg. 2 L4-5).

        None until the server has seen two pushes from the worker.
        """
        latest, second = self.table[worker]
        if math.isnan(latest) or math.isnan(second):
            return None
        return latest - second

    def latest_timestamp(self, worker: int) -> Optional[float]:
        ts = self.table[worker][0]
        return None if math.isnan(ts) else ts

    # -- metrics --------------------------------------------------------------
    def staleness_profile(self) -> Dict[int, int]:
        return {w: self.gap(w) for w in self.workers}

    def max_observed_staleness(self) -> int:
        return max((r.staleness for r in self.history), default=0)


def regret_bound_constant(s: int, num_workers: int) -> float:
    """The √(2(s+1)P) factor in the paper's Theorem 1/2 regret bound.

    DSSP with range [s_L, s_U] has the same bound as SSP with s = s_U
    (Theorem 2: substitute s' = s_L + r_max).  Exposed so experiments can
    report the theoretical staleness penalty next to measured throughput.
    """
    if s < 0 or num_workers < 1:
        raise ValueError("staleness must be >= 0 and workers >= 1")
    return math.sqrt(2.0 * (s + 1) * num_workers)


def dssp_effective_bound(s_lower: int, s_upper: int) -> int:
    """Worst-case staleness DSSP can admit = s_U (Theorem 2)."""
    if not 0 <= s_lower <= s_upper:
        raise ValueError(f"need 0 <= s_L <= s_U, got [{s_lower}, {s_upper}]")
    return s_upper
