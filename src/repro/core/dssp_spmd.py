"""DSSP adapted to SPMD TPU pods (DESIGN.md §3.2-3.3).

On a pod there is no parameter server: the gradient all-reduce *is* the
synchronization.  The paper's degree of freedom — how stale may a
contribution be before the system forces a sync — maps to two JAX-native
mechanisms, both bounded by [s_L, s_U] so Theorem 2 carries over:

1. **Delayed gradient application** (within-pod / cross-replica).  The
   gradient computed at step ``t`` enters a ring buffer and is *applied*
   at step ``t + d`` with ``d ∈ [s_L, s_U]`` chosen by the host-side
   controller.  Because step ``t``'s parameter update no longer depends
   on step ``t``'s collective, the runtime can overlap that collective
   with the forward/backward of the following step(s) — the SPMD analogue
   of "the fast worker keeps iterating instead of waiting".  ``d`` is a
   *traced scalar*: changing it between steps does not recompile.

2. **Dynamic-period cross-pod averaging** (local SGD).  Pods are the
   paper's workers; every pod takes ``k`` local steps between cross-pod
   averages, ``k ∈ [s_L, s_U]`` re-chosen at run time from per-pod step
   telemetry via the *same* Algorithm-2 controller.  Implemented with
   ``shard_map`` manual over the 'pod' axis (params carry per-pod values
   between syncs) while 'data'/'model' stay under GSPMD.

The host-side ``DsspScheduleController`` turns measured step/collective
times into (d, k) using the paper's simulated-timestamp argmin.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


Tree = Any


# ------------------------------------------------------- delayed gradients
class PipelineState(NamedTuple):
    buffer: Tree          # stacked pending grads, leading dim = depth
    step: jax.Array       # int32 global step


def init_pipeline(grads_like: Tree, depth: int) -> PipelineState:
    """depth = s_U + 1 ring slots (delay d uses slot (step - d) % depth)."""
    if depth < 1:
        raise ValueError("depth must be >= 1")
    buf = jax.tree_util.tree_map(
        lambda g: jnp.zeros((depth,) + g.shape, g.dtype), grads_like)
    return PipelineState(buffer=buf, step=jnp.zeros((), jnp.int32))


def pipeline_specs(grad_specs: Tree, depth: int) -> Tree:
    """Buffer shards like the gradient with an unsharded ring dim."""
    from jax.sharding import PartitionSpec as P

    def add_dim(spec):
        return P(None, *spec)

    buf = jax.tree_util.tree_map(
        add_dim, grad_specs, is_leaf=lambda x: isinstance(x, P))
    return PipelineState(buffer=buf, step=P())


def push_pop(state: PipelineState, grads: Tree, delay: jax.Array,
             ) -> Tuple[Tree, jax.Array, PipelineState]:
    """Write ``grads`` into the ring; read the gradient from ``delay``
    steps ago.  Returns (delayed_grads, valid_scale, new_state) where
    ``valid_scale`` is 0.0 for the warm-up steps that have no gradient to
    apply yet (t < d) and 1.0 afterwards.

    delay == 0 reproduces BSP exactly (reads what it just wrote).
    """
    depth = jax.tree_util.tree_leaves(state.buffer)[0].shape[0]
    delay = jnp.clip(jnp.asarray(delay, jnp.int32), 0, depth - 1)
    w = state.step % depth
    buf = jax.tree_util.tree_map(
        lambda b, g: jax.lax.dynamic_update_index_in_dim(
            b, g.astype(b.dtype), w, 0), state.buffer, grads)
    r = (state.step - delay) % depth
    delayed = jax.tree_util.tree_map(
        lambda b: jax.lax.dynamic_index_in_dim(b, r, 0, keepdims=False), buf)
    valid = (state.step >= delay).astype(jnp.float32)
    return delayed, valid, PipelineState(buffer=buf, step=state.step + 1)


# ------------------------------------------------------ host-side controller
@dataclasses.dataclass
class DsspScheduleController:
    """Chooses the delay ``d`` and cross-pod period ``k`` at run time.

    The paper's Algorithm-2 recipe — predict near-future intervals from
    the most recent observed ones, then pick the bound in [s_L, s_U] that
    minimizes predicted waiting — specialized to the SPMD streams:

    * ``delay()``: the compute stream (interval = step time) must not
      consume the collective stream's result before it lands; the minimal
      non-waiting delay is ceil(t_coll / t_step) on the *predicted*
      intervals (IntervalEstimator: 'last' = paper, 'ema'/'median'
      robust), clamped to [s_L, s_U].
    * ``period(pod_times)``: pods are the paper's workers; Algorithm 2's
      simulate+argmin runs verbatim on the fastest/slowest pod's
      predicted step intervals to choose extra local steps before the
      next cross-pod average.
    """

    s_lower: int
    s_upper: int
    estimator: str = "last"

    def __post_init__(self):
        from repro.core.controller import IntervalEstimator
        self._est = IntervalEstimator(mode=self.estimator)
        self.history = []

    def observe(self, step_time: float, collective_time: float) -> None:
        """Feed one step's measured (or roofline-derived) timings."""
        self._est.observe(0, max(1e-12, step_time))
        self._est.observe(1, max(0.0, collective_time))
        self.history.append((step_time, collective_time))

    def delay(self) -> int:
        t_step = self._est.predict(0)
        t_coll = self._est.predict(1)
        if t_step is None or t_coll is None:
            return self.s_lower
        d = -(-t_coll // t_step)                     # ceil division
        return int(min(self.s_upper, max(self.s_lower, d)))

    def period(self, pod_step_times) -> int:
        """Cross-pod averaging period from per-pod step times (Alg. 2)."""
        from repro.core.controller import (optimal_extra_iterations,
                                           simulate_push_times)
        fast, slow = min(pod_step_times), max(pod_step_times)
        r_max = self.s_upper - self.s_lower
        sim_fast = simulate_push_times(0.0, fast, r_max)
        sim_slow = simulate_push_times(0.0, slow, r_max, lead=1)
        r = optimal_extra_iterations(sim_fast, sim_slow)
        return int(min(self.s_upper, max(self.s_lower, self.s_lower + r)))


# --------------------------------------------------- cross-pod local SGD
def cross_pod_sync(tree: Tree, mesh: jax.sharding.Mesh,
                   specs: Tree) -> Tree:
    """Average a pytree across the 'pod' mesh axis with shard_map manual
    over 'pod' only ('data'/'model' shardings pass through untouched)."""

    def avg(t):
        return jax.tree_util.tree_map(
            lambda x: jax.lax.pmean(x, "pod"), t)

    try:  # jax >= 0.6: top-level API with per-axis manual mode
        from jax import shard_map

        fn = shard_map(avg, mesh=mesh, in_specs=(specs,), out_specs=specs,
                       axis_names=frozenset({"pod"}), check_vma=False)
    except ImportError:  # the experimental API this container ships
        from jax.experimental.shard_map import shard_map

        fn = shard_map(avg, mesh=mesh, in_specs=(specs,), out_specs=specs,
                       check_rep=False)
    return fn(tree)


def local_sgd_step(train_step: Callable, sync_params: Callable,
                   ) -> Callable:
    """Wrap a per-pod train step with conditional cross-pod averaging.

    ``do_sync`` is a traced bool scalar: the host flips it every k-th step
    (k from DsspScheduleController.period()) without recompiling.
    """

    def step(params, opt_state, pipeline, batch, delay, do_sync):
        params, opt_state, pipeline, metrics = train_step(
            params, opt_state, pipeline, batch, delay)
        params = jax.lax.cond(do_sync, sync_params,
                              lambda t: t, params)
        return params, opt_state, pipeline, metrics

    return step
