"""Core DSSP algorithm: staleness tracking, controller (Alg. 2), policies (Alg. 1)."""

from repro.core.controller import (
    IntervalEstimator,
    SynchronizationController,
    optimal_extra_iterations,
    simulate_push_times,
)
from repro.core.policies import (
    ASPPolicy,
    BackupWorkersBSP,
    BSPPolicy,
    Decision,
    DSSPPolicy,
    SSPPolicy,
    SyncPolicy,
    make_policy,
)
from repro.core.staleness import (
    PushRecord,
    StalenessTracker,
    dssp_effective_bound,
    regret_bound_constant,
)

__all__ = [
    "ASPPolicy", "BSPPolicy", "SSPPolicy", "DSSPPolicy", "BackupWorkersBSP",
    "SyncPolicy", "Decision", "make_policy",
    "SynchronizationController", "IntervalEstimator",
    "simulate_push_times", "optimal_extra_iterations",
    "StalenessTracker", "PushRecord",
    "regret_bound_constant", "dssp_effective_bound",
]
