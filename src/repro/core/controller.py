"""Algorithm 2: the DSSP synchronization controller.

Given the two latest push timestamps of every worker (table A in the
paper), the controller simulates the next ``r_max`` push times of the
current fastest worker ``p`` and of the slowest worker, and returns the
extra-iteration credit ``r* ∈ [0, r_max]`` that minimizes the *predicted*
waiting time of ``p``:

    Sim_p[0]       = A[p][0]
    Sim_p[i]       = Sim_p[0] + i · I_p                    (i = 1..r_max)
    Sim_slow[0]    = A[slow][0] + I_slow
    Sim_slow[k]    = Sim_slow[0] + k · I_slow              (k = 1..r_max)
    r*             = argmin_r  min_k | Sim_slow[k] − Sim_p[r] |

where I_w = A[w][0] − A[w][1] is the latest iteration interval of worker
``w`` (the paper's one-step predictor, §III.B assumption: contiguous
iterations of a worker in a short window have similar processing time).

Beyond-paper extensions (all optional, default = paper behaviour):

  * interval estimators 'ema' and 'median' — robust to transient network
    jitter the paper flags as a failure mode of the last-interval
    predictor ("we may make some wrong predictions … DSSP can still
    converge").
  * asymmetric tie-breaking — on equal predicted waits prefer the smaller
    r (less staleness ⇒ smaller Theorem-2 regret constant), which the
    paper's argmin leaves unspecified.
"""

from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Deque, Dict, List, Optional, Sequence

from repro.core.staleness import StalenessTracker


def simulate_push_times(start: float, interval: float, r_max: int,
                        *, lead: int = 0) -> List[float]:
    """Sim array of Algorithm 2 lines 6-7.

    ``lead=0`` gives Sim_p (first entry = the just-received push);
    ``lead=1`` gives Sim_slowest (first entry = the *next predicted* push
    of the slowest worker, A[slow][0] + I_slow).
    """
    if r_max < 0:
        raise ValueError("r_max must be >= 0")
    if interval < 0:
        raise ValueError("interval must be >= 0")
    return [start + (i + lead) * interval for i in range(r_max + 1)]


def optimal_extra_iterations(sim_fast: Sequence[float],
                             sim_slow: Sequence[float]) -> int:
    """Line 8 of Algorithm 2: argmin_r min_k |sim_slow[k] - sim_fast[r]|.

    Ties broken toward smaller r (lower staleness, see module docstring).
    """
    best_r, best_gap = 0, float("inf")
    for r, tp in enumerate(sim_fast):
        gap = min(abs(ts - tp) for ts in sim_slow)
        if gap < best_gap:
            best_r, best_gap = r, gap
    return best_r


@dataclasses.dataclass
class ControllerDecision:
    """One controller invocation, kept for metrics/EXPERIMENTS."""

    worker: int
    r_star: int
    predicted_wait: float
    interval_fast: float
    interval_slow: float
    timestamp: float


class IntervalEstimator:
    """Predicts a worker's next iteration interval from its push history."""

    def __init__(self, mode: str = "last", window: int = 8,
                 ema_alpha: float = 0.5):
        if mode not in ("last", "ema", "median"):
            raise ValueError(f"unknown estimator mode {mode!r}")
        self.mode = mode
        self.window = window
        self.ema_alpha = ema_alpha
        self._hist: Dict[int, Deque[float]] = collections.defaultdict(
            lambda: collections.deque(maxlen=window))
        self._ema: Dict[int, float] = {}

    def observe(self, worker: int, interval: float) -> None:
        self._hist[worker].append(interval)
        prev = self._ema.get(worker)
        self._ema[worker] = (interval if prev is None
                             else self.ema_alpha * interval
                             + (1 - self.ema_alpha) * prev)

    def predict(self, worker: int) -> Optional[float]:
        hist = self._hist.get(worker)
        if not hist:
            return None
        if self.mode == "last":
            return hist[-1]
        if self.mode == "ema":
            return self._ema[worker]
        return statistics.median(hist)


class SynchronizationController:
    """The server-side controller DSSP calls for the current fastest worker.

    ``r_max = s_U − s_L`` is the width of the user-given threshold range.
    """

    def __init__(self, r_max: int, *, estimator: str = "last",
                 window: int = 8):
        if r_max < 0:
            raise ValueError("r_max must be >= 0")
        self.r_max = r_max
        self.estimator = IntervalEstimator(mode=estimator, window=window)
        self.decisions: List[ControllerDecision] = []

    # The tracker's record_push() already maintains table A; the controller
    # additionally feeds its interval estimator (a superset of the paper's
    # last-interval table when estimator != 'last').
    def observe_push(self, tracker: StalenessTracker, worker: int) -> None:
        interval = tracker.latest_interval(worker)
        if interval is not None:
            self.estimator.observe(worker, max(0.0, interval))

    def __call__(self, tracker: StalenessTracker, worker: int,
                 push_timestamp: float) -> int:
        """Algorithm 2. Returns r* (0 ⇒ block now, paper line 17)."""
        slowest = tracker.slowest_worker()
        i_fast = self.estimator.predict(worker)
        i_slow = self.estimator.predict(slowest)
        slow_ts = tracker.latest_timestamp(slowest)
        if i_fast is None or i_slow is None or slow_ts is None:
            # Cold start: not enough history to simulate — the paper's
            # table A has NaNs. Be conservative: no extra credit.
            return 0
        sim_fast = simulate_push_times(push_timestamp, i_fast, self.r_max)
        sim_slow = simulate_push_times(slow_ts, i_slow, self.r_max, lead=1)
        r_star = optimal_extra_iterations(sim_fast, sim_slow)
        predicted_wait = min(abs(ts - sim_fast[r_star]) for ts in sim_slow)
        self.decisions.append(ControllerDecision(
            worker=worker, r_star=r_star, predicted_wait=predicted_wait,
            interval_fast=i_fast, interval_slow=i_slow,
            timestamp=push_timestamp))
        return r_star

    # -- metrics ----------------------------------------------------------
    def mean_granted(self) -> float:
        if not self.decisions:
            return 0.0
        return sum(d.r_star for d in self.decisions) / len(self.decisions)

    def grant_histogram(self) -> Dict[int, int]:
        hist: Dict[int, int] = {}
        for d in self.decisions:
            hist[d.r_star] = hist.get(d.r_star, 0) + 1
        return hist
