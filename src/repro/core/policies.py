"""Synchronization (gating) policies — the server side of Algorithm 1.

A policy decides, for every push request, (a) whether the carried gradient
is applied to the global weights and (b) whether the pushing worker is
released immediately (``OK``) or blocked.  Blocked workers are re-checked
(``may_release``) after every subsequent push.

Implemented paradigms:

  * ``BSPPolicy``            — lockstep (== SSP with s = 0).
  * ``ASPPolicy``            — never blocks.
  * ``SSPPolicy(s)``         — release iff t_p − t_slowest ≤ s.
  * ``DSSPPolicy(s_L, s_U)`` — the paper's contribution: Algorithm 1 with
    per-worker credits ``r_p`` granted by the Algorithm-2 controller.
  * ``BackupWorkersBSP(n, c)`` — Chen et al. 2016 baseline the paper
    discusses: per round apply the first ``n − c`` gradients, drop the
    ``c`` straggler gradients, stragglers are not blocked.

One semantic note on Algorithm 1 vs. Figure 2: the pseudocode (release on
grant at line 14, then decrement-release on later pushes at lines 3-5)
admits ``r* + 1`` releases per grant, while Figure 2's walkthrough
("DSSP allows worker₁ to run 3 more iterations and stop at the green
line") counts the on-grant release as the first of the ``r*``.  We follow
the figure: a grant of ``r*`` yields exactly ``r*`` releases
(credits ← r* − 1 plus the immediate OK), so the worker stops exactly at
the controller's predicted minimum-wait boundary.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.core.controller import SynchronizationController
from repro.core.staleness import StalenessTracker, dssp_effective_bound
from repro.obs.trace import TRACE


@dataclasses.dataclass
class Decision:
    apply_update: bool          # fold the pushed gradient into global weights?
    release_now: bool           # send OK immediately?
    credit_used: bool = False   # released via a pre-granted DSSP credit


class SyncPolicy:
    """Base class. Policies are stateful and are called under the server lock."""

    name = "base"

    def on_push(self, tracker: StalenessTracker, worker: int,
                timestamp: float) -> Decision:
        raise NotImplementedError

    def may_release(self, tracker: StalenessTracker, worker: int) -> bool:
        """Re-evaluated for a blocked worker after every later push."""
        raise NotImplementedError

    def effective_staleness_bound(self, tracker: StalenessTracker) -> float:
        """Upper bound on admitted staleness (for Theorem-1/2 reporting)."""
        raise NotImplementedError


class SSPPolicy(SyncPolicy):
    """Stale Synchronous Parallel with fixed threshold ``s`` (Ho et al. '13)."""

    def __init__(self, staleness: int):
        if staleness < 0:
            raise ValueError("staleness must be >= 0")
        self.s = staleness
        self.name = f"ssp(s={staleness})"

    def on_push(self, tracker, worker, timestamp):
        return Decision(apply_update=True,
                        release_now=tracker.gap(worker) <= self.s)

    def may_release(self, tracker, worker):
        return tracker.gap(worker) <= self.s

    def effective_staleness_bound(self, tracker):
        return self.s


class BSPPolicy(SSPPolicy):
    """Bulk Synchronous Parallel — SSP with s = 0 (full barrier)."""

    def __init__(self):
        super().__init__(0)
        self.name = "bsp"


class ASPPolicy(SyncPolicy):
    """Asynchronous Parallel — apply everything, never block (Hogwild-style)."""

    name = "asp"

    def on_push(self, tracker, worker, timestamp):
        return Decision(apply_update=True, release_now=True)

    def may_release(self, tracker, worker):
        return True

    def effective_staleness_bound(self, tracker):
        return float("inf")


class DSSPPolicy(SyncPolicy):
    """Dynamic SSP (the paper, Algorithms 1 + 2).

    ``s_lower``/``s_upper`` are the user's threshold range [s_L, s_U];
    ``r_max = s_U − s_L``.  ``estimator`` selects the interval predictor
    ('last' = paper, 'ema'/'median' = robust variants, §II of DESIGN.md).
    """

    def __init__(self, s_lower: int, s_upper: int, *,
                 estimator: str = "last",
                 controller: Optional[SynchronizationController] = None):
        dssp_effective_bound(s_lower, s_upper)  # validates the range
        self.s_lower = s_lower
        self.s_upper = s_upper
        self.controller = controller or SynchronizationController(
            s_upper - s_lower, estimator=estimator)
        self.name = f"dssp(s_L={s_lower},s_U={s_upper},{estimator})"
        self.credits_granted = 0
        self.credits_spent = 0

    def _trace_decision(self, tracker, worker: int, reason: str,
                        gap: int, threshold: int, r_star: int = 0) -> None:
        """``dssp_decision`` instant: the Algorithm-1/2 gate outcome.

        ``reason`` is one of ``credit_spend`` / ``credit_void`` /
        ``free`` / ``grant`` / ``block``; the threshold *extensions*
        (``grant`` + ``credit_spend``) are exactly the pushes
        ``RunMetrics`` counts in ``credit_releases``.
        """
        TRACE.instant(
            "dssp_decision", worker=worker,
            clock=tracker.counts.get(worker, -1),
            args={"reason": reason, "gap": gap, "threshold": threshold,
                  "s_lower": self.s_lower, "s_upper": self.s_upper,
                  "r_star": r_star,
                  "credits_left": tracker.credits[worker]})

    def on_push(self, tracker, worker, timestamp):
        # Feed the interval estimator on *every* push (table A upkeep).
        self.controller.observe_push(tracker, worker)
        gap = tracker.gap(worker)

        # Lines 3-5: spend a pre-granted credit.  A credit is only valid
        # while the hard bound holds (gap can outgrow it if the slowest
        # worker *leaves* the cluster — elastic membership); otherwise the
        # credit is voided and we fall through to the gating logic.
        if tracker.credits[worker] > 0:
            if gap <= self.s_upper:
                tracker.credits[worker] -= 1
                self.credits_spent += 1
                if TRACE.enabled:
                    self._trace_decision(tracker, worker, "credit_spend",
                                         gap, self.s_upper)
                return Decision(apply_update=True, release_now=True,
                                credit_used=True)
            tracker.credits[worker] = 0
            if TRACE.enabled:
                self._trace_decision(tracker, worker, "credit_void",
                                     gap, self.s_lower)

        # Lines 8-9: within the lower bound — free to go.
        if gap <= self.s_lower:
            if TRACE.enabled:
                self._trace_decision(tracker, worker, "free", gap,
                                     self.s_lower)
            return Decision(apply_update=True, release_now=True)

        # Lines 11-15: only the *current fastest* worker consults the
        # controller (footnote 1: saves server compute).  The grant is
        # capped so the worker never *runs* an iteration more than s_U
        # ahead of the slowest (r_max is "the maximum extra iterations
        # allowed ... beyond the lower bound", §III — Theorem 2 needs the
        # total staleness bounded by s_L + r_max = s_U, so repeated grants
        # must not compound past it).
        if tracker.is_fastest(worker):
            headroom = self.s_upper - gap + 1   # releases left within bound
            if headroom > 0:
                r_star = min(self.controller(tracker, worker, timestamp),
                             headroom)
                if r_star > 0:
                    # Figure-2 semantics: this OK is the first of r* releases.
                    tracker.credits[worker] = r_star - 1
                    self.credits_granted += r_star
                    if TRACE.enabled:
                        self._trace_decision(
                            tracker, worker, "grant", gap,
                            min(self.s_upper, gap + r_star - 1),
                            r_star=r_star)
                    return Decision(apply_update=True, release_now=True,
                                    credit_used=True)

        # Line 17: block until the slowest catches up to within s_L.
        if TRACE.enabled:
            self._trace_decision(tracker, worker, "block", gap,
                                 self.s_lower)
        return Decision(apply_update=True, release_now=False)

    def may_release(self, tracker, worker):
        return tracker.gap(worker) <= self.s_lower

    def effective_staleness_bound(self, tracker):
        return self.s_upper


class BackupWorkersBSP(SyncPolicy):
    """BSP with ``c`` backup workers (Chen et al. 2016).

    Per synchronous round, the first ``n_workers − c`` arriving gradients
    are applied; once they arrive the round commits and everyone blocked
    in it is released.  The ``c`` straggler gradients of that round are
    *dropped* (their training data is wasted — the cost the paper points
    out) and the stragglers are released immediately into the next round.
    """

    def __init__(self, n_workers: int, backups: int):
        if not 0 <= backups < n_workers:
            raise ValueError("need 0 <= backups < n_workers")
        self.n = n_workers
        self.c = backups
        self.quorum = n_workers - backups
        self.round = 0
        self.applied_this_round = 0
        self.worker_round: Dict[int, int] = {}
        self.dropped = 0
        self.name = f"bsp+backup(c={backups})"

    def on_push(self, tracker, worker, timestamp):
        wr = self.worker_round.get(worker, 0)
        if wr < self.round:
            # Straggler from an already-committed round: drop, release.
            self.worker_round[worker] = wr + 1
            self.dropped += 1
            return Decision(apply_update=False, release_now=True)
        self.worker_round[worker] = wr + 1
        self.applied_this_round += 1
        if self.applied_this_round >= self.quorum:
            self.round += 1
            self.applied_this_round = 0
            return Decision(apply_update=True, release_now=True)
        return Decision(apply_update=True, release_now=False)

    def may_release(self, tracker, worker):
        # Released once the round this worker pushed into has committed.
        return self.worker_round.get(worker, 0) <= self.round

    def effective_staleness_bound(self, tracker):
        return 1  # a straggler's dropped round puts it at most 1 behind


def make_policy(name: str, *, n_workers: int = 0, staleness: int = 3,
                s_lower: int = 3, s_upper: int = 15, backups: int = 1,
                estimator: str = "last") -> SyncPolicy:
    """Factory used by configs / CLI (``--sync dssp`` etc.)."""
    name = name.lower()
    if name == "bsp":
        return BSPPolicy()
    if name == "asp":
        return ASPPolicy()
    if name == "ssp":
        return SSPPolicy(staleness)
    if name == "dssp":
        return DSSPPolicy(s_lower, s_upper, estimator=estimator)
    if name in ("backup", "bsp+backup"):
        return BackupWorkersBSP(n_workers, backups)
    raise ValueError(f"unknown sync policy {name!r}")


def make_policy_factory(name: str, **kw) -> Callable[[], SyncPolicy]:
    """Zero-arg factory of *fresh, independent* policy instances.

    Policies are stateful (credits, controller interval tables, backup
    rounds), so anything that runs several gates concurrently — one per
    parameter-server shard in the ``sharded`` gating mode — must construct
    one instance per gate: each shard's DSSP Algorithm-2 controller then
    reads its own per-shard interval table instead of a shared one.
    """
    def factory() -> SyncPolicy:
        return make_policy(name, **kw)

    factory.__name__ = f"policy_factory[{name}]"
    return factory
