"""Deterministic fault injection for chaos testing.

A ``FaultPlan`` is a frozen, picklable description of every fault a
run should suffer — which makes chaos *reproducible*: the same plan +
seed injects the same faults at the same points, in CI and on a
laptop.

Three fault families:

  * **kill the server** at push-round R — implemented server-side (a
    watchdog in ``ft.server_proc`` SIGKILLs the server process when
    its aggregate push count crosses R; SIGKILL on purpose: no atexit,
    no final snapshot, the worst case),
  * **kill worker W** at its local iteration R' — the worker process
    SIGKILLs *itself* mid-loop (``worker_kill_due``), exercising the
    server's disconnect path and the barrier-seat release,
  * **drop / delay frames** of kind K with probability p — injected in
    ``FaultyChannel``, a ``Channel`` wrapper that parses each outgoing
    frame's header and consults a per-worker seeded RNG; a dropped
    frame surfaces to the client as ``TransportClosed`` (exactly what
    a dead socket looks like), driving the reconnect path.

Every injected fault is emitted as a typed ``fault`` obs instant so a
trace of a chaos run shows *why* the failover spans exist.

Stdlib + wireformat only: spawned workers import this before jax.
"""

from __future__ import annotations

import dataclasses
import os
import random
import signal
import time
from typing import Any, Dict, Optional

from repro.obs.trace import TRACE
from repro.transport.base import Channel, Frame, TransportClosed
from repro.wireformat import HEADER_SIZE, decode_header


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Sentinel-disabled fields: ``-1`` rounds and ``0.0`` probability
    mean 'never'.  ``drop_kind``/``delay_kind`` are wireformat MSG_*
    codes (0 = any kind)."""

    kill_server_round: int = -1   # SIGKILL server at aggregate push R
    kill_worker: int = -1         # which worker id self-SIGKILLs ...
    kill_worker_round: int = -1   # ... at this local iteration
    drop_kind: int = 0            # frame kind to drop (0 = any)
    drop_prob: float = 0.0        # per-frame drop probability
    delay_kind: int = 0           # frame kind to delay (0 = any)
    delay_ms: float = 0.0         # injected per-frame latency
    kill_mid_reshard: bool = False  # SIGKILL the server INSIDE a live
    #                               reshard (between shard migrations —
    #                               the torn-window failover case)
    seed: int = 0                 # RNG seed (per-worker offset added)

    def __post_init__(self):
        if not 0.0 <= self.drop_prob <= 1.0:
            raise ValueError("drop_prob is a probability in [0, 1]")
        if self.delay_ms < 0:
            raise ValueError("delay_ms must be >= 0")

    @property
    def active(self) -> bool:
        return (self.kill_server_round >= 0
                or (self.kill_worker >= 0 and self.kill_worker_round >= 0)
                or self.drop_prob > 0.0 or self.delay_ms > 0.0
                or self.kill_mid_reshard)

    @property
    def wants_channel(self) -> bool:
        """Does this plan need a ``FaultyChannel`` wrapper at all?"""
        return self.drop_prob > 0.0 or self.delay_ms > 0.0

    def worker_kill_due(self, worker_id: int, iteration: int) -> bool:
        return (self.kill_worker == worker_id
                and self.kill_worker_round >= 0
                and iteration == self.kill_worker_round)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Optional[Dict[str, Any]]) -> "FaultPlan":
        if not d:
            return cls()
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


def kill_self() -> None:  # pragma: no cover - the process dies here
    """SIGKILL the calling process: no cleanup, no flush — the honest
    simulation of a machine dropping off the fleet."""
    os.kill(os.getpid(), signal.SIGKILL)


class FaultyChannel(Channel):
    """Channel wrapper injecting the plan's drop/delay faults into the
    request path, deterministically per ``(plan.seed, worker_id)``."""

    def __init__(self, inner: Channel, plan: FaultPlan, worker_id: int):
        self.inner = inner
        self.plan = plan
        self.worker_id = worker_id
        self._rng = random.Random((plan.seed << 16) ^ worker_id)

    def request(self, data: bytes) -> Frame:
        plan = self.plan
        kind = 0
        if len(data) >= HEADER_SIZE:
            try:
                frame, _ = decode_header(bytes(data[:HEADER_SIZE]))
                kind = frame.kind
            except Exception:
                kind = 0
        if plan.drop_prob > 0.0 and plan.drop_kind in (0, kind):
            if self._rng.random() < plan.drop_prob:
                if TRACE.enabled:
                    TRACE.instant("fault", worker=self.worker_id,
                                  args={"fault": "drop", "kind": kind})
                raise TransportClosed(
                    f"injected drop of frame kind {kind} "
                    f"(worker {self.worker_id})")
        if plan.delay_ms > 0.0 and plan.delay_kind in (0, kind):
            if TRACE.enabled:
                TRACE.instant("fault", worker=self.worker_id,
                              args={"fault": "delay", "kind": kind,
                                    "ms": plan.delay_ms})
            time.sleep(plan.delay_ms / 1000.0)
        return self.inner.request(data)

    def close(self) -> None:
        self.inner.close()


def wrap_channel(channel: Channel, plan: Optional[FaultPlan],
                 worker_id: int) -> Channel:
    """Wrap iff the plan injects channel-level faults; otherwise the
    original channel passes through untouched (zero overhead)."""
    if plan is not None and plan.wants_channel:
        return FaultyChannel(channel, plan, worker_id)
    return channel


__all__ = ["FaultPlan", "FaultyChannel", "wrap_channel", "kill_self"]
