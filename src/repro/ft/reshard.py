"""Live resharding: migrate packed shard regions between stores.

A reshard takes a running ``ShardedParameterServer`` from S shards to
S' without stopping training.  The whole protocol rides the two
invariants the packed wire format already guarantees:

  * every ``LeafSlice`` occupies a **canonically contiguous** element
    range (``leaf_off[leaf] + start * row_elems``, see
    ``ShardPlan._build_wire_layout``), and within a shard's wire region
    slices are laid out in that same canonical order — so the overlap
    of an old slice with a new slice is one contiguous copy in BOTH
    wire layouts,
  * jax arrays are immutable, so grabbing a reference under a shard's
    lock IS a consistent snapshot of that shard.

The migration map
-----------------
``build_migration(old_plan, new_plan)`` intersects the two plans'
canonical partitions into a flat list of ``RegionMove``s::

    RegionMove(old_shard, old_off, new_shard, new_off, size)

``old_off``/``new_off`` are element offsets into the flat view of the
respective shard's ``(rows, 512)`` wire region.  The moves cover every
real element exactly once (padding never moves — it is zero in both
layouts), so ``migrate`` over the parameter and momentum buffers is a
permutation: bitwise, dtype-preserving, invertible.

The same map translates *gradients*: a push packed under the old plan
(a stale ``reshard_epoch``) is resliced into new-plan regions and
applied normally — no gradient is lost or double-applied when clients
lag the server by an epoch.

The live protocol (server side, see ``ShardedParameterServer.reshard``)
-----------------------------------------------------------------------
1. retire old shards one at a time under their own locks: mark the
   shard ``retired`` (new applies for it PARK as raw regions), drain
   any in-flight coalesce window, and reference-grab ``(p, m,
   version)`` — the lock hold is the only per-shard pause and is
   emitted as a ``reshard_shard`` obs span,
2. outside every lock, fold the copied regions through the migration
   map into the new plan's packed buffers,
3. atomically swap ``(plan, shards, n_shards)`` and bump
   ``reshard_epoch``; trackers/credits carry over (counts equalized to
   the per-worker minimum across old shards — the same rule failover
   restore uses), versions redistribute so their SUM is preserved
   (``server.version`` is continuous across the migration),
4. release any gate waiter still parked on an old shard's barrier
   (its peers now push to the new shards), wait for in-flight
   old-epoch pushes to drain, then REPLAY every parked region through
   the map onto the new shards — momentum folded only over the moved
   segments, so elements that already saw this push through another
   shard are not decayed twice.

Clients observe the epoch in HELLO/SUB replies and ``MSG_DELTA``
(carried in the frame's otherwise-unused ``shard`` field) and force a
full pull — the PR-5 version-vector fallback — then rebuild their
plan/buffers from the reply itself.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

from repro.wireformat import WIRE_LANES


@dataclasses.dataclass(frozen=True)
class RegionMove:
    """One contiguous copy between two packed shard regions.

    Offsets are ELEMENT offsets into the flat view of each shard's
    ``(rows, 512)`` wire region; ``size`` is the element count.
    """

    old_shard: int
    old_off: int
    new_shard: int
    new_off: int
    size: int


def _canonical_segments(plan) -> List[Tuple[int, int, int, int]]:
    """``(canon_start, size, shard, region_off)`` per slice, sorted by
    canonical position.  Mirrors ``ShardPlan._build_wire_layout``: a
    slice's wire bytes sit at ``region_off`` in its shard's flat region
    and cover canonical elements ``[canon_start, canon_start+size)``."""
    sizes = [math.prod(s) if s else 1 for s in plan.leaf_shapes]
    leaf_off = np.concatenate([[0], np.cumsum(sizes)])
    segs: List[Tuple[int, int, int, int]] = []
    for j, shard in enumerate(plan.shards):
        off = 0
        for sl in shard.slices:
            shape = plan.leaf_shapes[sl.leaf]
            row_elems = math.prod(shape[1:]) if len(shape) > 1 else 1
            canon0 = int(leaf_off[sl.leaf]) + sl.start * row_elems
            segs.append((canon0, sl.size, j, off))
            off += sl.size
    segs.sort()
    return segs


@dataclasses.dataclass(frozen=True)
class MigrationMap:
    """The full S -> S' region-move list plus both layouts' row counts."""

    old_n_shards: int
    new_n_shards: int
    old_shard_rows: Tuple[int, ...]
    new_shard_rows: Tuple[int, ...]
    dtype: Any
    moves: Tuple[RegionMove, ...]

    # -- state migration -----------------------------------------------------
    def migrate(self, old_bufs: Sequence[Any]) -> List[np.ndarray]:
        """Old per-shard packed buffers -> new per-shard packed buffers.

        Pure contiguous copies, one move at a time; padding stays zero.
        Dtype-preserving, so params and momentum migrate bitwise.
        """
        olds = [np.asarray(b).reshape(-1) for b in old_bufs]
        news = [np.zeros(r * WIRE_LANES, self.dtype)
                for r in self.new_shard_rows]
        for mv in self.moves:
            news[mv.new_shard][mv.new_off:mv.new_off + mv.size] = \
                olds[mv.old_shard][mv.old_off:mv.old_off + mv.size]
        return [b.reshape(-1, WIRE_LANES) for b in news]

    def migrate_grads(self, old_bufs: Sequence[Any]) -> List[np.ndarray]:
        """Gradient translation is the same permutation (padding rows
        carry zero gradient in both layouts)."""
        return self.migrate(old_bufs)

    def moves_from(self, old_shard: int) -> List[RegionMove]:
        """The moves that source from one old shard — the replay unit
        for a push parked against that shard mid-migration."""
        return [mv for mv in self.moves if mv.old_shard == old_shard]

    def describe(self) -> str:
        lines = [f"MigrationMap: {self.old_n_shards} -> "
                 f"{self.new_n_shards} shards, {len(self.moves)} moves, "
                 f"{sum(m.size for m in self.moves):,} elements"]
        for mv in self.moves:
            lines.append(
                f"  shard {mv.old_shard}[{mv.old_off}:"
                f"{mv.old_off + mv.size}] -> shard {mv.new_shard}"
                f"[{mv.new_off}:{mv.new_off + mv.size}]")
        return "\n".join(lines)


def build_migration(old_plan, new_plan, dtype=None) -> MigrationMap:
    """Intersect the two plans' canonical partitions into contiguous
    region moves.  Both plans must describe the SAME tree (that is what
    makes the canonical element space shared)."""
    if (old_plan.leaf_shapes != new_plan.leaf_shapes):
        raise ValueError(
            "migration requires both plans to describe the same tree "
            f"({len(old_plan.leaf_shapes)} vs "
            f"{len(new_plan.leaf_shapes)} leaves / shapes differ)")
    old_layout = old_plan.wire_layout(dtype)
    new_layout = new_plan.wire_layout(dtype)
    if old_layout.dtype != new_layout.dtype:
        raise ValueError("wire dtypes differ between plans")
    old_segs = _canonical_segments(old_plan)
    new_segs = _canonical_segments(new_plan)
    moves: List[RegionMove] = []
    i = j = 0
    while i < len(old_segs) and j < len(new_segs):
        oc, osz, osh, ooff = old_segs[i]
        nc, nsz, nsh, noff = new_segs[j]
        lo = max(oc, nc)
        hi = min(oc + osz, nc + nsz)
        if hi > lo:
            moves.append(RegionMove(
                old_shard=osh, old_off=ooff + (lo - oc),
                new_shard=nsh, new_off=noff + (lo - nc),
                size=hi - lo))
        if oc + osz <= nc + nsz:
            i += 1
        if nc + nsz <= oc + osz:
            j += 1
    covered = sum(m.size for m in moves)
    if covered != old_layout.total_elems:
        raise AssertionError(
            f"migration map covers {covered} of "
            f"{old_layout.total_elems} elements — plans disagree")
    return MigrationMap(
        old_n_shards=old_plan.n_shards, new_n_shards=new_plan.n_shards,
        old_shard_rows=old_layout.shard_rows,
        new_shard_rows=new_layout.shard_rows,
        dtype=np.dtype(old_layout.dtype), moves=tuple(moves))


def spread_versions(total: int, n_shards: int) -> List[int]:
    """Redistribute a version SUM over a new arity: ``server.version``
    (the sum) is the run's logical clock — snapshots, the loss
    trajectory and serving staleness all ride it — so it must be
    continuous across a reshard."""
    base, rem = divmod(int(total), n_shards)
    return [base + (1 if k < rem else 0) for k in range(n_shards)]


def equalized_counts(per_shard_counts: Sequence[Dict[int, int]],
                     ) -> Dict[int, int]:
    """Per-worker push counts for the new trackers: the MINIMUM across
    old shards — the same clamp rule failover restore uses, for the
    same reason (a count that runs ahead on some shards could gate two
    workers against each other's barriers forever)."""
    workers: Dict[int, int] = {}
    for counts in per_shard_counts:
        for w, c in counts.items():
            c = int(c)
            workers[w] = c if w not in workers else min(workers[w], c)
    return workers


def live_reshard(server, n_shards: int) -> bool:
    """Public entry point: live-migrate ``server`` to ``n_shards``.

    Returns True if a migration ran (False for a no-op same-arity
    call).  Training, pulls and serving continue throughout; see the
    module doc for the protocol.
    """
    return server.reshard(n_shards)


__all__ = [
    "MigrationMap",
    "RegionMove",
    "build_migration",
    "equalized_counts",
    "live_reshard",
    "spread_versions",
]
