"""Server snapshots: capture / restore the whole packed-store state.

A snapshot is everything a restarted server needs to carry on mid-run:

  * the resident packed per-shard parameter + momentum buffers
    (``apply_mode='fused'`` on the sharded server, ``'packed'`` on the
    monolithic one — tree mode has no resident store and is rejected),
  * the per-shard version vector (what version-delta pulls diff
    against: a restored server resumes *behind* any worker's last-seen
    vector, so the component-wise dominance rule in ``pull_delta``
    makes every reconnecting worker fall back to a full resync
    automatically),
  * per-shard ``StalenessTracker`` tables (iteration counts, table A,
    DSSP credits) and sync-policy state (DSSP credit counters +
    Algorithm-2 interval-estimator history; backup-BSP round state),
  * the aggregate ``RunMetrics`` (loss trajectory included), so the
    convergence curve survives the failover.

Capture is **per shard, under that shard's existing lock** — the pause
a snapshot imposes on any one push is one buffer-reference grab plus a
tracker/policy dict copy, emitted as a ``snapshot_shard`` obs span.
There is no global pause: serialization (host transfer + disk) happens
outside every lock, in the ``CheckpointManager``'s writer thread.

``ServerSnapshotter`` is the periodic driver; ``restore_latest`` is
the failover entry point (emits a ``failover`` span).
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.trace import TRACE

SNAPSHOT_VERSION = 1


# ===================================================================
# tracker / policy / metrics state (plain dicts, JSON-able)
# ===================================================================
def _tracker_state(tr) -> Dict[str, Any]:
    return {
        "workers": [int(w) for w in tr.workers],
        "counts": {str(w): int(c) for w, c in tr.counts.items()},
        "table": {str(w): [float(a), float(b)]
                  for w, (a, b) in tr.table.items()},
        "credits": {str(w): int(c) for w, c in tr.credits.items()},
    }


def _restore_tracker(tr, state: Dict[str, Any]) -> None:
    import math
    tr.workers = [int(w) for w in state["workers"]]
    tr.counts = {int(w): int(c) for w, c in state["counts"].items()}
    # Table A is NOT restored: its timestamps are clock readings of the
    # DEAD process (relative to its private t0), so diffing them against
    # the new process's clock would feed the Algorithm-2 estimator
    # negative/garbage intervals.  NaNs put the controller on its
    # documented cold-start path (no credit until two fresh pushes).
    tr.table = {int(w): (math.nan, math.nan) for w in state["table"]}
    tr.credits = {int(w): int(c) for w, c in state["credits"].items()}
    tr.history = []  # per-push records are metrics, not resume state


def capture_policy_state(policy) -> Dict[str, Any]:
    """Duck-typed policy state export.  SSP/ASP/BSP gate off the
    tracker alone; DSSP adds credit counters + the Algorithm-2
    estimator history; backup-BSP adds its round bookkeeping."""
    state: Dict[str, Any] = {"class": type(policy).__name__}
    if hasattr(policy, "credits_granted"):           # DSSP
        est = policy.controller.estimator
        state["credits_granted"] = int(policy.credits_granted)
        state["credits_spent"] = int(policy.credits_spent)
        state["estimator"] = {
            "hist": {str(w): [float(x) for x in dq]
                     for w, dq in est._hist.items()},
            "ema": {str(w): float(v) for w, v in est._ema.items()},
        }
    if hasattr(policy, "worker_round"):              # BackupWorkersBSP
        state["round"] = int(policy.round)
        state["applied_this_round"] = int(policy.applied_this_round)
        state["worker_round"] = {str(w): int(r)
                                 for w, r in policy.worker_round.items()}
        state["dropped"] = int(policy.dropped)
    return state


def restore_policy_state(policy, state: Dict[str, Any]) -> None:
    if hasattr(policy, "credits_granted") and "credits_granted" in state:
        policy.credits_granted = int(state["credits_granted"])
        policy.credits_spent = int(state["credits_spent"])
        est = policy.controller.estimator
        for w, xs in state.get("estimator", {}).get("hist", {}).items():
            for x in xs:
                est._hist[int(w)].append(float(x))
        est._ema.update({int(w): float(v) for w, v in
                         state.get("estimator", {}).get("ema", {}).items()})
    if hasattr(policy, "worker_round") and "worker_round" in state:
        policy.round = int(state["round"])
        policy.applied_this_round = int(state["applied_this_round"])
        policy.worker_round = {int(w): int(r)
                               for w, r in state["worker_round"].items()}
        policy.dropped = int(state["dropped"])


def _metrics_state(m) -> Dict[str, Any]:
    return {
        "total_pushes": m.total_pushes,
        "applied_updates": m.applied_updates,
        "dropped_updates": m.dropped_updates,
        "credit_releases": m.credit_releases,
        "total_time": m.total_time,
        "staleness_hist": {str(s): c for s, c in m.staleness_hist.items()},
        "pushes": {str(w): c for w, c in m.pushes.items()},
        "wait_time": {str(w): t for w, t in m.wait_time.items()},
        "loss_trajectory": [[t, s, loss]
                            for t, s, loss in m.loss_trajectory],
        "update_trajectory": [[t, u] for t, u in m.update_trajectory],
    }


def _restore_metrics(m, state: Dict[str, Any]) -> None:
    m.total_pushes = int(state["total_pushes"])
    m.applied_updates = int(state["applied_updates"])
    m.dropped_updates = int(state["dropped_updates"])
    m.credit_releases = int(state["credit_releases"])
    m.total_time = float(state["total_time"])
    m.staleness_hist = {int(s): int(c)
                        for s, c in state["staleness_hist"].items()}
    m.pushes = {int(w): int(c) for w, c in state["pushes"].items()}
    m.wait_time = {int(w): float(t)
                   for w, t in state["wait_time"].items()}
    m.loss_trajectory = [(float(t), int(s), float(loss))
                         for t, s, loss in state["loss_trajectory"]]
    m.update_trajectory = [(float(t), int(u))
                           for t, u in state["update_trajectory"]]


# ===================================================================
# capture
# ===================================================================
def _require_packed(server) -> None:
    if not getattr(server, "packed_wire", False):
        raise ValueError(
            "server snapshots capture the resident packed store; "
            f"apply_mode={getattr(server, 'apply_mode', None)!r} has "
            "none (use ps.apply='fused' or 'packed')")


def snapshot_server(server) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Capture ``(tree, extras)``: the array tree for the
    ``CheckpointManager`` plus the JSON-able bookkeeping.

    Per-shard state is grabbed under that shard's own lock — jax
    arrays are immutable, so a reference IS a consistent snapshot and
    the pause per shard is bounded by a dict copy, never by
    serialization.  Shards mutated between grabs may differ in
    version: exactly the per-shard consistency the partitioned server
    offers its own pulls.
    """
    _require_packed(server)
    t0 = TRACE.now() if TRACE.enabled else 0.0
    for _attempt in range(8):
        out = _snapshot_once(server)
        if out is not None:
            tree, extras = out
            break
    else:  # pragma: no cover - needs 8 reshards racing one capture
        raise RuntimeError("snapshot raced a live reshard 8 times")
    if TRACE.enabled:
        TRACE.span("snapshot", t0,
                   args={"shards": extras["n_shards"],
                         "version": sum(extras["versions"])})
    return tree, extras


def _snapshot_once(server):
    """One capture attempt; ``None`` when a live reshard swapped the
    shard list mid-capture (the caller retries — mixing regions from
    two plans in one snapshot would be a torn, unrestorable state)."""
    epoch0 = int(getattr(server, "reshard_epoch", 0))
    tree: Dict[str, Any] = {}
    versions: List[int] = []
    shard_states: List[Dict[str, Any]] = []
    shards = getattr(server, "shards", None)
    if shards is not None:                       # ShardedParameterServer
        kind = "sharded"
        for st in shards:
            with st.cond:
                # Span starts AFTER acquisition: it measures the lock
                # HOLD (the pause imposed on that shard's pushes), not
                # time spent queueing behind an in-flight apply.
                ts = TRACE.now() if TRACE.enabled else 0.0
                p, m = st._packed_p, st._packed_m
                version = st.version
                trk = _tracker_state(st.tracker)
                pol = capture_policy_state(st.policy)
            if TRACE.enabled:
                TRACE.span("snapshot_shard", ts, shard=st.index)
            tree[f"shard{st.index:03d}"] = {"p": p, "m": m}
            versions.append(version)
            shard_states.append({"tracker": trk, "policy": pol})
        gate = None
        if server.gating == "global":
            with server._gate_cond:
                gate = {"tracker": _tracker_state(server._gate_tracker),
                        "policy": capture_policy_state(server._gate_policy)}
        with server._metrics_lock:
            metrics = _metrics_state(server.metrics)
        gating = server.gating
    else:                                        # mono ParameterServer
        kind = "mono"
        with server._cond:
            ts = TRACE.now() if TRACE.enabled else 0.0
            p, m = server._wire_p, server._wire_m
            versions.append(server.version)
            shard_states.append(
                {"tracker": _tracker_state(server.tracker),
                 "policy": capture_policy_state(server.policy)})
            metrics = _metrics_state(server.metrics)
        if TRACE.enabled:
            TRACE.span("snapshot_shard", ts, shard=0)
        tree["shard000"] = {"p": p, "m": m}
        gate, gating = None, "mono"
    if int(getattr(server, "reshard_epoch", 0)) != epoch0:
        return None  # a reshard swapped plans mid-capture: retry
    opt = (shards[0].optimizer if shards is not None
           else server.optimizer)
    extras = {
        "snapshot_version": SNAPSHOT_VERSION,
        "kind": kind,
        "gating": gating,
        "n_shards": len(versions),
        "versions": versions,
        "shards": shard_states,
        "gate": gate,
        "optimizer": {"lr": opt.lr, "momentum": opt.momentum,
                      "staleness_damping": bool(opt.staleness_damping)},
        "metrics": metrics,
        # The live-reshard epoch these regions were laid out under —
        # restore uses it (with n_shards) to decide whether the target
        # server must be resharded before the install.
        "reshard_epoch": epoch0,
    }
    return tree, extras


# ===================================================================
# restore
# ===================================================================
def _equalize_counts(shards) -> None:
    """Clamp every worker's iteration count to its cross-shard minimum.

    The snapshot grabs each shard's tracker under its OWN lock, so a
    push in flight at capture time is recorded on the shards it already
    visited but not the rest.  Left as-is, that skew breaks the
    invariant the gating deadlock-freedom argument rests on (a worker's
    counts at shards 0..S-1 differ by at most its one in-flight push,
    always in canonical order): after the worker retries the
    interrupted push, its early-shard counts run TWO ahead of its
    late-shard counts, and two blocked workers can then wait on each
    other across different shards' barriers — a circular wait observed
    as the post-failover DSSP hang.  Clamping to the minimum re-enters
    the canonical-order regime (the retried push re-records uniformly);
    the discarded surplus is exactly the interrupted push the worker is
    about to re-send.
    """
    floor: Dict[int, int] = {}
    for st in shards:
        with st.cond:
            for w, c in st.tracker.counts.items():
                floor[w] = min(floor.get(w, c), c)
    for st in shards:
        with st.cond:
            for w in st.tracker.counts:
                st.tracker.counts[w] = floor[w]
            st.cond.notify_all()


def restore_server(server, tree: Dict[str, Any],
                   extras: Dict[str, Any]) -> None:
    """Install a captured snapshot into a freshly-built server of the
    same spec.  Per-shard installs run under each shard's lock and
    notify waiters; caches keyed by version (packed-snapshot cache,
    unpacked-piece cache) are invalidated."""
    _require_packed(server)
    import jax.numpy as jnp
    ver = extras.get("snapshot_version")
    if ver != SNAPSHOT_VERSION:
        raise ValueError(f"snapshot version {ver!r} != supported "
                         f"{SNAPSHOT_VERSION}")
    shards = getattr(server, "shards", None)
    n = len(shards) if shards is not None else 1
    if extras["n_shards"] != n:
        # Cross-plan restore: the snapshot was taken under a different
        # shard arity (a live reshard ran before — or after — the
        # capture).  A resharding-capable server is moved to the
        # snapshot's arity FIRST (which also installs the migration
        # map old->new, so stale-epoch client pushes keep translating);
        # then the install below proceeds shard-for-shard.
        if shards is None or not hasattr(server, "reshard"):
            raise ValueError(
                f"snapshot has {extras['n_shards']} shard(s), server "
                f"has {n} and cannot reshard — restore needs the same "
                "RunSpec the snapshot came from")
        server.reshard(extras["n_shards"])
        shards = server.shards
        n = len(shards)
    versions = [int(v) for v in extras["versions"]]
    states = extras["shards"]
    if shards is not None:
        if extras["kind"] != "sharded":
            raise ValueError(f"snapshot kind {extras['kind']!r} cannot "
                             "restore into a sharded server")
        for st in shards:
            blob = tree[f"shard{st.index:03d}"]
            with st.cond:
                st._packed_p = jnp.asarray(blob["p"])
                st._packed_m = jnp.asarray(blob["m"])
                st._pieces = None
                st.version = versions[st.index]
                _restore_tracker(st.tracker, states[st.index]["tracker"])
                restore_policy_state(st.policy,
                                     states[st.index]["policy"])
                st.metrics.n_workers = len(st.tracker.workers)
                st.cond.notify_all()
        _equalize_counts(shards)
        if extras.get("gate") and server.gating == "global":
            with server._gate_cond:
                _restore_tracker(server._gate_tracker,
                                 extras["gate"]["tracker"])
                restore_policy_state(server._gate_policy,
                                     extras["gate"]["policy"])
                server._gate_cond.notify_all()
        with server._snap_lock:
            server._snap_key = server._snap_wire = None
        with server._metrics_lock:
            _restore_metrics(server.metrics, extras["metrics"])
            server.metrics.n_workers = len(shards[0].tracker.workers)
    else:
        if extras["kind"] != "mono":
            raise ValueError(f"snapshot kind {extras['kind']!r} cannot "
                             "restore into a monolithic server")
        blob = tree["shard000"]
        with server._cond:
            server._wire_p = jnp.asarray(blob["p"])
            server._wire_m = jnp.asarray(blob["m"])
            server._params = None
            server.version = versions[0]
            _restore_tracker(server.tracker, states[0]["tracker"])
            restore_policy_state(server.policy, states[0]["policy"])
            _restore_metrics(server.metrics, extras["metrics"])
            server.metrics.n_workers = len(server.tracker.workers)
            server._cond.notify_all()


def restore_latest(server, manager) -> Optional[int]:
    """Failover entry point: restore the newest usable snapshot from
    ``manager`` into ``server``.  Returns the snapshot step, or
    ``None`` when the directory holds no (complete) snapshot.

    Cross-plan aware: when the snapshot was captured at a different
    shard arity (it straddles a live reshard), a resharding-capable
    server is moved to the snapshot's arity BEFORE the template tree is
    built, so the shape validation in ``CheckpointManager.restore``
    sees matching region buffers.  The run then resumes under exactly
    the plan the snapshot recorded — never a torn mixture."""
    t0 = TRACE.now() if TRACE.enabled else 0.0
    step = manager.latest_step()
    if step is None:
        return None
    peek = manager.peek_extras(step)
    want = int(peek.get("n_shards", 0))
    shards = getattr(server, "shards", None)
    if (shards is not None and want and want != len(shards)
            and hasattr(server, "reshard")):
        server.reshard(want)
    like, _ = snapshot_server(server)
    tree, extras = manager.restore(step, like)
    restore_server(server, tree, extras)
    if TRACE.enabled:
        TRACE.span("failover", t0,
                   args={"step": step,
                         "versions": [int(v)
                                      for v in extras["versions"]]})
    return step


# ===================================================================
# periodic driver
# ===================================================================
class ServerSnapshotter:
    """Daemon thread checkpointing ``server`` every ``every_s`` seconds
    (skipping intervals where no shard version moved).  ``save_now``
    is the synchronous path tests and final-save hooks use; a failed
    save is re-raised on ``stop()`` so sessions surface it."""

    def __init__(self, server, manager, every_s: float):
        if every_s <= 0:
            raise ValueError("snapshot interval must be positive")
        self.server = server
        self.manager = manager
        self.every_s = float(every_s)
        self.snapshots = 0
        self.failure: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop,
                                        name="ft-snapshotter", daemon=True)
        self._last_version = -1

    def start(self) -> "ServerSnapshotter":
        self._thread.start()
        return self

    def save_now(self) -> bool:
        """One snapshot, skipped (False) when nothing changed since the
        last one."""
        version = int(self.server.version)
        if version == self._last_version:
            return False
        tree, extras = snapshot_server(self.server)
        self.manager.save(version, tree, extras)
        self._last_version = version
        self.snapshots += 1
        return True

    def _loop(self) -> None:
        while not self._stop.wait(self.every_s):
            try:
                self.save_now()
            except BaseException as e:
                self.failure = e
                return

    def stop(self, *, final_save: bool = True,
             timeout: float = 30.0) -> None:
        """Stop the thread, optionally take one last snapshot, flush
        the manager's writer, and re-raise any deferred failure."""
        self._stop.set()
        self._thread.join(timeout=timeout)
        if self.failure is not None:
            raise self.failure
        if final_save:
            self.save_now()
        self.manager.wait()


def sleep_until(deadline: float) -> None:  # pragma: no cover - trivial
    time.sleep(max(0.0, deadline - time.monotonic()))


__all__ = ["SNAPSHOT_VERSION", "snapshot_server", "restore_server",
           "restore_latest", "ServerSnapshotter",
           "capture_policy_state", "restore_policy_state"]
