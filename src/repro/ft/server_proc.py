"""Restartable out-of-process parameter server.

``ServerProcess`` hosts a ``build_server(spec)`` +
``PSServerEndpoint`` + ``TcpTransport`` stack in its *own* spawned
process, which is what makes killing it meaningful: SIGKILL takes out
the packed store, every live socket, and every in-flight push — the
honest failure model for a parameter-server machine dropping off the
fleet.

The failover loop the chaos tests (and a real deployment script)
drive:

    sp = ServerProcess(spec)         # spec.ft.dir names the ckpt dir
    addr = sp.start()                # fresh run: no snapshot to load
    ... workers train, snapshotter checkpoints every snapshot_every_s
    sp.kill()                        # SIGKILL — or the machine dies
    addr2 = sp.restart()             # same port, resumes from latest
                                     # snapshot; workers' reconnect
                                     # loops re-HELLO and full-resync

``restart`` rebinds the SAME host:port (``socket.create_server`` sets
SO_REUSEADDR on POSIX), so the address workers hold stays valid across
the failover — their backoff loop only has to outlast the restart.

tcp only: shmem segments die with the process that owns them, so a
killed shmem server takes the transport down unrecoverably (spec
validation enforces this).

In-process faults: ``spec.ft.fault_kill_server_round >= 0`` arms a
watchdog thread that SIGKILLs the server the moment its aggregate push
count crosses the round — deterministic in *round* (the paper's unit
of progress), not in wall-clock.  A restarted incarnation never
re-arms the watchdog.

The server-side trace ring spills to ``<trace_spill>/server<i>.jsonl``
on a short cadence, so ``snapshot_shard``/``snapshot`` spans survive
the SIGKILL and the parent's collector can still assert the
per-shard-pause bound after the chaos run.
"""

from __future__ import annotations

import json
import multiprocessing
import os
import signal
import threading
import time
from typing import Any, Dict, Optional, Tuple

_SPILL_PERIOD_S = 0.2


def _spill_loop(trace, path: str, stop: threading.Event) -> None:
    with open(path, "a", encoding="utf-8") as fh:
        while True:
            stopped = stop.wait(_SPILL_PERIOD_S)
            for e in trace.drain():
                fh.write(json.dumps(e, separators=(",", ":")))
                fh.write("\n")
            fh.flush()
            if stopped:
                return


def _server_main(spec_dict: Dict[str, Any], port: int, queue,
                 trace_spill: str, kill_server_round: int,
                 incarnation: int) -> None:
    """Entry point of the spawned server process."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from repro.api.session import build_server
    from repro.api.spec import RunSpec
    from repro.checkpoint.manager import CheckpointManager
    from repro.ft.snapshot import ServerSnapshotter, restore_latest
    from repro.obs.trace import TRACE
    from repro.transport import PSServerEndpoint
    from repro.transport.tcp import TcpTransport

    spec = RunSpec.from_dict(spec_dict)
    spill_stop = threading.Event()
    if spec.obs.trace or trace_spill:
        TRACE.enable(source=f"server{incarnation}")
    if trace_spill:
        os.makedirs(trace_spill, exist_ok=True)
        threading.Thread(
            target=_spill_loop,
            args=(TRACE, os.path.join(trace_spill,
                                      f"server{incarnation}.jsonl"),
                  spill_stop),
            name="ft-trace-spill", daemon=True).start()

    server = build_server(spec)
    manager = CheckpointManager(spec.ft.dir, keep=spec.ft.keep)
    # Resume BEFORE serving: the endpoint's pull cache is keyed by
    # version, and a restore lowers versions — nothing may be served
    # from the pre-restore state.
    resumed_step = restore_latest(server, manager)
    endpoint = PSServerEndpoint(server)
    transport = TcpTransport(spec.transport.host, port)
    transport.serve(endpoint)

    snapshotter = None
    if spec.ft.snapshot_every_s > 0:
        snapshotter = ServerSnapshotter(
            server, manager, spec.ft.snapshot_every_s).start()

    if kill_server_round >= 0:
        def watchdog() -> None:  # pragma: no cover - dies via SIGKILL
            while server.metrics.total_pushes < kill_server_round:
                time.sleep(0.005)
            os.kill(os.getpid(), signal.SIGKILL)
        threading.Thread(target=watchdog, name="ft-kill-watchdog",
                         daemon=True).start()

    stop = threading.Event()
    if spec.ft.reshards:
        # Live-reshard trigger: manual (aggregate push round) and/or
        # the hot-shard policy (per-shard applied-update growth read
        # off the server's version vector — the obs per-shard push
        # metric).  Re-armed in EVERY incarnation: reshard() to an
        # arity the restore already reached is a no-op, and a restart
        # that resumed from a pre-migration snapshot gets to finish
        # the move.  The mid-migration SIGKILL fires once, in the
        # first incarnation only (mirrors the kill watchdog).
        armed_kill = (spec.ft.fault_kill_mid_reshard
                      and incarnation == 0)

        def _mid_hook(shard_index: int) -> None:
            # Fires after each old shard's state is copied out; dying
            # at index >= 1 leaves the migration genuinely mid-flight.
            if shard_index >= 1:  # pragma: no cover - dies via SIGKILL
                os.kill(os.getpid(), signal.SIGKILL)

        def reshard_trigger() -> None:
            target = spec.ft.reshard_shards
            round_ = spec.ft.reshard_round
            hot = spec.ft.reshard_hot_factor
            last = server.shard_versions()
            while not stop.is_set() and not server.stopped:
                time.sleep(0.02)
                if round_ >= 0 \
                        and server.metrics.total_pushes >= round_:
                    server.reshard(
                        target,
                        _mid_hook=_mid_hook if armed_kill else None)
                    return
                if hot > 0.0:
                    cur = server.shard_versions()
                    if len(cur) == len(last):
                        deltas = [c - b for c, b in zip(cur, last)]
                        total = sum(deltas)
                        if total > 0 and max(deltas) > \
                                hot * (total / len(deltas)):
                            server.reshard(target)
                            return
                    last = cur

        threading.Thread(target=reshard_trigger,
                         name="ft-reshard-trigger",
                         daemon=True).start()
    signal.signal(signal.SIGTERM, lambda *_: stop.set())
    queue.put(("up", transport.address(), resumed_step))
    stop.wait()

    # Graceful shutdown: final snapshot, release gated pushes with a
    # STOP, tear the wire down, flush the trace spill.
    if snapshotter is not None:
        try:
            snapshotter.stop(final_save=True)
        except Exception:
            pass  # a torn final save must not block the shutdown
    server.stop()
    transport.shutdown()
    server.shutdown()
    spill_stop.set()
    time.sleep(2 * _SPILL_PERIOD_S)  # let the spill thread drain
    queue.put(("down", server.metrics.total_pushes, None))


class ServerProcess:
    """Parent-side handle on one spawned, restartable server."""

    def __init__(self, spec, *, port: int = 0, trace_spill: str = "",
                 mp_context: str = "spawn",
                 start_timeout: float = 120.0):
        self.spec = spec
        self.port = port            # 0 = ephemeral on first start
        self.trace_spill = trace_spill
        self.start_timeout = start_timeout
        self.incarnation = 0
        self.resumed_step: Optional[int] = None
        self.address: Optional[Tuple] = None
        self._ctx = multiprocessing.get_context(mp_context)
        self._queue = self._ctx.Queue()
        self._proc: Optional[multiprocessing.Process] = None

    # -- lifecycle ----------------------------------------------------
    def start(self) -> Tuple:
        """Spawn (or respawn) the server; blocks until it serves.
        Returns its transport address — stable across restarts."""
        if self._proc is not None and self._proc.is_alive():
            raise RuntimeError("server process already running")
        # The FaultPlan's server-kill only fires in the FIRST
        # incarnation: a restarted server must get to finish the run.
        kill_round = (self.spec.ft.fault_kill_server_round
                      if self.incarnation == 0 else -1)
        self._proc = self._ctx.Process(
            target=_server_main,
            args=(self.spec.to_dict(), self.port, self._queue,
                  self.trace_spill, kill_round, self.incarnation),
            name=f"ft-ps-server-{self.incarnation}", daemon=True)
        self._proc.start()
        deadline = time.monotonic() + self.start_timeout
        while True:
            try:
                tag, addr, resumed = self._queue.get(timeout=1.0)
            except Exception:
                if not self._proc.is_alive():
                    raise RuntimeError(
                        f"server process died during startup (exit "
                        f"{self._proc.exitcode})") from None
                if time.monotonic() > deadline:
                    raise RuntimeError("server startup timed out")
                continue
            if tag == "up":
                break
        self.address = addr
        self.resumed_step = resumed
        # Pin the ephemeral port the first bind chose so every restart
        # lands on the address the workers are retrying against.
        self.port = addr[2]
        self.incarnation += 1
        return addr

    def restart(self) -> Tuple:
        """Failover: reap the corpse, respawn on the same port (the new
        incarnation resumes from the latest snapshot in spec.ft.dir)."""
        if self._proc is not None:
            self._proc.join(timeout=10.0)
        return self.start()

    def is_alive(self) -> bool:
        return self._proc is not None and self._proc.is_alive()

    def wait_dead(self, timeout: float = 60.0) -> bool:
        """Block until the server process exits (a FaultPlan kill is
        asynchronous); False on timeout."""
        deadline = time.monotonic() + timeout
        while self.is_alive():
            if time.monotonic() > deadline:
                return False
            time.sleep(0.01)
        return True

    def kill(self) -> None:
        """SIGKILL — the crash case.  No flush, no final snapshot."""
        if self._proc is not None and self._proc.is_alive():
            self._proc.kill()
            self._proc.join(timeout=10.0)

    def stop(self) -> None:
        """SIGTERM — the graceful case: final snapshot, STOP replies to
        gated workers, clean socket teardown."""
        if self._proc is not None and self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(timeout=30.0)

    def __enter__(self) -> "ServerProcess":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
        self.kill()


__all__ = ["ServerProcess"]
