"""Fault tolerance: snapshots, failover, reconnect, chaos injection.

Submodules (see ``src/repro/ft/README.md`` for the protocol):

  * ``backoff``     — shared bounded-exponential-backoff retry helper
  * ``snapshot``    — server state capture/restore + ``ServerSnapshotter``
  * ``faults``      — deterministic ``FaultPlan`` chaos injection
  * ``server_proc`` — restartable out-of-process server host
  * ``reshard``     — live shard migration (S -> S' without stopping)

Only ``backoff`` is imported eagerly (it is stdlib-only and the
transport layer depends on it); the rest load lazily so importing
``repro.transport`` never drags jax-adjacent snapshot code into a
spawned worker that does not need it.
"""

from __future__ import annotations

from repro.ft.backoff import (  # noqa: F401
    BackoffPolicy,
    CONNECT_POLICY,
    RECONNECT_POLICY,
    retry,
)

_LAZY = {
    "snapshot_server": "repro.ft.snapshot",
    "restore_server": "repro.ft.snapshot",
    "restore_latest": "repro.ft.snapshot",
    "ServerSnapshotter": "repro.ft.snapshot",
    "SNAPSHOT_VERSION": "repro.ft.snapshot",
    "FaultPlan": "repro.ft.faults",
    "FaultyChannel": "repro.ft.faults",
    "wrap_channel": "repro.ft.faults",
    "ServerProcess": "repro.ft.server_proc",
    "MigrationMap": "repro.ft.reshard",
    "RegionMove": "repro.ft.reshard",
    "build_migration": "repro.ft.reshard",
    "live_reshard": "repro.ft.reshard",
    "spread_versions": "repro.ft.reshard",
    "equalized_counts": "repro.ft.reshard",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.ft' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)


__all__ = ["BackoffPolicy", "CONNECT_POLICY", "RECONNECT_POLICY",
           "retry", *_LAZY]
