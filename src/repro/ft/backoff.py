"""Bounded exponential backoff with deterministic jitter.

One policy object serves every retry loop in the fault-tolerance
stack — the tcp client's initial connect (worker spawn vs server bind
races), the failover reconnect loop in ``PSTransportClient``, and the
proc-pool worker's resume-after-server-death path — so chaos tests can
reason about exactly how long a given failure takes to surface.

Jitter is seeded (``random.Random(seed)``), never ambient: two retry
loops constructed with the same policy and seed sleep the same
schedule, which is what makes the CI chaos runs reproducible.

Stdlib-only on purpose: this module is imported by the transport
client, which spawned worker processes import before jax.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Iterator, Optional, Tuple, Type


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Delay schedule: ``base_s * factor**i`` capped at ``max_s``, at
    most ``max_tries`` attempts, each delay jittered by up to
    ``jitter`` (a fraction of the delay, added)."""

    base_s: float = 0.05
    factor: float = 2.0
    max_s: float = 2.0
    max_tries: int = 8
    jitter: float = 0.25

    def __post_init__(self):
        if self.base_s <= 0 or self.max_s <= 0:
            raise ValueError("backoff delays must be positive")
        if self.factor < 1.0:
            raise ValueError("backoff factor must be >= 1")
        if self.max_tries < 1:
            raise ValueError("backoff needs at least one try")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter is a fraction of the delay in [0, 1]")

    def delays(self, seed: int = 0) -> Iterator[float]:
        """The deterministic sleep schedule: one delay per retry (so
        ``max_tries`` attempts yield ``max_tries - 1`` delays)."""
        rng = random.Random(seed)
        for i in range(self.max_tries - 1):
            d = min(self.base_s * (self.factor ** i), self.max_s)
            yield d * (1.0 + self.jitter * rng.random())


#: Conservative default for the initial tcp connect: ~10 tries over
#: roughly three seconds — enough to absorb a worker-spawn vs
#: server-bind race without masking a genuinely absent server forever.
CONNECT_POLICY = BackoffPolicy(base_s=0.05, factor=1.7, max_s=0.8,
                               max_tries=10)

#: Failover reconnect: a restarting server has to reload a checkpoint
#: and rebind, so back off further and longer before giving up.
RECONNECT_POLICY = BackoffPolicy(base_s=0.1, factor=2.0, max_s=2.0,
                                 max_tries=12)


def retry(fn: Callable, policy: BackoffPolicy, *, seed: int = 0,
          retry_on: Tuple[Type[BaseException], ...] = (OSError,),
          sleep: Callable[[float], None] = time.sleep,
          on_retry: Optional[Callable[[int, BaseException], None]] = None):
    """Call ``fn()`` up to ``policy.max_tries`` times, sleeping the
    policy's jittered schedule between attempts.  Re-raises the last
    failure when the budget is exhausted; ``on_retry(attempt, exc)``
    observes each intermediate failure (telemetry hooks)."""
    schedule = policy.delays(seed)
    attempt = 0
    while True:
        try:
            return fn()
        except retry_on as e:
            attempt += 1
            delay = next(schedule, None)
            if delay is None:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            sleep(delay)


__all__ = ["BackoffPolicy", "CONNECT_POLICY", "RECONNECT_POLICY", "retry"]
