"""repro.api — the declarative session layer.

One frozen ``RunSpec`` describes the whole run (model, data, optimizer,
sync paradigm, server kind, wire format, transport); ``build_session``
turns it into a context-managed ``TrainingSession``; every server
implements ``ParameterServerProtocol`` so no caller ever branches on a
concrete server type.

    from repro.api import RunSpec, SyncSpec, ServerSpec, build_session

    spec = RunSpec(sync=SyncSpec(mode="dssp", s_lower=1, s_upper=4),
                   ps=ServerSpec(kind="sharded", shards=4, workers=4))
    with build_session(spec) as session:
        session.run(steps=200)
        print(session.metrics())

Schema lock: ``python -m repro.api --dump-schema`` (CI diffs it against
the checked-in ``schema.json``).  Field reference + migration table
from the old flag/constructor surface: ``src/repro/api/README.md``.
"""

from repro.api.protocol import ParameterServerProtocol
from repro.api.session import (
    SpmdSession,
    ThreadedPSSession,
    TrainingSession,
    TransportPSSession,
    build_server,
    build_session,
    register_engine,
    register_server,
)
from repro.api.spec import (
    APPLY_MODES,
    CUSTOM_ARCH,
    DataSpec,
    FtSpec,
    ModelSpec,
    ObsSpec,
    OptimizerSpec,
    RunSpec,
    SERVER_KINDS,
    SPEC_VERSION,
    ServeSpec,
    ServerSpec,
    SpecError,
    SYNC_MODES,
    SyncSpec,
    TRANSPORT_KINDS,
    TransportSpec,
    WIRE_COMPRESSIONS,
    WIRE_FORMATS,
    WireSpec,
    dump_schema,
)

__all__ = [
    "APPLY_MODES",
    "CUSTOM_ARCH",
    "DataSpec",
    "FtSpec",
    "ModelSpec",
    "ObsSpec",
    "OptimizerSpec",
    "ParameterServerProtocol",
    "RunSpec",
    "SERVER_KINDS",
    "SPEC_VERSION",
    "SYNC_MODES",
    "ServeSpec",
    "ServerSpec",
    "SpecError",
    "SpmdSession",
    "SyncSpec",
    "TRANSPORT_KINDS",
    "ThreadedPSSession",
    "TrainingSession",
    "TransportPSSession",
    "TransportSpec",
    "WIRE_COMPRESSIONS",
    "WIRE_FORMATS",
    "WireSpec",
    "build_server",
    "build_session",
    "dump_schema",
    "register_engine",
    "register_server",
]
