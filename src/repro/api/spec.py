"""The declarative run specification: one frozen config tree per run.

``RunSpec`` describes *what* to train and *how* the distributed pieces
fit together — model, data, optimizer, synchronization paradigm, server
kind, wire format, transport — and validates the whole combination at
construction time.  Invalid combinations (a tree wire over a process
transport, a fused apply on the monolithic server, ASP on the SPMD
pipeline, ...) raise ``SpecError`` with an actionable message instead
of failing deep inside a worker thread.

The tree is plain data: ``to_dict``/``from_dict`` round-trip it
bitwise, ``to_json``/``from_json`` wrap that for files, and
``dump_schema`` emits the full field/choice/default schema (the CI
API-surface lock: ``python -m repro.api --dump-schema``).

Importing this module is light (no jax), so tooling can load and
``dump_schema`` anywhere.  *Constructing* a spec whose ``model.arch``
names a registry architecture imports ``repro.configs`` (and thus jax)
to validate the name; ``arch='custom'`` stays import-free.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, Optional

#: Bump when a field changes meaning; ``from_dict`` accepts its own
#: version only (the schema lock makes accidental drift loud).
SPEC_VERSION = 1

SYNC_MODES = ("bsp", "asp", "ssp", "dssp")
ESTIMATORS = ("last", "ema", "median")
SERVER_KINDS = ("none", "mono", "sharded")
APPLY_MODES = ("tree", "fused", "packed")
GATING_MODES = ("sharded", "global")
WIRE_FORMATS = ("tree", "packed")
WIRE_COMPRESSIONS = ("none", "int8", "topk")
TRANSPORT_KINDS = ("inproc", "tcp", "shmem")

#: Sentinel arch meaning "parameters are supplied at build time"
#: (benchmarks / toy problems that never touch the model registry).
CUSTOM_ARCH = "custom"


class SpecError(ValueError):
    """An invalid RunSpec field or combination of fields."""


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise SpecError(msg)


def _choice(value: str, field: str, choices) -> None:
    _require(value in choices,
             f"{field}={value!r} is not one of {list(choices)}")


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """What to train.  ``arch`` is a ``repro.configs`` key (dashed CLI
    id) or ``'custom'`` when params/step come from build-time
    overrides; ``smoke`` selects the reduced config.

    ``kernels`` selects worker-step kernel variants via the dispatch
    registry (``repro.kernels.registry``): ``'auto'`` (per-backend
    default — Pallas on TPU, the XLA formulations elsewhere), a bare
    variant applied to every op (``'pallas'``/``'xla'``), or
    comma-separated per-op overrides such as
    ``'attention=pallas,ssm_scan=xla_associative'``."""

    arch: str = "xlstm-125m"
    smoke: bool = True
    kernels: str = "auto"

    def __post_init__(self):
        _require(bool(self.arch), "model.arch must be a non-empty name")
        if self.arch != CUSTOM_ARCH:
            from repro.configs import arch_names  # light import
            _require(self.arch in arch_names(),
                     f"model.arch={self.arch!r} is not a known "
                     f"architecture (have {arch_names()} or "
                     f"{CUSTOM_ARCH!r} for build-time overrides)")
        # jax-free half of the kernel registry: validates the grammar
        # and the per-op variant tables without importing jax
        from repro.kernels.interface import parse_kernels
        try:
            parse_kernels(self.kernels)
        except ValueError as e:
            raise SpecError(str(e)) from e


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """The deterministic synthetic stream (vocab comes from the model)."""

    seq_len: int = 64
    global_batch: int = 8
    seed: int = 0

    def __post_init__(self):
        _require(self.seq_len > 0, "data.seq_len must be positive")
        _require(self.global_batch > 0,
                 "data.global_batch must be positive")


@dataclasses.dataclass(frozen=True)
class OptimizerSpec:
    """Update rule.  On the SPMD engine ``name`` is a ``repro.optim``
    optimizer (``None`` = the model config's default); on the PS
    engines the server steps SGD/momentum (``name`` must then be
    ``None``, ``'sgd'`` or ``'momentum'``).  ``staleness_damping=None``
    keeps each engine's historical default (SPMD: on, PS server:
    off)."""

    name: Optional[str] = None
    lr: float = 3e-3
    momentum: float = 0.0
    staleness_damping: Optional[bool] = None

    def __post_init__(self):
        _require(self.lr > 0, "optimizer.lr must be positive")
        _require(0.0 <= self.momentum < 1.0,
                 "optimizer.momentum must be in [0, 1)")


@dataclasses.dataclass(frozen=True)
class SyncSpec:
    """Synchronization paradigm (the paper's axis).  ``staleness`` is
    the SSP threshold; ``[s_lower, s_upper]`` the DSSP range;
    ``estimator`` the Algorithm-2 interval predictor."""

    mode: str = "dssp"
    staleness: int = 1
    s_lower: int = 0
    s_upper: int = 3
    estimator: str = "last"

    def __post_init__(self):
        _choice(self.mode, "sync.mode", SYNC_MODES)
        _choice(self.estimator, "sync.estimator", ESTIMATORS)
        _require(self.staleness >= 0, "sync.staleness must be >= 0")
        _require(0 <= self.s_lower <= self.s_upper,
                 f"sync range needs 0 <= s_lower <= s_upper, got "
                 f"[{self.s_lower}, {self.s_upper}]")

    def policy_factory(self, n_workers: int) -> Callable[[], Any]:
        """Zero-arg factory of fresh ``SyncPolicy`` instances for this
        paradigm — the spec-level face of ``make_policy_factory``."""
        from repro.core.policies import make_policy_factory
        return make_policy_factory(
            self.mode, n_workers=n_workers, staleness=self.staleness,
            s_lower=self.s_lower, s_upper=self.s_upper,
            estimator=self.estimator)


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    """Where the global weights live.

    ``kind='none'``    SPMD delayed-gradient pipeline (no server).
    ``kind='mono'``    monolithic ``ParameterServer`` (one lock);
                       ``apply`` in {tree, packed}.
    ``kind='sharded'`` ``ShardedParameterServer`` with ``shards``
                       partitions; ``apply`` in {tree, fused}.
    """

    kind: str = "none"
    shards: int = 0
    workers: int = 4
    apply: str = "tree"
    gating: str = "sharded"
    straggler: float = 1.0
    #: Coalescing window: up to this many concurrent workers' packed
    #: pushes fold through ONE batched kernel launch per shard.  1 =
    #: one launch per push (the historical behavior).
    coalesce: int = 1
    #: Flusher linger (milliseconds): how long an applying push waits
    #: for the window to fill before launching a partial batch.  The
    #: latency/batching trade — 0 batches only genuinely concurrent
    #: pushes; None keeps the server default (50 ms when coalescing).
    coalesce_wait_ms: Optional[float] = None

    def __post_init__(self):
        _choice(self.kind, "ps.kind", SERVER_KINDS)
        _choice(self.apply, "ps.apply", APPLY_MODES)
        _choice(self.gating, "ps.gating", GATING_MODES)
        _require(self.workers >= 1, "ps.workers must be >= 1")
        _require(self.straggler >= 1.0,
                 "ps.straggler is a slowdown factor (>= 1.0)")
        _require(self.coalesce >= 1,
                 "ps.coalesce is a window size (>= 1; 1 disables "
                 "coalescing)")
        _require(self.coalesce_wait_ms is None
                 or self.coalesce_wait_ms >= 0.0,
                 "ps.coalesce_wait_ms is a linger in milliseconds "
                 "(>= 0, or null for the server default)")
        if self.kind == "none":
            _require(self.shards == 0,
                     "ps.kind='none' (SPMD pipeline) takes ps.shards=0; "
                     "to shard a parameter server use ps.kind='sharded'")
            _require(self.apply == "tree",
                     "ps.apply selects a server apply path; the SPMD "
                     "pipeline (ps.kind='none') has none — leave it "
                     "'tree'")
            _require(self.coalesce == 1,
                     "ps.coalesce batches server-side applies; the SPMD "
                     "pipeline (ps.kind='none') has no server — set "
                     "ps.kind='mono'/'sharded' or leave ps.coalesce=1")
        elif self.kind == "mono":
            _require(self.shards in (0, 1),
                     "the monolithic server is one shard by definition "
                     f"(ps.shards={self.shards}); use ps.kind='sharded' "
                     "to partition")
            _require(self.apply != "fused",
                     "ps.apply='fused' is the sharded server's batched "
                     "apply; the monolithic server's packed path is "
                     "ps.apply='packed' (or use ps.kind='sharded')")
        else:  # sharded
            _require(self.shards >= 1,
                     "ps.kind='sharded' needs ps.shards >= 1")
            _require(self.apply != "packed",
                     "ps.apply='packed' is the monolithic server's "
                     "resident-wire mode; the sharded equivalent is "
                     "ps.apply='fused'")
        _require(self.gating == "sharded" or self.kind == "sharded",
                 "ps.gating='global' only applies to the sharded "
                 "server (it is the monolithic gating semantics)")


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """Push/pull representation: per-leaf pytrees or the zero-repack
    packed (rows, 512) buffer, plus gradient compression."""

    format: str = "tree"
    compression: str = "none"
    topk_fraction: float = 0.05
    #: Version-delta pulls: workers track the server's per-shard
    #: version vector and pull only the shard regions that advanced
    #: (full-snapshot fallback on mismatch).  Packed wire only.
    delta_pull: bool = False

    def __post_init__(self):
        _choice(self.format, "wire.format", WIRE_FORMATS)
        _choice(self.compression, "wire.compression", WIRE_COMPRESSIONS)
        _require(0.0 < self.topk_fraction <= 1.0,
                 "wire.topk_fraction must be in (0, 1]")


@dataclasses.dataclass(frozen=True)
class TransportSpec:
    """How workers reach the server.  ``inproc`` runs workers in the
    server's process (threads); ``tcp``/``shmem`` spawn real worker
    processes speaking the packed frame protocol.  ``endpoint=True``
    serves the frame codec even in-process (the serialization
    baseline)."""

    kind: str = "inproc"
    endpoint: bool = False
    host: str = "127.0.0.1"
    port: int = 0

    def __post_init__(self):
        _choice(self.kind, "transport.kind", TRANSPORT_KINDS)
        _require(0 <= self.port <= 65535,
                 "transport.port must be a port number (0 = ephemeral)")

    @property
    def serves_endpoint(self) -> bool:
        """True when the run speaks the frame protocol (always for the
        process transports; opt-in for inproc)."""
        return self.kind != "inproc" or self.endpoint


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Run-wide tracing & telemetry (``repro.obs``).

    ``trace=True`` arms the trace recorders everywhere the run executes
    (server process AND spawned workers — their rings merge into one
    timeline).  ``trace_path`` exports the merged trace on session
    close: ``.jsonl`` writes JSONL, anything else writes Chrome
    ``trace_event`` JSON (Perfetto-loadable).  ``sample_every`` > 0
    additionally samples server metrics (staleness histogram, per-worker
    wait, effective threshold) into the trace on that interval
    (seconds).
    """

    trace: bool = False
    trace_path: str = ""
    sample_every: float = 0.0

    def __post_init__(self):
        _require(self.sample_every >= 0.0,
                 "obs.sample_every is an interval in seconds (>= 0; "
                 "0 disables sampling)")
        if not self.trace:
            _require(not self.trace_path,
                     "obs.trace_path exports the recorded trace; it "
                     "needs obs.trace=true")
            _require(self.sample_every == 0.0,
                     "obs.sample_every samples into the recorded trace; "
                     "it needs obs.trace=true")


@dataclasses.dataclass(frozen=True)
class FtSpec:
    """Fault tolerance (``repro.ft``): server snapshots, failover
    resume, worker reconnect, and deterministic chaos injection.

    Snapshots (``snapshot_every_s > 0``) periodically checkpoint the
    server's packed per-shard buffers + momentum + version vector +
    sync-policy state into ``dir`` (keep-K, atomic); ``resume=True``
    restores the latest snapshot before serving.  ``reconnect_tries``
    arms the worker-side failover loop: on a dead server a worker
    backs off (``reconnect_base_s`` doubling up to ``reconnect_max_s``,
    jittered) and re-HELLOs up to that many times.  The ``fault_*``
    fields are the ``FaultPlan`` (kill the server at aggregate push
    round R; worker W SIGKILLs itself at its local iteration R';
    drop/delay frames of a wireformat kind) — ``-1``/``0.0`` sentinels
    mean "never", and the seed makes injected chaos reproducible.
    """

    snapshot_every_s: float = 0.0  # 0 disables periodic snapshots
    keep: int = 3                  # keep-K snapshot GC
    dir: str = ""                  # checkpoint directory
    resume: bool = False           # restore latest snapshot on start
    reconnect_tries: int = 0       # 0 disables worker reconnect
    reconnect_base_s: float = 0.1
    reconnect_max_s: float = 2.0
    fault_kill_server_round: int = -1
    fault_kill_worker: int = -1
    fault_kill_worker_round: int = -1
    fault_drop_kind: int = 0
    fault_drop_prob: float = 0.0
    fault_delay_kind: int = 0
    fault_delay_ms: float = 0.0
    fault_kill_mid_reshard: bool = False
    fault_seed: int = 0
    #: Live reshard (``repro.ft.reshard``): migrate the packed store to
    #: ``reshard_shards`` partitions WITHOUT stopping training, when
    #: the aggregate push count crosses ``reshard_round`` (manual
    #: trigger; -1 = never) and/or whenever one shard's share of the
    #: recent pushes exceeds ``reshard_hot_factor`` x the uniform share
    #: (hot-shard policy, read from the per-shard push metrics; 0
    #: disables).  0 shards disables resharding entirely.
    reshard_shards: int = 0
    reshard_round: int = -1
    reshard_hot_factor: float = 0.0

    def __post_init__(self):
        _require(self.snapshot_every_s >= 0.0,
                 "ft.snapshot_every_s is an interval in seconds (>= 0; "
                 "0 disables snapshots)")
        _require(self.keep >= 1, "ft.keep must keep at least one "
                 "snapshot (>= 1)")
        _require(self.reconnect_tries >= 0,
                 "ft.reconnect_tries must be >= 0 (0 disables worker "
                 "reconnect)")
        _require(self.reconnect_base_s > 0 and self.reconnect_max_s > 0,
                 "ft reconnect backoff delays must be positive")
        _require(0.0 <= self.fault_drop_prob <= 1.0,
                 "ft.fault_drop_prob is a probability in [0, 1]")
        _require(self.fault_delay_ms >= 0.0,
                 "ft.fault_delay_ms is a latency in milliseconds (>= 0)")
        if self.snapshot_every_s > 0 or self.resume:
            _require(bool(self.dir),
                     "ft snapshots/resume need ft.dir (the checkpoint "
                     "directory)")
        _require(self.reshard_shards >= 0,
                 "ft.reshard_shards is a target shard count (>= 1; 0 "
                 "disables live resharding)")
        _require(self.reshard_hot_factor >= 0.0,
                 "ft.reshard_hot_factor is a load-imbalance multiple "
                 "(> 1 makes sense; 0 disables the hot-shard policy)")
        if self.reshard_round >= 0 or self.reshard_hot_factor > 0.0:
            _require(self.reshard_shards >= 1,
                     "a reshard trigger (ft.reshard_round / "
                     "ft.reshard_hot_factor) needs a target arity: set "
                     "ft.reshard_shards >= 1")
        if self.fault_kill_mid_reshard:
            _require(self.reshard_shards >= 1 and self.reshard_round >= 0,
                     "ft.fault_kill_mid_reshard kills the server inside "
                     "a live migration — arm one with ft.reshard_round "
                     ">= 0 and ft.reshard_shards >= 1")

    @property
    def snapshots(self) -> bool:
        return self.snapshot_every_s > 0 or self.resume

    @property
    def reshards(self) -> bool:
        """Is a live reshard armed (by round and/or hot-shard policy)?"""
        return self.reshard_shards >= 1 and (
            self.reshard_round >= 0 or self.reshard_hot_factor > 0.0)

    @property
    def faults(self) -> bool:
        return (self.fault_kill_server_round >= 0
                or (self.fault_kill_worker >= 0
                    and self.fault_kill_worker_round >= 0)
                or self.fault_drop_prob > 0.0 or self.fault_delay_ms > 0.0
                or self.fault_kill_mid_reshard)

    def fault_plan(self):
        """The picklable ``repro.ft.FaultPlan`` these fields describe."""
        from repro.ft.faults import FaultPlan
        return FaultPlan(
            kill_server_round=self.fault_kill_server_round,
            kill_worker=self.fault_kill_worker,
            kill_worker_round=self.fault_kill_worker_round,
            drop_kind=self.fault_drop_kind,
            drop_prob=self.fault_drop_prob,
            delay_kind=self.fault_delay_kind,
            delay_ms=self.fault_delay_ms,
            kill_mid_reshard=self.fault_kill_mid_reshard,
            seed=self.fault_seed)


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Online serving tier (``repro.serve``): N replicas ride the SAME
    run as the training workers, keeping a resident packed parameter
    buffer fresh over the transport via version-delta pulls and serving
    decode requests through a continuous-batching queue.

    ``staleness_bound`` is the SSP-style freshness contract mirrored to
    the consumer side: a replica whose resident version vector trails
    the server by more than this many applied updates BLOCKS admission
    (forcing an immediate refresh) instead of serving stale weights —
    the serving analogue of the training gate's bound on gradient
    staleness.  ``refresh_every_s`` is the background refresh cadence
    between forced refreshes; ``batch_window_ms``/``max_batch`` shape
    the continuous-batching window; ``requests``/``prompt_len``/
    ``max_new`` size each replica's closed-loop request stream and
    ``request_every_ms`` paces it (so serving can be spread across the
    training run instead of bursting up front).
    """

    replicas: int = 0              # 0 disables the serving tier
    refresh_every_s: float = 0.05  # background delta-pull cadence
    staleness_bound: int = 4       # max versions behind at admission
    batch_window_ms: float = 2.0   # continuous-batching linger
    max_batch: int = 8             # decode requests per batch
    requests: int = 32             # closed-loop requests per replica
    request_every_ms: float = 0.0  # pacing between submits (0 = burst)
    start_at_version: int = 0      # delay serving until the server has
                                   # applied this many updates (0 = now)
    prompt_len: int = 16
    max_new: int = 8

    def __post_init__(self):
        _require(self.replicas >= 0,
                 "serve.replicas must be >= 0 (0 disables serving)")
        _require(self.refresh_every_s > 0.0,
                 "serve.refresh_every_s is the replica refresh cadence "
                 "in seconds (> 0)")
        _require(self.staleness_bound >= 0,
                 "serve.staleness_bound is the max applied updates a "
                 "replica may trail the server at admission (>= 0)")
        _require(self.batch_window_ms >= 0.0,
                 "serve.batch_window_ms is a linger in milliseconds "
                 "(>= 0; 0 batches only already-queued requests)")
        _require(self.max_batch >= 1, "serve.max_batch must be >= 1")
        _require(self.requests >= 1,
                 "serve.requests is each replica's closed-loop request "
                 "count (>= 1)")
        _require(self.request_every_ms >= 0.0,
                 "serve.request_every_ms paces the request stream in "
                 "milliseconds (>= 0; 0 submits as fast as possible)")
        _require(self.start_at_version >= 0,
                 "serve.start_at_version delays the request stream "
                 "until the server has applied that many updates "
                 "(>= 0; 0 serves from the initial weights)")
        _require(self.prompt_len >= 1, "serve.prompt_len must be >= 1")
        _require(self.max_new >= 1, "serve.max_new must be >= 1")


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """The whole run, validated as a unit.

    Cross-field rules (each raises ``SpecError`` at construction):

    * process transports (tcp/shmem) and in-process endpoints carry the
      packed wire format only — ``wire.format='tree'`` is rejected;
    * the packed wire needs a packed-resident store — ``ps.apply`` must
      be ``'packed'`` (mono) or ``'fused'`` (sharded);
    * the SPMD pipeline (``ps.kind='none'``) trains bsp/ssp/dssp only
      (ASP exists in the PS layer) and has no packed wire;
    * process transports need a parameter server and a registry arch
      (spawned workers rebuild the model from its config name);
    * compression needs an engine with a compression path (SPMD or the
      sharded server);
    * ``wire.delta_pull`` (version-delta pulls) and ``ps.coalesce > 1``
      (batched server apply) ride the packed wire only — over the tree
      wire both raise;
    * ``ft`` snapshots capture the packed-resident store, so they need
      a parameter server with ``ps.apply='fused'``/``'packed'``; the
      ``FaultPlan`` kills/drops cross a process boundary, so faults and
      worker reconnect need a process transport (and killing/restarting
      the server needs tcp — shmem segments die with their owner);
    * ``serve.replicas > 0`` rides the delta-pull protocol: it needs a
      parameter server, the packed wire with ``wire.delta_pull=true``,
      and a registry arch (replicas rebuild the decode path from the
      config name — ``'custom'`` cannot cross the spawn boundary).
    """

    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    optimizer: OptimizerSpec = dataclasses.field(
        default_factory=OptimizerSpec)
    sync: SyncSpec = dataclasses.field(default_factory=SyncSpec)
    ps: ServerSpec = dataclasses.field(default_factory=ServerSpec)
    wire: WireSpec = dataclasses.field(default_factory=WireSpec)
    transport: TransportSpec = dataclasses.field(
        default_factory=TransportSpec)
    obs: ObsSpec = dataclasses.field(default_factory=ObsSpec)
    ft: FtSpec = dataclasses.field(default_factory=FtSpec)
    serve: ServeSpec = dataclasses.field(default_factory=ServeSpec)

    def __post_init__(self):
        ps, wire, tp, sync = self.ps, self.wire, self.transport, self.sync
        ft = self.ft
        if self.serve.replicas > 0:
            _require(ps.kind != "none",
                     "serve.replicas subscribe to a live parameter "
                     "server; the SPMD pipeline (ps.kind='none') has "
                     "none — set ps.kind='mono'/'sharded'")
            _require(wire.format == "packed",
                     "serving replicas keep a resident packed buffer; "
                     "set wire.format='packed' (and ps.apply='fused'/"
                     "'packed')")
            _require(wire.delta_pull,
                     "serving replicas refresh via version-delta pulls "
                     "(bytes proportional to change — the high-"
                     "frequency refresh path); set wire.delta_pull="
                     "true")
            _require(self.model.arch != CUSTOM_ARCH,
                     "serving replicas rebuild the decode path from "
                     "the model config name — model.arch='custom' "
                     "cannot serve; name a registry architecture")
        if ft.snapshots:
            _require(ps.kind != "none",
                     "ft snapshots checkpoint a parameter server's "
                     "packed store; the SPMD pipeline (ps.kind='none') "
                     "has its own checkpointing — set ps.kind='mono'/"
                     "'sharded'")
            _require(ps.apply in ("fused", "packed"),
                     "ft snapshots capture the packed-resident store; "
                     "ps.apply='tree' keeps no packed buffers to "
                     "snapshot — set ps.apply='fused' (sharded) or "
                     "'packed' (mono)")
        if ft.reshards:
            _require(ps.kind == "sharded" and ps.apply == "fused",
                     "ft.reshard_* migrates packed regions between the "
                     "sharded server's stores; set ps.kind='sharded' "
                     "and ps.apply='fused'")
            _require(wire.format == "packed" and wire.delta_pull,
                     "live resharding resyncs clients through the "
                     "version-delta full-pull fallback; set wire."
                     "format='packed' and wire.delta_pull=true")
            _require(tp.kind in ("tcp", "shmem"),
                     "live resharding changes the wire layout under "
                     "running workers, which only the frame protocol "
                     "renegotiates — set transport.kind='tcp' or "
                     "'shmem'")
            _require(ft.reshard_shards != ps.shards,
                     f"ft.reshard_shards={ft.reshard_shards} equals "
                     "ps.shards — a live reshard to the same arity is "
                     "a no-op")
        if ft.faults:
            _require(tp.kind != "inproc",
                     "the FaultPlan kills processes and drops frames; "
                     "over transport.kind='inproc' there is no process "
                     "boundary to fault — set transport.kind='tcp' or "
                     "'shmem'")
        if (ft.fault_kill_server_round >= 0 or ft.reconnect_tries > 0
                or ft.fault_kill_mid_reshard):
            _require(tp.kind == "tcp",
                     "killing/restarting the server (and reconnecting "
                     "to it) needs transport.kind='tcp': shmem segments "
                     "die with the server process, so there is nothing "
                     "left to reconnect to")
        if ps.kind == "none":
            _require(sync.mode != "asp",
                     "sync.mode='asp' is not trainable on the SPMD "
                     "pipeline (ps.kind='none'); use a parameter server "
                     "(ps.kind='mono'/'sharded')")
            _require(wire.format == "tree",
                     "wire.format='packed' is the parameter-server hot "
                     "path; the SPMD pipeline has no wire — set "
                     "ps.kind='mono'/'sharded' or wire.format='tree'")
            _require(tp.kind == "inproc" and not tp.endpoint,
                     f"transport.kind={tp.kind!r} moves PS workers into "
                     "separate processes; the SPMD pipeline "
                     "(ps.kind='none') has no PS workers — set "
                     "ps.kind='sharded' (or 'mono') to use a transport")
        if wire.format == "packed":
            _require(ps.apply in ("fused", "packed"),
                     "wire.format='packed' needs a packed-resident "
                     "store: ps.apply='packed' (mono) or 'fused' "
                     "(sharded); ps.apply='tree' re-packs every push")
        if wire.delta_pull:
            _require(wire.format == "packed",
                     "wire.delta_pull serves version-delta pulls of the "
                     "packed snapshot; the tree wire has no per-shard "
                     "version vector to diff against — set wire.format="
                     "'packed' (and ps.apply='fused'/'packed')")
        if ps.coalesce > 1:
            _require(wire.format == "packed",
                     "ps.coalesce batches packed wire buffers through "
                     "one fused launch; the tree wire has nothing to "
                     "stack — set wire.format='packed' (and ps.apply="
                     "'fused'/'packed')")
        if tp.serves_endpoint:
            _require(wire.format == "packed",
                     f"transport.kind={tp.kind!r} carries the packed "
                     "frame protocol only — wire.format='tree' cannot "
                     "cross a process boundary; set wire.format="
                     "'packed' (and ps.apply='fused'/'packed')")
        if tp.kind != "inproc":
            _require(ps.kind != "none",
                     "process transports live in the PS layer; set "
                     "ps.kind='mono' or 'sharded'")
        if wire.compression != "none":
            _require(ps.kind != "mono",
                     f"wire.compression={wire.compression!r} has no "
                     "monolithic-server path; use ps.kind='sharded' "
                     "(fused wire compression) or ps.kind='none' "
                     "(worker-side error feedback)")
        if ps.kind != "none" and self.optimizer.name is not None:
            _require(self.optimizer.name in ("sgd", "momentum"),
                     f"optimizer.name={self.optimizer.name!r}: the "
                     "parameter server steps SGD/momentum (workers send "
                     "raw gradients); rich optimizers run on the SPMD "
                     "engine (ps.kind='none')")

    # ------------------------------------------------------------ dicts
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["version"] = SPEC_VERSION
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunSpec":
        if not isinstance(d, dict):
            raise SpecError(f"spec must be a dict, got {type(d).__name__}")
        d = dict(d)
        version = d.pop("version", SPEC_VERSION)
        _require(version == SPEC_VERSION,
                 f"spec version {version!r} != supported {SPEC_VERSION}")
        sections = {f.name: f for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - set(sections))
        _require(not unknown,
                 f"unknown spec section(s) {unknown}; valid sections: "
                 f"{sorted(sections)}")
        kwargs = {}
        for name, field in sections.items():
            sub = d.get(name)
            if sub is None:
                continue
            sub_cls = field.default_factory
            kwargs[name] = _sub_from_dict(sub_cls, name, sub)
        return cls(**kwargs)

    # ------------------------------------------------------------ json
    def to_json(self, **json_kw) -> str:
        json_kw.setdefault("indent", 2)
        json_kw.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **json_kw)

    @classmethod
    def from_json(cls, text: str) -> "RunSpec":
        try:
            d = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"spec is not valid JSON: {e}") from e
        return cls.from_dict(d)

    # ------------------------------------------------------- conveniences
    def replace(self, **sections) -> "RunSpec":
        """``dataclasses.replace`` that re-runs whole-tree validation."""
        return dataclasses.replace(self, **sections)

    @property
    def engine(self) -> str:
        """Which session engine this spec selects (see repro.api.session)."""
        if self.ps.kind == "none":
            return "spmd"
        if self.transport.serves_endpoint:
            return "ps-transport"
        return "ps-threads"


def _sub_from_dict(sub_cls, section: str, sub: Any):
    if not isinstance(sub, dict):
        raise SpecError(f"spec section {section!r} must be a dict, got "
                        f"{type(sub).__name__}")
    valid = {f.name for f in dataclasses.fields(sub_cls)}
    unknown = sorted(set(sub) - valid)
    _require(not unknown,
             f"unknown field(s) {unknown} in spec section {section!r}; "
             f"valid fields: {sorted(valid)}")
    return sub_cls(**sub)


# ----------------------------------------------------------------- schema
#: field -> closed choice set (the schema surfaces these; validation
#: enforces them in each dataclass's __post_init__).
_FIELD_CHOICES = {
    ("sync", "mode"): SYNC_MODES,
    ("sync", "estimator"): ESTIMATORS,
    ("ps", "kind"): SERVER_KINDS,
    ("ps", "apply"): APPLY_MODES,
    ("ps", "gating"): GATING_MODES,
    ("wire", "format"): WIRE_FORMATS,
    ("wire", "compression"): WIRE_COMPRESSIONS,
    ("transport", "kind"): TRANSPORT_KINDS,
}


def dump_schema() -> Dict[str, Any]:
    """Machine-readable schema of the RunSpec surface: every section,
    field, type, default and closed choice set.  Checked in at
    ``src/repro/api/schema.json`` and diffed by CI — any change to the
    public spec surface must update that file in the same PR."""
    schema: Dict[str, Any] = {"spec_version": SPEC_VERSION, "sections": {}}
    for sec_field in dataclasses.fields(RunSpec):
        if sec_field.name == "version":
            continue
        sub_cls = sec_field.default_factory
        fields = {}
        for f in dataclasses.fields(sub_cls):
            entry: Dict[str, Any] = {
                "type": _type_name(f.type),
                "default": f.default,
            }
            choices = _FIELD_CHOICES.get((sec_field.name, f.name))
            if choices is not None:
                entry["choices"] = list(choices)
            fields[f.name] = entry
        schema["sections"][sec_field.name] = {
            "class": sub_cls.__name__,
            "fields": fields,
        }
    return schema


def _type_name(annotation) -> str:
    text = annotation if isinstance(annotation, str) else str(annotation)
    return (text.replace("typing.", "")
                .replace("builtins.", "")
                .replace("<class '", "").replace("'>", ""))
