"""``build_session(spec) -> TrainingSession`` — the single wiring path.

Every way this repo trains (SPMD delayed-gradient pipeline, threaded
parameter server, process-isolated transport workers) is one *engine*
behind the same session surface:

    with build_session(spec) as session:      # start() on enter
        session.run(steps)                    # blocks until trained
        print(session.metrics())              # engine-uniform dict
                                              # close() on exit

Engines are registry-driven (``register_engine``) and selected from the
spec alone (``RunSpec.engine``); server construction is likewise
registry-driven (``register_server``).  All heavy imports (jax, the
model zoo, the transports) happen inside ``start()``/``run()`` so specs
can be built and validated anywhere — including spawned worker
processes and tooling that never trains.

Build-time overrides (keyword arguments to ``build_session``) inject
the pieces a spec cannot serialize: a custom parameter pytree, a custom
jitted step, per-worker batch iterators, per-worker speed factors.
They exist for benchmarks and toy problems (``model.arch='custom'``);
ordinary runs need none of them.

``external_workers=True`` builds and serves the run's server side only
— the caller drives its own clients (benchmark harnesses); ``run()``
is then invalid.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, List

from repro._compat import api_managed
from repro.api.spec import CUSTOM_ARCH, RunSpec, SpecError

_ENGINES: Dict[str, type] = {}
_SERVER_BUILDERS: Dict[str, Callable] = {}


def register_engine(name: str):
    """Class decorator: make ``name`` a buildable session engine."""
    def deco(cls):
        cls.engine = name
        _ENGINES[name] = cls
        return cls
    return deco


def register_server(kind: str):
    """Register a server builder ``fn(spec, params) -> server`` for
    ``ps.kind == kind``."""
    def deco(fn):
        _SERVER_BUILDERS[kind] = fn
        return fn
    return deco


def build_session(spec, **overrides) -> "TrainingSession":
    """The one public entry point: a validated ``RunSpec`` (or a plain
    dict in its ``to_dict`` shape) in, an unstarted session out."""
    if isinstance(spec, dict):
        spec = RunSpec.from_dict(spec)
    if not isinstance(spec, RunSpec):
        raise SpecError(
            f"build_session takes a RunSpec or its dict form, got "
            f"{type(spec).__name__}")
    engine = spec.engine
    cls = _ENGINES.get(engine)
    if cls is None:  # unreachable unless a registry entry was removed
        raise SpecError(f"no session engine registered for {engine!r} "
                        f"(have {sorted(_ENGINES)})")
    return cls(spec, **overrides)


def build_server(spec: RunSpec, params=None):
    """Construct (only) the spec's parameter server — the registry hook
    the sessions use.  Public for tests; everything else should go
    through ``build_session``."""
    builder = _SERVER_BUILDERS.get(spec.ps.kind)
    if builder is None:
        raise SpecError(f"no server builder registered for "
                        f"ps.kind={spec.ps.kind!r} "
                        f"(have {sorted(_SERVER_BUILDERS)})")
    if params is None:
        params = _registry_params(spec)
    with api_managed():
        return builder(spec, params)


# ===================================================================
# session base
# ===================================================================
class TrainingSession:
    """Context-managed lifecycle over one training run.

    ``start()`` builds the heavy pieces (server, transport, jitted
    steps), ``run(steps)`` trains, ``metrics()`` reports an
    engine-uniform summary, ``close()`` releases gated workers and
    tears transports down.  Idempotent: ``start`` after start and
    ``close`` after close are no-ops.
    """

    engine = "base"
    OVERRIDES: frozenset = frozenset({"verbose"})

    def __init__(self, spec: RunSpec, **overrides):
        unknown = sorted(set(overrides) - self.OVERRIDES)
        if unknown:
            raise SpecError(
                f"unknown build_session override(s) {unknown} for the "
                f"{self.engine!r} engine; valid overrides: "
                f"{sorted(self.OVERRIDES)}")
        self.spec = spec
        self.verbose = bool(overrides.get("verbose", False))
        self._ov = overrides
        self._started = False
        self._closed = False

    # -- lifecycle ----------------------------------------------------
    def start(self) -> "TrainingSession":
        if not self._started:
            with api_managed():
                self._start()
            self._started = True
        return self

    def run(self, steps: int) -> Dict[str, Any]:
        """Train for ``steps`` global steps (PS engines divide them
        across workers, matching the historical CLI semantics).
        Returns ``metrics()``."""
        if self._closed:
            raise SpecError("session is closed")
        self.start()
        with api_managed():
            self._run(int(steps))
        return self.metrics()

    def metrics(self) -> Dict[str, Any]:
        raise NotImplementedError

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._started:
            self._close()

    def __enter__(self) -> "TrainingSession":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- engine hooks -------------------------------------------------
    def _start(self) -> None:
        raise NotImplementedError

    def _run(self, steps: int) -> None:
        raise NotImplementedError

    def _close(self) -> None:
        pass


# ===================================================================
# observability rig
# ===================================================================
class _ObsRig:
    """Per-session lifecycle for ``spec.obs``: enables the server-side
    recorder, runs the metrics sampler, merges worker flushes/spills
    into one ``TraceCollector``, exports on finish.

    All ``repro.obs`` imports are local so specs with tracing off never
    pay for the package.
    """

    def __init__(self, obs):
        from repro.obs import TraceCollector
        self.obs = obs
        self.collector = TraceCollector()
        self.sampler = None
        self.spill_dir = None
        self.summary = None
        self._done = False

    def start(self, metrics_fn=None) -> None:
        from repro.obs.trace import TRACE
        TRACE.enable(source="server")
        if self.obs.sample_every > 0 and metrics_fn is not None:
            from repro.obs import MetricsSampler
            self.sampler = MetricsSampler(TRACE, metrics_fn,
                                          self.obs.sample_every)
            self.sampler.start()

    def make_spill_dir(self) -> str:
        """Temp dir spawned workers spill their rings into (recovered
        on finish, so a killed worker's events still reach the trace)."""
        import tempfile
        if self.spill_dir is None:
            self.spill_dir = tempfile.mkdtemp(prefix="repro-obs-spill-")
        return self.spill_dir

    def finish(self) -> None:
        """Stop sampling, drain + merge every source, export, summarize.
        Idempotent — sessions call it from both ``_run`` and ``_close``."""
        if self._done:
            return
        self._done = True
        import shutil
        from repro.obs import summarize, write_chrome_trace, write_jsonl
        from repro.obs.trace import TRACE
        if self.sampler is not None:
            self.sampler.stop()
        self.collector.ingest_local(TRACE, source="server")
        TRACE.disable()
        if self.spill_dir is not None:
            self.collector.ingest_spill_dir(self.spill_dir)
            shutil.rmtree(self.spill_dir, ignore_errors=True)
        events = self.collector.timeline()
        path = self.obs.trace_path
        if path:
            if path.endswith(".jsonl"):
                write_jsonl(events, path)
            else:
                write_chrome_trace(events, path)
        self.summary = summarize(events)


class _FtRig:
    """Per-session lifecycle for ``spec.ft`` on the PS engines: the
    checkpoint manager, the optional resume-before-serve, and the
    periodic ``ServerSnapshotter``.  All ``repro.ft`` imports are local
    so specs without fault tolerance never pay for the package."""

    def __init__(self, ft, server):
        from repro.checkpoint.manager import CheckpointManager
        from repro.ft.snapshot import ServerSnapshotter, restore_latest
        self.manager = CheckpointManager(ft.dir, keep=ft.keep)
        # Resume BEFORE anything serves: endpoint pull caches are keyed
        # by version, and a restore moves versions backwards.
        self.resumed_step = (restore_latest(server, self.manager)
                             if ft.resume else None)
        self.snapshotter = (
            ServerSnapshotter(server, self.manager,
                              ft.snapshot_every_s).start()
            if ft.snapshot_every_s > 0 else None)
        self._done = False

    def finish(self) -> None:
        """Final snapshot + writer flush; surfaces any async-save
        failure the snapshotter thread parked.  Idempotent."""
        if self._done:
            return
        self._done = True
        if self.snapshotter is not None:
            self.snapshotter.stop(final_save=True)
        self.manager.wait()

    def metrics(self) -> Dict[str, Any]:
        return {
            "resumed_step": self.resumed_step,
            "snapshots": (self.snapshotter.snapshots
                          if self.snapshotter else 0),
            "latest_step": self.manager.latest_step(),
        }


def _obs_snapshot_fn(server):
    """Sampler callable for the PS engines: counters + the policy's
    current effective staleness bound (the DSSP threshold timeline)."""
    from repro.perfcount import snapshot_all

    def snap() -> Dict[str, Any]:
        m = server.metrics
        out = {
            "pushes": m.total_pushes,
            "applied": m.applied_updates,
            "version": server.version,
            "total_wait": round(m.total_wait, 6),
            "max_staleness": m.max_staleness,
            "credit_releases": m.credit_releases,
            "perfcount": snapshot_all(),
        }
        shards = getattr(server, "shards", None)
        pol, trk = ((shards[0].policy, shards[0].tracker) if shards
                    else (getattr(server, "policy", None),
                          getattr(server, "tracker", None)))
        if pol is not None:
            bound = pol.effective_staleness_bound(trk)
            out["effective_threshold"] = (None if bound == float("inf")
                                          else float(bound))
        return out

    return snap


# ===================================================================
# server builders
# ===================================================================
def _server_optimizer_factory(spec: RunSpec):
    from repro.ps.server import ServerOptimizer
    opt = spec.optimizer
    damping = (False if opt.staleness_damping is None
               else opt.staleness_damping)
    momentum = opt.momentum if opt.name in (None, "sgd", "momentum") else 0.0
    return lambda: ServerOptimizer(lr=opt.lr, momentum=momentum,
                                   staleness_damping=damping)


def _coalesce_kwargs(spec: RunSpec) -> Dict[str, Any]:
    wait = spec.ps.coalesce_wait_ms
    return {"coalesce": spec.ps.coalesce,
            "coalesce_wait": None if wait is None else wait / 1e3}


def _compression_plan(spec: RunSpec):
    """(tree_compressor, wire_compression, frame_compress) — where the
    configured compression actually runs, per the transport/wire combo
    (frame-level int8 shrinks real wire bytes and dequantizes on
    receipt, so the server must not quantize again)."""
    packed = spec.wire.format == "packed"
    comp = spec.wire.compression
    frame = ("int8" if spec.transport.kind != "inproc" and comp == "int8"
             else "none")
    if frame != "none" or comp == "none":
        wire_compression = None
    else:
        wire_compression = comp if packed else None
    tree_compressor = comp if (not packed and comp != "none"
                               and frame == "none") else None
    return tree_compressor, wire_compression, frame


@register_server("mono")
def _build_mono(spec: RunSpec, params):
    from repro.ps.server import ParameterServer
    policy = spec.sync.policy_factory(spec.ps.workers)()
    return ParameterServer(
        params, policy, _server_optimizer_factory(spec)(),
        spec.ps.workers,
        apply_mode="packed" if spec.ps.apply == "packed" else "tree",
        **_coalesce_kwargs(spec))


@register_server("sharded")
def _build_sharded(spec: RunSpec, params):
    from repro.optim.compression import make_compressor
    from repro.ps.sharded import ShardedParameterServer
    tree_comp, wire_comp, _ = _compression_plan(spec)
    return ShardedParameterServer(
        params, spec.sync.policy_factory(spec.ps.workers),
        _server_optimizer_factory(spec),
        spec.ps.workers, spec.ps.shards,
        gating=spec.ps.gating, apply_mode=spec.ps.apply,
        compressor=make_compressor(tree_comp) if tree_comp else None,
        wire_compression=wire_comp,
        topk_fraction=spec.wire.topk_fraction,
        **_coalesce_kwargs(spec))


# ===================================================================
# shared model plumbing (PS engines)
# ===================================================================
def _model_setup(spec: RunSpec):
    from repro.configs import get_config, get_smoke_config
    from repro.data.synthetic import DataConfig
    if spec.model.arch == CUSTOM_ARCH:
        raise SpecError(
            "model.arch='custom' needs build-time overrides (params=, "
            "step_fn=, batches=); name a registry architecture to run "
            "the model zoo")
    cfg = (get_smoke_config(spec.model.arch) if spec.model.smoke
           else get_config(spec.model.arch))
    if spec.model.kernels != cfg.kernels:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, kernels=spec.model.kernels)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size,
                          seq_len=spec.data.seq_len,
                          global_batch=spec.data.global_batch,
                          seed=spec.data.seed)
    return cfg, data_cfg


def _registry_params(spec: RunSpec):
    import jax
    from repro.models import registry
    cfg, _ = _model_setup(spec)
    return registry.init_params(cfg, jax.random.PRNGKey(0))


def _speed_factors(spec: RunSpec, override) -> List[float]:
    w = spec.ps.workers
    if override is not None:
        if len(override) != w:
            raise SpecError(f"{len(override)} speed factors for "
                            f"{w} workers")
        return list(override)
    return [spec.ps.straggler if i == w - 1 else 1.0 for i in range(w)]


def _default_loss_from_aux(aux) -> float:
    return float(aux["loss"])


# ===================================================================
# serving rig (both PS engines)
# ===================================================================
def _serve_threads(session) -> tuple:
    """Start ``spec.serve.replicas`` in-heap replica threads against a
    live server (the ps-threads engine's serve tier: replicas read the
    server directly, no transport).  Returns ``(threads, results)`` —
    join the threads, then read the results list."""
    spec = session.spec
    if spec.serve.replicas <= 0:
        return [], []
    import threading
    import traceback

    from repro.data.synthetic import DataConfig, MarkovLM
    from repro.serve import (
        BatchQueue,
        Decoder,
        DirectSubscription,
        ParamSubscriber,
        Refresher,
        ReplicaResult,
        ReplicaWorker,
        drive_replica,
    )
    cfg, _ = _model_setup(spec)
    plan = session.server.plan
    layout = plan.wire_layout()
    sv = spec.serve
    w = spec.ps.workers
    results: List = [None] * sv.replicas
    threads = []

    def run_one(i: int, rid: int) -> None:
        sub = DirectSubscription(session.server, rid)
        subscriber = ParamSubscriber(sub, layout, replica_id=rid)
        refresher = Refresher(subscriber, sv.refresh_every_s)
        refresher.start()
        try:
            decoder = Decoder(cfg, plan, prompt_len=sv.prompt_len,
                              max_new=sv.max_new, max_batch=sv.max_batch)
            decoder.warmup()  # compile before the first real request
            chain = MarkovLM(DataConfig(
                vocab_size=cfg.vocab_size,
                seq_len=sv.prompt_len + sv.max_new, global_batch=1,
                seed=spec.data.seed + 1000 + rid))
            worker = ReplicaWorker(
                rid, subscriber, BatchQueue(), decoder,
                staleness_bound=sv.staleness_bound,
                batch_window_ms=sv.batch_window_ms,
                max_batch=sv.max_batch)
            results[i] = drive_replica(
                worker, chain, requests=sv.requests,
                prompt_len=sv.prompt_len,
                pace_s=sv.request_every_ms / 1e3,
                start_at_version=sv.start_at_version)
        except Exception:
            results[i] = ReplicaResult(rid,
                                       error=traceback.format_exc())
        finally:
            refresher.stop()

    for i in range(sv.replicas):
        # Replica ids sit AFTER the trainers' (workers 0..W-1), same
        # slot convention as the transport engine.
        t = threading.Thread(target=run_one, args=(i, w + i),
                             daemon=True, name=f"serve-replica-{w + i}")
        t.start()
        threads.append(t)
    return threads, results


# ===================================================================
# engine: SPMD delayed-gradient pipeline
# ===================================================================
@register_engine("spmd")
class SpmdSession(TrainingSession):
    """The delayed-gradient emulation (``repro.launch.train.Trainer``):
    one process, the DSSP delay re-tuned per step by the Algorithm-2
    controller, gradient collective off the critical path."""

    OVERRIDES = frozenset({
        "verbose", "model_config", "data_config", "checkpoint_dir",
        "save_every", "resume", "collective_time_fn", "rules",
    })

    trainer = None
    resumed = False
    obs_rig = None

    def _start(self) -> None:
        from repro.data.synthetic import DataConfig
        from repro.launch.train import Trainer
        spec = self.spec
        if spec.obs.trace:
            self.obs_rig = _ObsRig(spec.obs)
            self.obs_rig.start()  # one process: no PS counters to sample
        cfg = self._ov.get("model_config")
        if cfg is None:
            cfg, data_cfg = _model_setup(spec)
        else:
            data_cfg = DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=spec.data.seq_len,
                                  global_batch=spec.data.global_batch,
                                  seed=spec.data.seed)
        data_cfg = self._ov.get("data_config") or data_cfg
        damping = spec.optimizer.staleness_damping
        self.trainer = Trainer(
            cfg, data_cfg, sync=spec.sync.mode,
            s_lower=spec.sync.s_lower, s_upper=spec.sync.s_upper,
            lr=spec.optimizer.lr, optimizer=spec.optimizer.name,
            compressor=spec.wire.compression,
            checkpoint_dir=self._ov.get("checkpoint_dir"),
            save_every=self._ov.get("save_every", 50),
            collective_time_fn=self._ov.get("collective_time_fn"),
            rules=self._ov.get("rules"),
            staleness_damping=True if damping is None else damping)
        if self._ov.get("resume"):
            self.resumed = self.trainer.resume()

    def _run(self, steps: int) -> None:
        self.trainer.train(steps, verbose=self.verbose)
        if self.obs_rig is not None:
            self.obs_rig.finish()

    def metrics(self) -> Dict[str, Any]:
        log = self.trainer.log if self.trainer else None
        losses = log.losses if log else []
        out = {
            "engine": self.engine,
            "steps": len(losses),
            "first_loss": losses[0] if losses else None,
            "final_loss": losses[-1] if losses else None,
            "mean_delay": (sum(log.delays) / len(log.delays)
                           if log and log.delays else 0.0),
        }
        if self.obs_rig is not None and self.obs_rig.summary is not None:
            out["obs"] = self.obs_rig.summary
        return out

    def _close(self) -> None:
        if self.obs_rig is not None:
            self.obs_rig.finish()


# ===================================================================
# engine: threaded parameter server
# ===================================================================
@register_engine("ps-threads")
class ThreadedPSSession(TrainingSession):
    """Worker threads pushing into an in-heap parameter server — the
    Algorithm-1 execution model with GIL-released jitted compute."""

    OVERRIDES = frozenset({
        "verbose", "params", "step_fn", "batches", "loss_from_aux",
        "speed_factors", "external_workers", "timeout",
    })

    server = None
    obs_rig = None
    ft_rig = None
    serve_results = None

    def _start(self) -> None:
        self.server = build_server(self.spec, self._ov.get("params"))
        if self.spec.ft.snapshots:
            self.ft_rig = _FtRig(self.spec.ft, self.server)
        if self.spec.obs.trace:
            self.obs_rig = _ObsRig(self.spec.obs)
            self.obs_rig.start(_obs_snapshot_fn(self.server))
        if self.verbose and self.spec.ps.kind == "sharded":
            print(self.server.plan.describe())

    def _run(self, steps: int) -> None:
        if self._ov.get("external_workers"):
            raise SpecError("this session was built with "
                            "external_workers=True — drive the server "
                            "yourself (run() has no workers to start)")
        from repro.ps.worker import PSWorker, run_cluster
        spec = self.spec
        w = spec.ps.workers
        iters = max(1, steps // w)
        speeds = _speed_factors(spec, self._ov.get("speed_factors"))
        make_step = self._step_factory()
        batches = self._batches_factory()
        loss_from_aux = self._ov.get("loss_from_aux",
                                     _default_loss_from_aux)
        workers = [
            PSWorker(i, self.server, make_step(), batches(i), iters,
                     speed_factor=speeds[i],
                     wire_format=spec.wire.format,
                     delta_pull=spec.wire.delta_pull,
                     loss_from_aux=loss_from_aux)
            for i in range(w)]
        serve_threads, serve_results = _serve_threads(self)
        run_cluster(self.server, workers,
                    timeout=self._ov.get("timeout", 1200.0))
        for t in serve_threads:
            t.join(timeout=self._ov.get("timeout", 1200.0))
        if serve_threads:
            self.serve_results = serve_results
            failed = [r for r in serve_results if r is not None and r.error]
            if failed:
                raise RuntimeError(
                    f"{len(failed)} serve replica(s) failed:\n"
                    + "\n".join(r.error for r in failed))
        if self.obs_rig is not None:
            self.obs_rig.finish()
        if self.verbose:
            m = self.server.metrics
            print(f"pushes={m.total_pushes} applied_updates="
                  f"{self.server.version} wait_s={m.total_wait:.2f} "
                  f"max_stale={m.max_staleness}")

    # -- worker construction ------------------------------------------
    def _step_factory(self):
        """() -> step_fn per worker.  The packed path gives each worker
        its own donated gradient wire buffer around one shared jit."""
        step_fn = self._ov.get("step_fn")
        if step_fn is not None:
            return lambda: step_fn
        import functools

        import jax
        import jax.numpy as jnp

        from repro.models import registry
        cfg, _ = _model_setup(self.spec)
        loss_fn = registry.loss_fn(cfg)
        if self.spec.wire.format == "tree":
            @jax.jit
            def _tree_step(p, batch):
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(p, batch)
                return grads, {"loss": loss}

            return lambda: _tree_step

        plan = self.server.plan

        @functools.partial(jax.jit, donate_argnums=(1,))
        def _packed_step(wire_p, wire_g_prev, batch):
            p = plan.unpack(wire_p)
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(p, batch)
            # Write the packed grads INTO the donated buffer: the
            # output aliases wire_g_prev's memory.  A plain `return
            # plan.pack(...)` would leave wire_g_prev unread, and jit's
            # keep_unused=False prunes unread args before donation.
            return wire_g_prev.at[:].set(plan.pack(grads)), {"loss": loss}

        def make_step():
            # One gradient wire buffer per worker, donated back into
            # the jit every iteration; the params buffer is the
            # server's shared snapshot and must NOT be donated.
            from repro.wireformat import WIRE_LANES
            layout = plan.wire_layout()
            state = {"g": jnp.zeros((layout.total_rows, WIRE_LANES),
                                    layout.dtype)}

            def step(wire_p, batch):
                g, aux = _packed_step(wire_p, state["g"], batch)
                state["g"] = g
                return g, aux

            return step

        return make_step

    def _batches_factory(self):
        batches = self._ov.get("batches")
        if batches is not None:
            return batches
        import jax.numpy as jnp

        from repro.data.synthetic import batches as data_batches
        cfg, data_cfg = _model_setup(self.spec)

        def worker_batches(w: int) -> Iterator:
            wcfg = dataclasses.replace(data_cfg,
                                       seed=data_cfg.seed + 1 + w)
            for b in data_batches(cfg, wcfg):
                yield {k: jnp.asarray(v) for k, v in b.items()}

        return worker_batches

    # -- reporting ----------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        out = _ps_metrics(self.engine, self.server, self.obs_rig)
        if self.ft_rig is not None:
            out["ft"] = self.ft_rig.metrics()
        if self.serve_results is not None:
            from repro.serve import aggregate_serve
            out["serve"] = aggregate_serve(self.serve_results)
        return out

    def _close(self) -> None:
        if self.ft_rig is not None:
            self.ft_rig.finish()
        if self.server is not None:
            self.server.shutdown()
        if self.obs_rig is not None:
            self.obs_rig.finish()


# ===================================================================
# engine: process-isolated transport workers
# ===================================================================
@register_engine("ps-transport")
class TransportPSSession(TrainingSession):
    """Spawned worker processes pushing packed frames over a real wire
    (tcp / shmem / in-process loopback) into a ``PSServerEndpoint``."""

    OVERRIDES = frozenset({
        "verbose", "params", "external_workers", "speed_factors",
        "timeout",
    })

    server = None
    endpoint = None
    transport = None
    results = None
    obs_rig = None
    ft_rig = None
    serve_results = None

    def _start(self) -> None:
        from repro.transport import PSServerEndpoint, make_transport
        spec = self.spec
        self.server = build_server(spec, self._ov.get("params"))
        if spec.ft.snapshots:
            self.ft_rig = _FtRig(spec.ft, self.server)
        if spec.obs.trace:
            self.obs_rig = _ObsRig(spec.obs)
        self.endpoint = PSServerEndpoint(
            self.server,
            collector=self.obs_rig.collector if self.obs_rig else None)
        if self.obs_rig is not None:
            self.obs_rig.start(_obs_snapshot_fn(self.server))
        # Serving replicas take transport slots AFTER the trainers'
        # (shmem pre-allocates one segment per id; tcp ignores the
        # count) — workers 0..W-1, replicas W..W+R-1.
        self.transport = make_transport(
            spec.transport.kind,
            n_workers=spec.ps.workers + spec.serve.replicas,
            host=spec.transport.host, port=spec.transport.port)
        self.transport.serve(self.endpoint)

    def address(self):
        """The picklable transport address clients ``connect`` to."""
        self.start()
        return self.transport.address()

    def reshard(self, n_shards: int) -> bool:
        """Manual live-reshard trigger: migrate the running server's
        packed store to ``n_shards`` partitions WITHOUT stopping
        training (``repro.ft.reshard``).  Workers and replicas resync
        through the version-delta full-pull fallback on their next
        pull.  Returns False when the server is already at that arity."""
        self.start()
        if not hasattr(self.server, "reshard"):
            raise SpecError(
                "live resharding migrates the sharded server's packed "
                "stores — this spec builds "
                f"ps.kind={self.spec.ps.kind!r}; set ps.kind='sharded' "
                "with ps.apply='fused'")
        return bool(self.server.reshard(int(n_shards)))

    def _run(self, steps: int) -> None:
        if self._ov.get("external_workers"):
            raise SpecError("this session was built with "
                            "external_workers=True — connect your own "
                            "clients to session.address()")
        if self.spec.transport.kind == "inproc":
            raise SpecError(
                "transport.endpoint=True over inproc is the in-process "
                "serialization baseline for external clients — spawned "
                "workers cannot reach an in-process address; use "
                "external_workers=True or transport.kind='tcp'/'shmem'")
        if self.spec.model.arch == CUSTOM_ARCH:
            raise SpecError(
                "transport workers rebuild the model from its config "
                "name — model.arch='custom' cannot cross the spawn "
                "boundary (pass a registry arch, or drive the endpoint "
                "with external_workers=True)")
        from repro.launch.proc_pool import (ProcessWorkerPool, WorkerTask,
                                            raise_on_failure)
        spec = self.spec
        w = spec.ps.workers
        iters = max(1, steps // w)
        task = WorkerTask.from_spec(
            spec, iters,
            trace_spill=(self.obs_rig.make_spill_dir()
                         if self.obs_rig else ""))
        slowdowns = _speed_factors(spec, self._ov.get("speed_factors"))
        pool = ProcessWorkerPool(self.transport.address(), task, w,
                                 slowdowns=slowdowns)
        rpool = None
        if spec.serve.replicas > 0:
            from repro.serve import ReplicaPool, ReplicaTask
            rtask = ReplicaTask.from_spec(
                spec, trace_spill=(self.obs_rig.make_spill_dir()
                                   if self.obs_rig else ""))
            rpool = ReplicaPool(self.transport.address(), rtask,
                                spec.serve.replicas, first_id=w)
        pool.start()
        if rpool is not None:
            rpool.start()
        trigger_stop = None
        if spec.ft.reshards:
            import threading
            trigger_stop = threading.Event()
            threading.Thread(
                target=_reshard_watch,
                args=(self.server, spec.ft, trigger_stop),
                name="reshard-trigger", daemon=True).start()
        try:
            self.results = pool.join(
                timeout=self._ov.get("timeout", 1200.0),
                endpoint=self.endpoint)
            if rpool is not None:
                # Replicas drain their own request load; join them
                # while the wire is still up (their last refreshes and
                # TRACE flushes ride it).
                self.serve_results = rpool.join(
                    timeout=self._ov.get("timeout", 1200.0),
                    endpoint=self.endpoint)
        finally:
            # Training is over either way: release gated workers and
            # tear the wire down before surfacing failures.
            if trigger_stop is not None:
                trigger_stop.set()
            self.close()
            pool.terminate()
            if rpool is not None:
                rpool.terminate()
        raise_on_failure(self.results)
        if rpool is not None:
            from repro.serve import raise_on_replica_failure
            raise_on_replica_failure(self.serve_results)
        if self.verbose:
            m = self.server.metrics
            done = sum(r.iterations_done for r in self.results)
            print(f"workers={w} ({spec.transport.kind}) "
                  f"iterations={done} pushes={m.total_pushes} "
                  f"applied_updates={self.server.version} "
                  f"max_stale={m.max_staleness}")

    def metrics(self) -> Dict[str, Any]:
        out = _ps_metrics(self.engine, self.server, self.obs_rig)
        if self.results is not None:
            out["iterations_done"] = sum(r.iterations_done
                                         for r in self.results)
        if self.ft_rig is not None:
            out["ft"] = self.ft_rig.metrics()
        if self.serve_results is not None:
            from repro.serve import aggregate_serve
            out["serve"] = aggregate_serve(self.serve_results)
        return out

    def _close(self) -> None:
        if self.ft_rig is not None:
            self.ft_rig.finish()
        if self.server is not None:
            self.server.shutdown()
        if self.transport is not None:
            self.transport.shutdown()
        # After the transport is down: every in-flight TRACE frame has
        # either been dispatched into the collector or lost to the
        # spill files the rig is about to recover.
        if self.obs_rig is not None:
            self.obs_rig.finish()


def _reshard_watch(server, ft, stop) -> None:
    """Background live-reshard trigger for the in-parent transport
    session: fire at the manual push round (``ft.reshard_round``)
    and/or when one shard's applied-update growth exceeds
    ``ft.reshard_hot_factor`` x the uniform share (the hot-shard
    policy).  One-shot — the thread exits after triggering.  The
    ``repro.ft.server_proc`` process runs its own copy of this logic
    (plus the mid-migration kill hook) for out-of-process servers."""
    import time as _time
    last = server.shard_versions()
    while not stop.is_set() and not server.stopped:
        _time.sleep(0.02)
        if ft.reshard_round >= 0 \
                and server.metrics.total_pushes >= ft.reshard_round:
            server.reshard(ft.reshard_shards)
            return
        if ft.reshard_hot_factor > 0.0:
            cur = server.shard_versions()
            if len(cur) == len(last):
                deltas = [c - b for c, b in zip(cur, last)]
                total = sum(deltas)
                if total > 0 and max(deltas) > \
                        ft.reshard_hot_factor * (total / len(deltas)):
                    server.reshard(ft.reshard_shards)
                    return
            last = cur


def _ps_metrics(engine: str, server, obs_rig=None) -> Dict[str, Any]:
    if server is None:
        return {"engine": engine}
    from repro.perfcount import snapshot_all
    m = server.metrics
    losses = [loss for _, _, loss in m.loss_trajectory]
    out = {
        "engine": engine,
        "pushes": m.total_pushes,
        "applied_updates": server.version,
        "max_staleness": m.max_staleness,
        "total_wait": m.total_wait,
        "wait_fraction": m.wait_fraction(),
        "credit_releases": m.credit_releases,
        "first_loss": losses[0] if losses else None,
        "final_loss": losses[-1] if losses else None,
        "perfcount": snapshot_all(),
    }
    if obs_rig is not None and obs_rig.summary is not None:
        out["obs"] = obs_rig.summary
    return out
