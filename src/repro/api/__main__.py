"""CLI surface of the spec layer.

    python -m repro.api --dump-schema          # the API-surface lock
    python -m repro.api --validate run.json    # lint a spec file
    python -m repro.api --example              # a ready-to-edit spec

CI runs ``--dump-schema`` and diffs the output against the checked-in
``src/repro/api/schema.json``: any change to the public RunSpec surface
fails the build until the schema file is updated (i.e. reviewed) in the
same PR.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.api.spec import RunSpec, SpecError, dump_schema


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.api",
                                 description=__doc__)
    group = ap.add_mutually_exclusive_group(required=True)
    group.add_argument("--dump-schema", action="store_true",
                       help="print the RunSpec schema as canonical JSON")
    group.add_argument("--validate", metavar="SPEC.json",
                       help="parse + validate a spec file; exit 1 with "
                            "the SpecError message if invalid")
    group.add_argument("--example", action="store_true",
                       help="print a default RunSpec as editable JSON")
    args = ap.parse_args(argv)

    if args.dump_schema:
        print(json.dumps(dump_schema(), indent=2, sort_keys=True))
        return 0
    if args.example:
        print(RunSpec().to_json())
        return 0
    try:
        with open(args.validate) as f:
            spec = RunSpec.from_json(f.read())
    except OSError as e:
        print(f"cannot read {args.validate}: {e}", file=sys.stderr)
        return 1
    except SpecError as e:
        print(f"invalid spec: {e}", file=sys.stderr)
        return 1
    print(f"ok: {args.validate} is a valid RunSpec "
          f"(engine={spec.engine})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
