"""``ParameterServerProtocol`` — the one server surface every engine,
endpoint and worker codes against.

``ParameterServer`` (monolithic) and ``ShardedParameterServer`` both
inherit this base, so the transport endpoint, the PS workers and the
process pool never branch on the server's concrete type: every server
answers the full push/pull surface —

    pull / push                tree wire format (per-leaf pytrees)
    pull_packed / push_packed  packed (rows, 512) wire format
    pull_delta                 version-delta pull (changed shards only)
    pull_packed_shard /        per-shard packed regions (the unit the
    push_packed_shard          transport endpoints route on)
    snapshot / shutdown        lifecycle
    add_worker / remove_worker elastic membership
    record_loss / metrics      accounting

The per-shard variants have a default single-shard implementation
(shard 0 == the whole store), so the monolithic server is routable
behind a per-shard endpoint without any adapter.  ``packed_wire``
reports whether the packed surface is live for this instance (it
depends on the constructor's apply mode, not the class).

Import-light on purpose: this module must be importable before jax and
without triggering the rest of ``repro.api`` machinery.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

Params = Any
Grads = Any


@dataclasses.dataclass(frozen=True)
class DeltaPull:
    """Result of a version-delta pull: only the shards that advanced.

    ``versions`` is the server's per-shard version vector at snapshot
    time (the client stores it and sends it back on its next
    ``pull_delta``); ``shards``/``regions`` are the parallel lists of
    advanced shard ids and their packed ``(rows, 512)`` regions
    (jax arrays server-side, numpy host buffers on a transport
    client).  ``full`` marks a full-snapshot fallback — the client's
    version vector did not match the server's shard arity (or ran
    ahead of it), so every non-empty shard's region is included and
    the client should treat the patch as a complete rebuild.
    ``epoch`` is the server's live-reshard epoch at snapshot time: a
    change from the client's last-seen epoch means the shard arity
    (and wire layout) moved under it — the reply is already a full
    snapshot in the NEW layout, and the client must rebuild its
    plan/buffers before patching.
    """

    versions: Tuple[int, ...]
    shards: Tuple[int, ...] = ()
    regions: Tuple[Any, ...] = ()
    full: bool = False
    epoch: int = 0

    @property
    def empty(self) -> bool:
        return not self.shards


class ParameterServerProtocol:
    """Base class + default impls for the unified server surface.

    Subclasses must provide ``pull``, ``push``, ``stop``,
    ``record_loss``, ``add_worker``, ``remove_worker`` and a
    ``version`` counter; packed-mode subclasses additionally provide
    ``pull_packed``/``push_packed`` (the per-shard defaults below then
    come for free on single-shard servers).
    """

    #: concrete servers set this in __init__ ("tree"/"packed"/"fused")
    apply_mode: str = "tree"
    stopped: bool = False
    version: int = 0

    # ---------------------------------------------------- capabilities
    @property
    def packed_wire(self) -> bool:
        """Does this instance hold a resident packed store (i.e. are
        ``*_packed`` calls valid)?  The transport layer speaks packed
        frames only and checks this instead of the concrete type."""
        return self.apply_mode in ("packed", "fused")

    #: plain attribute (not a property) so sharded subclasses can
    #: assign their arity in __init__
    n_shards: int = 1

    def shard_versions(self) -> List[int]:
        return [self.version]

    # ------------------------------------------------------- tree wire
    def pull(self, worker: int) -> Params:
        raise NotImplementedError

    def push(self, worker: int, grads: Grads) -> None:
        raise NotImplementedError

    # ----------------------------------------------------- packed wire
    def pull_packed(self, worker: int = -1):
        raise NotImplementedError(
            f"{type(self).__name__}(apply_mode={self.apply_mode!r}) has "
            "no resident packed store")

    def push_packed(self, worker: int, wire) -> None:
        raise NotImplementedError(
            f"{type(self).__name__}(apply_mode={self.apply_mode!r}) has "
            "no resident packed store")

    def pull_delta(self, worker: int,
                   versions: Optional[Sequence[int]]) -> DeltaPull:
        """Version-delta pull: the shards that advanced past the
        client's ``versions`` vector, or a full-snapshot fallback on a
        vector mismatch.  Packed-mode servers override this; the base
        raises like the other packed calls."""
        raise NotImplementedError(
            f"{type(self).__name__}(apply_mode={self.apply_mode!r}) has "
            "no resident packed store")

    # ------------------------------------- per-shard (default: 1 shard)
    def pull_packed_shard(self, shard: int, worker: int = -1):
        self._only_shard(shard)
        return self.pull_packed(worker)

    def push_packed_shard(self, worker: int, shard: int, buf) -> None:
        self._only_shard(shard)
        self.push_packed(worker, buf)

    def _only_shard(self, shard: int) -> None:
        if shard != 0:
            raise ValueError(
                f"{type(self).__name__} is single-shard: shard must be "
                f"0, got {shard}")

    # ------------------------------------------------------- lifecycle
    def stop(self) -> None:
        raise NotImplementedError

    def shutdown(self) -> None:
        """Release every gated worker and refuse new work.  Alias of
        ``stop`` today; sessions call this so servers can grow teardown
        steps without touching call sites."""
        self.stop()

    def snapshot(self) -> Params:
        """A consistent pytree snapshot of the global weights."""
        return self.pull(-1)

    @property
    def params(self) -> Params:
        return self.snapshot()

    # ------------------------------------------------------ membership
    def add_worker(self, worker: int) -> None:
        raise NotImplementedError

    def remove_worker(self, worker: int) -> None:
        raise NotImplementedError

    # ------------------------------------------------------ accounting
    def record_loss(self, step: int, loss: float) -> None:
        raise NotImplementedError

    def staleness_profile(self) -> Dict:
        raise NotImplementedError
