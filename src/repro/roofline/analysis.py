"""Roofline-term extraction from compiled XLA artifacts.

Per (arch × shape × mesh) cell the dry-run produces a lowered+compiled
module; from it we derive the three roofline terms on TPU v5e:

  compute    = HLO_FLOPs           / (peak_FLOP/s per chip)
  memory     = HLO_bytes_accessed  / (HBM bytes/s per chip)
  collective = Σ collective bytes  / (ICI bytes/s per chip)

``cost_analysis`` on an SPMD-partitioned module reports *per-device*
flops/bytes, so no per-chip division is needed; collective bytes are NOT
in cost_analysis — we parse the post-SPMD HLO text and sum the result
shapes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute ops (each counted once per executed instruction,
with while-loop trip counts applied when derivable from scan bounds).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

# TPU v5e hardware constants (assignment brief)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (serialized-link model)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,256,512]{2,1,0}   or   f32[]   (scalars)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of one HLO shape string (tuples handled by caller)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]       # per-chip wire bytes (ring model)
    count_by_kind: Dict[str, int]
    result_bytes_by_kind: Dict[str, int]  # raw result-shape bytes

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    # legacy {{0,1,...},{...}} format: size of the first group
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return m.group(1).count(",") + 1
    return 2


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Per-chip wire bytes of every collective instruction (ring model).

    Result-shape bytes are a poor cost proxy because XLA freely rewrites
    all-reduce <-> reduce-scatter + all-gather (same wire traffic, 2x the
    result bytes).  Ring-algorithm wire bytes per chip, result size S,
    group size n:
        all-reduce          2.S.(n-1)/n      (reduce + broadcast phases)
        all-gather          S.(n-1)/n        (S = full gathered result)
        reduce-scatter      S.(n-1)          (S = the scattered shard)
        all-to-all          S.(n-1)/n
        collective-permute  S
    Trip counts of scan loops are handled by the caller via the
    two-point depth fit (cost_configs), not here."""
    bytes_by: Dict[str, float] = {k: 0 for k in _COLLECTIVES}
    result_by: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count_by: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result shape appears before ' = ... <op>('
        for kind in _COLLECTIVES:
            if f" {kind}(" in s or f" {kind}-start(" in s:
                lhs = s.split(" = ", 1)
                if len(lhs) != 2:
                    continue
                shape_part = lhs[1].split(kind, 1)[0]
                size = shape_bytes(shape_part)
                # XLA:CPU promotes bf16 reductions to f32 ("..._promoted"
                # reducers); TPU runs them native bf16 -- count half.
                if kind == "all-reduce" and "promoted" in s \
                        and "f32[" in shape_part:
                    size //= 2
                n = _group_size(s)
                if kind == "all-reduce":
                    wire = 2.0 * size * (n - 1) / n
                elif kind == "reduce-scatter":
                    wire = float(size) * (n - 1)
                elif kind == "collective-permute":
                    wire = float(size)
                else:  # all-gather / all-to-all
                    wire = float(size) * (n - 1) / n
                bytes_by[kind] += wire
                result_by[kind] += size
                count_by[kind] += 1
                break
    return CollectiveStats({k: int(v) for k, v in bytes_by.items()},
                           count_by, result_by)


def while_trip_counts(hlo_text: str) -> List[int]:
    """Best-effort trip counts of while loops (scan emits constant trip
    counts as a comparison against an iteration bound constant)."""
    # xla renders known trip counts in backend_config or in the condition
    # root: constant(<n>); this is heuristic and only used for reporting.
    counts = []
    for m in re.finditer(r"trip_count[\"']?[:=]\s*(\d+)", hlo_text):
        counts.append(int(m.group(1)))
    return counts


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops: float                 # per-chip, from cost_analysis
    hbm_bytes: float             # per-chip, from cost_analysis
    collective_bytes: float      # per-chip HLO static sum (see note)
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float           # 6·N·D (train) / 2·N·D (decode), global
    per_device_argument_bytes: float
    peak_memory_bytes: float
    collective_counts: Dict[str, int]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bounded_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): >1 means HLO under-counts
        (e.g. fused ops), <1 means remat/dispatch overhead."""
        if self.flops <= 0:
            return 0.0
        n_chips = {"16x16": 256, "2x16x16": 512}.get(self.mesh, 256)
        return self.model_flops / (self.flops * n_chips)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step runs in
        bounded_time: useful model flops / (chips · peak · bounded_time)."""
        n_chips = {"16x16": 256, "2x16x16": 512}.get(self.mesh, 256)
        denom = n_chips * PEAK_FLOPS * self.bounded_time
        return self.model_flops / denom if denom > 0 else 0.0


def model_flops(cfg, shape) -> float:
    """6·N_active·D for training, 2·N_active·D per generated token for
    decode, 2·N·D for prefill (forward only)."""
    from repro.models.registry import count_params
    n_active = count_params(cfg, active_only=True)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * shape.global_batch


def extract(compiled, lowered_text: Optional[str], cfg, shape,
            mesh_label: str) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))

    text = lowered_text if lowered_text is not None else compiled.as_text()
    colls = parse_collectives(text)

    mem = compiled.memory_analysis()
    arg_bytes = float(getattr(mem, "argument_size_in_bytes", 0) or 0)
    temp = float(getattr(mem, "temp_size_in_bytes", 0) or 0)
    out_b = float(getattr(mem, "output_size_in_bytes", 0) or 0)

    return RooflineTerms(
        arch=cfg.name, shape=shape.name, mesh=mesh_label,
        flops=flops, hbm_bytes=hbm,
        collective_bytes=float(colls.total_bytes),
        t_compute=flops / PEAK_FLOPS,
        t_memory=hbm / HBM_BW,
        t_collective=colls.total_bytes / ICI_BW,
        model_flops=model_flops(cfg, shape),
        per_device_argument_bytes=arg_bytes,
        peak_memory_bytes=arg_bytes + temp + out_b,
        collective_counts={k: v for k, v in colls.count_by_kind.items()
                           if v},
    )


def format_row(t: RooflineTerms) -> str:
    return (f"{t.arch:>22} {t.shape:>12} {t.mesh:>8} "
            f"{t.flops:>12.3e} {t.hbm_bytes:>12.3e} "
            f"{t.collective_bytes:>12.3e} "
            f"{t.t_compute * 1e3:>10.2f} {t.t_memory * 1e3:>10.2f} "
            f"{t.t_collective * 1e3:>10.2f} {t.dominant:>10} "
            f"{t.useful_flops_ratio:>8.3f} {t.roofline_fraction:>8.3f} "
            f"{t.per_device_argument_bytes / 2**30:>8.2f}")


HEADER = (f"{'arch':>22} {'shape':>12} {'mesh':>8} "
          f"{'flops/chip':>12} {'bytes/chip':>12} {'coll_B/chip':>12} "
          f"{'t_comp_ms':>10} {'t_mem_ms':>10} {'t_coll_ms':>10} "
          f"{'dominant':>10} {'useful':>8} {'roofline':>8} {'argGiB':>8}")


# ------------------------------------------------- two-point depth fit
def cost_configs(cfg):
    """Depth-reduced, inner-scan-free config pair for exact cost fitting.

    XLA's HloCostAnalysis counts while-loop bodies ONCE (trip counts are
    annotated but not applied), so a scan-over-layers module under-reports
    flops/bytes by ~n_layers×.  Fix: compile the same cell at two depths
    (d1, d2) with every *inner* scan disabled (attention/MoE/Mamba
    chunking off — identical math, no nested loops), then extrapolate
    affinely: cost(L) = c(d1) + (c(d2) − c(d1)) · (L − d1) / (d2 − d1).
    The remaining outer scan-over-layers has its body counted once per
    compile, which the affine fit absorbs exactly because layers are
    homogeneous (per-family period groups for jamba).

    Returns (cfg_d1, cfg_d2, d1, d2, L_units) or None when the family has
    no outer scan (xLSTM is unrolled: its reported costs are already
    correct, modulo the sLSTM time-scan noted in slstm_correction()).
    """
    kill_inner = dict(attn_chunk=0, moe_chunk=0, mamba_chunk=0,
                      scan_unroll=True, grad_accum=1)
    if cfg.family == "ssm":
        return None
    if cfg.family == "hybrid":
        p = cfg.attn_period or 1
        return (cfg.scaled(n_layers=p, **kill_inner),
                cfg.scaled(n_layers=2 * p, **kill_inner),
                1, 2, cfg.n_layers // p)
    if cfg.family == "audio":
        return (cfg.scaled(n_layers=1, n_encoder_layers=1, **kill_inner),
                cfg.scaled(n_layers=2, n_encoder_layers=2, **kill_inner),
                1, 2, cfg.n_layers)
    return (cfg.scaled(n_layers=1, **kill_inner),
            cfg.scaled(n_layers=2, **kill_inner),
            1, 2, cfg.n_layers)


def affine_fit(c1: float, c2: float, d1: int, d2: int, L: int) -> float:
    return c1 + (c2 - c1) * (L - d1) / float(d2 - d1)


def slstm_correction_flops(cfg, shape) -> float:
    """sLSTM's time recurrence is a lax.scan over seq_len whose body the
    HLO cost analysis counts once; add the missing (L_time − 1) bodies
    analytically (recurrent per-head matmul dominates):
        flops/step = 2 · b · H · hd · 4hd = 8 · b · d · hd
    """
    if cfg.family != "ssm" or not cfg.slstm_layers:
        return 0.0
    b = shape.global_batch
    l = shape.seq_len if shape.kind != "decode" else 1
    d = cfg.d_model
    hd = d // cfg.n_heads
    per_step = 8.0 * b * d * hd
    return len(cfg.slstm_layers) * max(0, l - 1) * per_step


def raw_costs(compiled, hlo_text: Optional[str] = None) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = parse_collectives(text)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(colls.total_bytes)}
