"""Roofline analysis from compiled dry-run artifacts."""

from repro.roofline.analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    CollectiveStats,
    RooflineTerms,
    extract,
    format_row,
    HEADER,
    model_flops,
    parse_collectives,
    shape_bytes,
)

__all__ = ["extract", "RooflineTerms", "CollectiveStats",
           "parse_collectives", "shape_bytes", "model_flops",
           "format_row", "HEADER", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
