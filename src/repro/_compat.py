"""Legacy-construction bookkeeping for the ``repro.api`` migration.

The declarative session layer (``repro.api.build_session``) is the
supported way to wire servers, workers and transports together.  The
old direct constructors keep working, but emit a single
``DeprecationWarning`` per class naming the replacement — unless the
construction happens *inside* the api builder itself, which is the one
place that is allowed to call them without ceremony.

This module is import-light on purpose (stdlib only): it is imported at
module scope by ``repro.ps`` and must never create an import cycle with
``repro.api``.
"""

from __future__ import annotations

import contextlib
import threading
import warnings

_local = threading.local()
_warned: set = set()


@contextlib.contextmanager
def api_managed():
    """Mark the current thread as 'inside the repro.api builder':
    legacy-constructor warnings are suppressed within the block."""
    depth = getattr(_local, "depth", 0)
    _local.depth = depth + 1
    try:
        yield
    finally:
        _local.depth = depth


def in_api_build() -> bool:
    return getattr(_local, "depth", 0) > 0


def warn_legacy(name: str, replacement: str) -> None:
    """Emit one DeprecationWarning per process for ``name``.

    No-op while the api builder is constructing on this thread: the
    builder IS the replacement and must stay warning-free.
    """
    if in_api_build() or name in _warned:
        return
    _warned.add(name)
    warnings.warn(
        f"constructing {name} directly is deprecated; build the run "
        f"declaratively via {replacement} (see src/repro/api/README.md "
        "for the migration table)",
        DeprecationWarning, stacklevel=3)


def reset_legacy_warnings() -> None:
    """Forget which classes already warned (test hook)."""
    _warned.clear()
