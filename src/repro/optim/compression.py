"""Gradient compression for the cross-pod (DCN) hop.

Two schemes, both with error feedback so the compression bias does not
accumulate (Seide et al. / Karimireddy et al.):

  * ``int8``  — per-tensor symmetric linear quantization (4x smaller than
    f32, 2x smaller than bf16 on the wire).
  * ``topk``  — magnitude top-k sparsification (k as a fraction), dense
    mask representation (JAX-native; a real DCN transport would send
    indices+values — the *information* reduction is what matters for the
    convergence experiments, and the byte reduction is reported by the
    roofline module for the collective term).

The DSSP cross-pod mode composes with either: compress the pod-averaged
gradient before the cross-pod all-reduce.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Tree = Any


@dataclasses.dataclass(frozen=True)
class Compressor:
    name: str
    # (grads, error_state) -> (compressed-but-decoded grads, new_error)
    apply: Callable[[Tree, Tree], Tuple[Tree, Tree]]
    init_error: Callable[[Tree], Tree]
    wire_bytes_per_value: float      # for the roofline collective term


def _zeros_like_f32(tree: Tree) -> Tree:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _transpose_apply(one: Callable) -> Callable:
    """Lift a per-leaf ``(g, e) -> (g', e')`` into a tree apply.

    ``tree_map(one, ...)`` yields a grads-shaped tree of (g', e') pairs;
    ``tree_transpose`` flips it into the ((g' tree), (e' tree)) pair the
    Compressor contract wants — structurally, instead of the fragile
    double tree_map with an ``is_leaf`` tuple sniff.
    """
    inner = jax.tree_util.tree_structure((0, 0))

    def apply(grads, err):
        outer = jax.tree_util.tree_structure(grads)
        outs = jax.tree_util.tree_map(one, grads, err)
        return jax.tree_util.tree_transpose(outer, inner, outs)

    return apply


def int8_compressor() -> Compressor:
    def one(g: jax.Array, e: jax.Array) -> Tuple[jax.Array, jax.Array]:
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    return Compressor("int8", _transpose_apply(one), _zeros_like_f32, 1.0)


def topk_compressor(fraction: float = 0.05) -> Compressor:
    if not 0.0 < fraction <= 1.0:
        raise ValueError("fraction in (0, 1]")

    def one(g: jax.Array, e: jax.Array) -> Tuple[jax.Array, jax.Array]:
        gf = g.astype(jnp.float32) + e
        flat = gf.reshape(-1)
        k = max(1, int(fraction * flat.size))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(gf) >= thresh).astype(jnp.float32)
        kept = gf * mask
        return kept.astype(g.dtype), gf - kept

    # indices (4B) + values (2B) per kept value, k fraction of tensor
    return Compressor(f"topk({fraction})", _transpose_apply(one),
                      _zeros_like_f32, 6.0 * fraction)


@dataclasses.dataclass(frozen=True)
class PackedCompressor:
    """Wire compression over the packed (rows, 512) buffer.

    The tree ``Compressor`` above runs a per-leaf ``tree_map`` — one
    XLA dispatch chain per pytree leaf.  On the packed wire format the
    whole shard is one lane-aligned buffer, so quantize + dequant +
    error feedback fuse into a single Pallas VMEM pass per shard
    (``repro.kernels.fused_compress``).  ``apply`` maps
    ``(wire_grads, wire_err) -> (decoded_grads, new_err)`` with the
    same error-feedback contract as the tree path.
    """

    name: str
    apply: Callable[[Any, Any], Tuple[Any, Any]]
    wire_bytes_per_value: float


def make_packed_compressor(name: str, *,
                           fraction: float = 0.05
                           ) -> "PackedCompressor | None":
    """Fused wire compressor for the packed push path (None = identity).

    Imports the kernel stack lazily so ``import repro.optim`` (and the
    ps layer that re-exports this) stays Pallas-free.
    """
    if name in ("none", "", None):
        return None
    from repro.kernels import ops as kops
    if name == "int8":
        return PackedCompressor("int8", kops.fused_int8_ef, 1.0)
    if name == "topk":
        if not 0.0 < fraction <= 1.0:
            raise ValueError("fraction in (0, 1]")
        return PackedCompressor(
            f"topk({fraction})",
            lambda g, e: kops.fused_topk_ef(g, e, fraction=fraction),
            6.0 * fraction)
    raise ValueError(f"unknown wire compressor {name!r}")


def make_compressor(name: str, **kw) -> Compressor:
    if name in ("none", "", None):
        # Identity — but with a *real* grads-shaped error state so code
        # that round-trips (grads, err) through any compressor works
        # unchanged when compression is switched off.
        ident = Compressor(
            "none",
            lambda g, e: (g, e),
            _zeros_like_f32,
            2.0)
        return ident
    if name == "int8":
        return int8_compressor()
    if name == "topk":
        return topk_compressor(**kw)
    raise ValueError(f"unknown compressor {name!r}")
