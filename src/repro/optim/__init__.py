"""Optimizers, schedules and gradient compression."""

from repro.optim.compression import (
    Compressor,
    int8_compressor,
    make_compressor,
    topk_compressor,
)
from repro.optim.optimizers import (
    AdafactorState,
    AdamState,
    Optimizer,
    adafactor,
    adamw,
    make_optimizer,
    momentum,
    sgd,
)

__all__ = [
    "Optimizer", "sgd", "momentum", "adamw", "adafactor", "make_optimizer",
    "AdamState", "AdafactorState",
    "Compressor", "make_compressor", "int8_compressor", "topk_compressor",
]
