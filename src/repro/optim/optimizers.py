"""Optimizers (pure-pytree, optax-like minimal API) + staleness awareness.

``init(params) -> state``; ``update(grads, state, params, *, staleness=0)
-> (new_params, new_state)``.  All states are pytrees that shard exactly
like their parameters (the dry-run passes them as inputs).

* ``sgd`` / ``momentum``  — the paper's server update rule.
* ``adamw``               — standard training baseline.
* ``adafactor``           — factored second moment; chosen for the >100B
  assigned configs where Adam state would not fit 16 GB/chip (DESIGN.md).
* Every rule accepts ``staleness`` and optionally damps the step by
  1/(1+s) — the Omnivore-style mitigation the paper cites (§II); used by
  the DSSP-SPMD delayed-gradient pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any
Grads = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Params], Any]
    update: Callable[..., Tuple[Params, Any]]


def _tree_map(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


def _staleness_scale(staleness, damping: bool):
    if not damping:
        return jnp.float32(1.0)
    return 1.0 / (1.0 + jnp.asarray(staleness, jnp.float32))


# ------------------------------------------------------------------ SGD
def sgd(lr: float, *, staleness_damping: bool = False) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, *, staleness=0, lr_scale=1.0):
        s = lr * lr_scale * _staleness_scale(staleness, staleness_damping)
        new = _tree_map(lambda p, g: (p.astype(jnp.float32)
                                      - s * g.astype(jnp.float32)
                                      ).astype(p.dtype), params, grads)
        return new, state

    return Optimizer("sgd", init, update)


def momentum(lr: float, beta: float = 0.9, *, nesterov: bool = False,
             staleness_damping: bool = False) -> Optimizer:
    def init(params):
        return _tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, *, staleness=0, lr_scale=1.0):
        scale = _staleness_scale(staleness, staleness_damping)
        new_v = _tree_map(lambda v, g: beta * v
                          + g.astype(jnp.float32) * scale, state, grads)
        if nesterov:
            step = _tree_map(lambda v, g: beta * v
                             + g.astype(jnp.float32) * scale, new_v, grads)
        else:
            step = new_v
        new_p = _tree_map(lambda p, st: (p.astype(jnp.float32)
                                         - lr * lr_scale * st
                                         ).astype(p.dtype), params, step)
        return new_p, new_v

    return Optimizer("momentum", init, update)


# ------------------------------------------------------------------ AdamW
class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, *,
          staleness_damping: bool = False) -> Optimizer:
    def init(params):
        z = _tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(mu=z, nu=_tree_map(jnp.zeros_like, z),
                         count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, *, staleness=0, lr_scale=1.0):
        count = state.count + 1
        scale = _staleness_scale(staleness, staleness_damping)
        mu = _tree_map(lambda m, g: b1 * m + (1 - b1)
                       * g.astype(jnp.float32) * scale, state.mu, grads)
        nu = _tree_map(lambda v, g: b2 * v + (1 - b2)
                       * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def step(p, m, v):
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            pf = p.astype(jnp.float32)
            pf = pf - lr * lr_scale * (upd + weight_decay * pf)
            return pf.astype(p.dtype)

        new_p = _tree_map(step, params, mu, nu)
        return new_p, AdamState(mu=mu, nu=nu, count=count)

    return Optimizer("adamw", init, update)


# ------------------------------------------------------------------ Adafactor
class AdafactorState(NamedTuple):
    v_row: Any       # factored second moment (rank>=2 leaves)
    v_col: Any
    v_full: Any      # unfactored for vectors
    count: jax.Array


def adafactor(lr: float = 1e-2, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, *,
              staleness_damping: bool = False) -> Optimizer:
    """Factored Adafactor (Shazeer & Stern 2018) without update clipping
    schedules; factored along the last two dims of every rank>=2 leaf."""

    def init(params):
        def rows(p):
            if p.ndim < 2:
                return jnp.zeros((), jnp.float32)
            return jnp.zeros(p.shape[:-1], jnp.float32)

        def cols(p):
            if p.ndim < 2:
                return jnp.zeros((), jnp.float32)
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)

        def full(p):
            if p.ndim < 2:
                return jnp.zeros(p.shape, jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdafactorState(v_row=_tree_map(rows, params),
                              v_col=_tree_map(cols, params),
                              v_full=_tree_map(full, params),
                              count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, *, staleness=0, lr_scale=1.0):
        count = state.count + 1
        t = count.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)
        scale = _staleness_scale(staleness, staleness_damping)

        def upd(p, g, vr, vc, vf):
            gf = g.astype(jnp.float32) * scale
            g2 = jnp.square(gf) + eps
            if p.ndim < 2:
                nvf = beta * vf + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(nvf + eps)
                nvr, nvc = vr, vc
            else:
                nvr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                nvc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                # normalized row factor keeps the factored product an
                # unbiased estimate of the full second moment
                r = nvr / jnp.maximum(
                    jnp.mean(nvr, axis=-1, keepdims=True), eps)
                denom = (jnp.sqrt(r)[..., :, None]
                         * jnp.sqrt(nvc)[..., None, :] + eps)
                u = gf / denom
                nvf = vf
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            pf = p.astype(jnp.float32) - lr * lr_scale * u
            return pf.astype(p.dtype), nvr, nvc, nvf

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_vr = jax.tree_util.tree_leaves(state.v_row)
        flat_vc = jax.tree_util.tree_leaves(state.v_col)
        flat_vf = jax.tree_util.tree_leaves(state.v_full)
        outs = [upd(p, g, vr, vc, vf) for p, g, vr, vc, vf
                in zip(flat_p, flat_g, flat_vr, flat_vc, flat_vf)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_state = AdafactorState(
            v_row=tdef.unflatten([o[1] for o in outs]),
            v_col=tdef.unflatten([o[2] for o in outs]),
            v_full=tdef.unflatten([o[3] for o in outs]),
            count=count)
        return new_p, new_state

    return Optimizer("adafactor", init, update)


def make_optimizer(name: str, lr: float = 1e-3, **kw) -> Optimizer:
    name = name.lower()
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "momentum":
        return momentum(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")


# ---------------------------------------------------------- sharding specs
def state_partition_specs(opt: Optimizer, param_specs: Any,
                          param_sds: Any) -> Any:
    """PartitionSpec tree for ``opt``'s state, derived from the params'
    specs (optimizer state shards exactly like its parameter; factored
    Adafactor moments inherit the surviving dims' spec)."""
    from jax.sharding import PartitionSpec as P

    def norm(spec, rank):
        dims = list(spec) + [None] * (rank - len(spec))
        return dims[:rank]

    if opt.name in ("sgd",):
        return ()
    if opt.name == "momentum":
        return param_specs
    if opt.name == "adamw":
        return AdamState(mu=param_specs, nu=param_specs, count=P())

    if opt.name == "adafactor":
        flat_specs = jax.tree_util.tree_leaves(
            param_specs, is_leaf=lambda x: isinstance(x, P))
        flat_sds, tdef = jax.tree_util.tree_flatten(param_sds)

        rows, cols, fulls = [], [], []
        for spec, sds in zip(flat_specs, flat_sds):
            rank = len(sds.shape)
            dims = norm(spec, rank)
            if rank < 2:
                rows.append(P())
                cols.append(P())
                fulls.append(P(*dims))
            else:
                rows.append(P(*dims[:-1]))
                cols.append(P(*(dims[:-2] + [dims[-1]])))
                fulls.append(P())
        return AdafactorState(v_row=tdef.unflatten(rows),
                              v_col=tdef.unflatten(cols),
                              v_full=tdef.unflatten(fulls),
                              count=P())
    raise ValueError(f"no spec rule for optimizer {opt.name!r}")
