import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

For each cell this driver:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. builds the step bundle (train_step with the DSSP delayed-gradient
     pipeline / prefill / serve_step) with full in/out shardings,
  3. ``jax.jit(...).lower(**ShapeDtypeStructs).compile()`` — no arrays
     are ever allocated at 123B scale,
  4. prints ``memory_analysis()`` (proves it fits) and
     ``cost_analysis()`` and appends the roofline terms to a JSON report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out reports/dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --arch jamba-v0.1-52b \
      --shape train_4k --mesh single --sync bsp
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import arch_names, get_config
from repro.configs.shapes import SHAPES, cell_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.roofline import analysis as roofline


def _compile(cfg, mesh, shape, sync):
    kw = {"sync": sync} if shape.kind == "train" else {}
    bundle = build_step(cfg, mesh, shape, **kw)
    jitted = jax.jit(bundle.fn,
                     in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
    lowered = jitted.lower(*bundle.input_sds)
    return lowered, lowered.compile()


def fitted_costs(cfg, mesh, shape, sync) -> dict:
    """Exact flops/bytes/collective-bytes via the two-point depth fit
    (roofline.cost_configs); falls back to the full compile's raw costs
    plus the analytic sLSTM correction for the unrolled xLSTM family."""
    cc = roofline.cost_configs(cfg)
    if cc is None:
        _, compiled = _compile(cfg, mesh, shape, sync)
        raw = roofline.raw_costs(compiled)
        raw["flops"] += (roofline.slstm_correction_flops(cfg, shape)
                         / mesh.devices.size)
        raw["fit"] = "direct(unrolled)+slstm-analytic"
        return raw
    cfg1, cfg2, d1, d2, L = cc
    _, comp1 = _compile(cfg1, mesh, shape, sync)
    c1 = roofline.raw_costs(comp1)
    _, comp2 = _compile(cfg2, mesh, shape, sync)
    c2 = roofline.raw_costs(comp2)
    out = {k: roofline.affine_fit(c1[k], c2[k], d1, d2, L)
           for k in ("flops", "bytes", "coll")}
    out["fit"] = f"affine(d{d1},d{d2}->L{L})"
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             sync: str = "dssp", verbose: bool = True,
             cost_fit: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_label = "2x16x16" if multi_pod else "16x16"
    if not cell_applicable(arch, shape_name):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_label,
                "status": "skipped",
                "reason": "full attention is quadratic at 500k "
                          "(DESIGN.md §5)"}
    t0 = time.monotonic()
    mesh = make_production_mesh(multi_pod=multi_pod)

    # 1. full-config compile: the runnability proof + memory analysis
    lowered, compiled = _compile(cfg, mesh, shape, sync)
    lowered_text = lowered.as_text()

    # 2. cost extraction (two-point depth fit, see roofline/analysis.py);
    #    the multi-pod pass skips it (the roofline table is single-pod)
    terms = roofline.extract(compiled, None, cfg, shape, mesh_label)
    if cost_fit:
        costs = fitted_costs(cfg, mesh, shape, sync)
        # replace while-undercounted raw numbers with the fitted ones
        terms.flops = costs["flops"]
        terms.hbm_bytes = costs["bytes"]
        terms.collective_bytes = costs["coll"]
        terms.t_compute = costs["flops"] / roofline.PEAK_FLOPS
        terms.t_memory = costs["bytes"] / roofline.HBM_BW
        terms.t_collective = costs["coll"] / roofline.ICI_BW
    compile_s = time.monotonic() - t0
    mem = compiled.memory_analysis()
    if verbose:
        print(f"--- {arch} × {shape_name} × {mesh_label} "
              f"(sync={sync if shape.kind == 'train' else '-'}) ---")
        print(f"memory_analysis: {mem}")
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        keep = {k: v for k, v in sorted(cost.items())
                if k in ("flops", "bytes accessed", "transcendentals")
                or k.startswith("bytes accessed")}
        print(f"cost_analysis (per-chip): "
              f"{json.dumps(keep, default=float)[:400]}")
        print(roofline.HEADER)
        print(roofline.format_row(terms))
        sys.stdout.flush()

    hbm_limit = 16 * 2**30
    fits = terms.per_device_argument_bytes <= hbm_limit
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_label,
        "status": "ok", "sync": sync if shape.kind == "train" else None,
        "compile_seconds": round(compile_s, 1),
        "fits_hbm": bool(fits),
        "flops_per_chip": terms.flops,
        "hbm_bytes_per_chip": terms.hbm_bytes,
        "collective_bytes_per_chip": terms.collective_bytes,
        "t_compute": terms.t_compute,
        "t_memory": terms.t_memory,
        "t_collective": terms.t_collective,
        "dominant": terms.dominant,
        "model_flops": terms.model_flops,
        "useful_flops_ratio": terms.useful_flops_ratio,
        "roofline_fraction": terms.roofline_fraction,
        "argument_gib_per_chip": terms.per_device_argument_bytes / 2**30,
        "peak_gib_per_chip": terms.peak_memory_bytes / 2**30,
        "collective_counts": terms.collective_counts,
        "hlo_bytes": len(lowered_text),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--sync", default="dssp",
                    choices=["bsp", "ssp", "dssp"])
    ap.add_argument("--out", default="")
    ap.add_argument("--keep-going", action="store_true", default=True)
    ap.add_argument("--no-cost-fit", action="store_true")
    args = ap.parse_args()

    archs = arch_names() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results, failures = [], 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                try:
                    results.append(run_cell(arch, shape, multi,
                                            sync=args.sync,
                                            cost_fit=not args.no_cost_fit))
                except Exception as e:  # a failed cell is a bug: report it
                    failures += 1
                    traceback.print_exc()
                    results.append({
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if multi else "16x16",
                        "status": "error", "error": repr(e)[:500],
                    })
                    if not args.keep_going:
                        raise
                if args.out:
                    os.makedirs(os.path.dirname(args.out) or ".",
                                exist_ok=True)
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1, default=float)

    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    print(f"\n=== dry-run: {ok} ok, {sk} skipped, {failures} failed, "
          f"{len(results)} total ===")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
