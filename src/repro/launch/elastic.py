"""Elastic scaling: re-mesh a training state onto a different device count.

Node loss (or growth) flow:
  1. the job restarts with however many devices survive,
  2. ``elastic_mesh(n)`` builds the largest (data, model) mesh that fits,
  3. ``remesh`` device_puts the checkpointed state under the new mesh's
     shardings (host RAM is the transfer buffer — the same path a real
    multi-host restore uses per-host shards for),
  4. the data pipeline re-shards itself by (host_index, n_hosts) — batch
     order is a pure function of the step, so no samples are lost or
     duplicated (data/synthetic.py),
  5. DSSP's controller re-learns step intervals within a few steps
     (the paper's adaptivity argument, §III.B).

The PS layer has its own elasticity (workers join/leave the staleness
tracker at runtime — ps/server.py); this module covers the SPMD path.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import elastic_mesh
from repro.models import registry
from repro.models.params import spec_tree
from repro.models.sharding import rules_for_mesh


def remesh(tree: Any, spec: Any, mesh: jax.sharding.Mesh) -> Any:
    """device_put a pytree under new shardings (specs pytree-aligned)."""
    def put(x, s):
        return jax.device_put(x, NamedSharding(mesh, s))

    return jax.tree_util.tree_map(
        put, tree, spec, is_leaf=lambda x: x is None)


def rescale_params(cfg, params: Any, n_devices: int,
                   model_parallel: int = 16,
                   ) -> Tuple[Any, jax.sharding.Mesh]:
    """Reshard ``params`` for a cluster that now has ``n_devices``."""
    mesh = elastic_mesh(n_devices, model_parallel=model_parallel)
    rules = rules_for_mesh(mesh)
    specs = spec_tree(registry.param_defs(cfg), rules)
    return remesh(params, specs, mesh), mesh
