"""Elastic scaling: adapt a running job to a changed resource shape.

Two independent elasticity paths live in this repo, one per engine:

**SPMD pipeline** (this module): node loss (or growth) flow —
  1. the job restarts with however many devices survive,
  2. ``elastic_mesh(n)`` builds the largest (data, model) mesh that fits,
  3. ``remesh`` device_puts the checkpointed state under the new mesh's
     shardings (host RAM is the transfer buffer — the same path a real
     multi-host restore uses per-host shards for),
  4. the data pipeline re-shards itself by (host_index, n_hosts) — batch
     order is a pure function of the step, so no samples are lost or
     duplicated (data/synthetic.py),
  5. DSSP's controller re-learns step intervals within a few steps
     (the paper's adaptivity argument, §III.B).

**Parameter-server layer**: elasticity has two axes —
  * *worker membership* is handled in-place: workers join/leave the
    per-shard staleness trackers at runtime (``ps/server.py``,
    ``add_worker``/``remove_worker``) and the barrier gate re-derives
    its group from the live membership;
  * *shard arity* is handled by **live resharding**
    (``repro.ft.reshard`` + ``ShardedParameterServer.reshard``): the
    packed parameter+momentum regions migrate S -> S' one shard at a
    time under the per-shard locks while training continues, and
    clients resync through the version-delta full-pull fallback.
    ``reshard_ps`` below is the launch-layer entry point.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: F401

from repro.launch.mesh import elastic_mesh
from repro.models import registry
from repro.models.params import spec_tree
from repro.models.sharding import rules_for_mesh


def remesh(tree: Any, spec: Any, mesh: jax.sharding.Mesh) -> Any:
    """device_put a pytree under new shardings (specs pytree-aligned)."""
    def put(x, s):
        return jax.device_put(x, NamedSharding(mesh, s))

    return jax.tree_util.tree_map(
        put, tree, spec, is_leaf=lambda x: x is None)


def rescale_params(cfg, params: Any, n_devices: int,
                   model_parallel: int = 16,
                   ) -> Tuple[Any, jax.sharding.Mesh]:
    """Reshard ``params`` for a cluster that now has ``n_devices``."""
    mesh = elastic_mesh(n_devices, model_parallel=model_parallel)
    rules = rules_for_mesh(mesh)
    specs = spec_tree(registry.param_defs(cfg), rules)
    return remesh(params, specs, mesh), mesh


def reshard_ps(server, n_shards: int) -> bool:
    """PS-side elasticity: migrate a live sharded server to
    ``n_shards`` partitions without stopping training.

    Thin launch-layer alias of ``repro.ft.reshard.live_reshard`` — the
    full protocol (migration map, parked pushes, epoch bump, client
    resync) is documented there.  Returns False when the server is
    already at that arity.
    """
    from repro.ft.reshard import live_reshard
    return live_reshard(server, n_shards)
