"""Serving driver: train and serve the SAME parameters in one run.

One ``RunSpec`` stands up the whole loop — a DSSP training fleet
pushing gradients at the parameter server while ``repro.serve``
replicas subscribe to it over the same transport, keep a resident
packed buffer fresh via version-delta pulls, and decode continuously-
batched requests behind the ``serve.staleness_bound`` admission gate.

On this container it runs the reduced smoke configs on CPU processes;
on a pod the identical code path serves the production configs — the
spec is the only thing that changes.

CLI:
  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --transport tcp --workers 2 --replicas 2 --steps 40 \
      --requests 16 --prompt-len 8 --max-new 4
"""

from __future__ import annotations

import argparse
import json


def build_spec(args) -> "RunSpec":
    from repro.api import (
        DataSpec,
        ModelSpec,
        ObsSpec,
        RunSpec,
        ServeSpec,
        ServerSpec,
        SyncSpec,
        TransportSpec,
        WireSpec,
    )
    return RunSpec(
        model=ModelSpec(arch=args.arch, smoke=args.smoke),
        data=DataSpec(seq_len=args.seq_len, global_batch=args.batch),
        ps=ServerSpec(kind="sharded", shards=args.shards,
                      workers=args.workers, apply="fused"),
        sync=SyncSpec(mode=args.sync),
        wire=WireSpec(format="packed", delta_pull=True),
        transport=TransportSpec(kind=args.transport, endpoint=True),
        obs=ObsSpec(trace=bool(args.trace), trace_path=args.trace),
        serve=ServeSpec(replicas=args.replicas,
                        refresh_every_s=args.refresh_every_s,
                        staleness_bound=args.staleness_bound,
                        batch_window_ms=args.batch_window_ms,
                        max_batch=args.max_batch,
                        requests=args.requests,
                        request_every_ms=args.request_every_ms,
                        start_at_version=args.start_at_version,
                        prompt_len=args.prompt_len,
                        max_new=args.max_new))


def main() -> None:
    ap = argparse.ArgumentParser(
        description="train + serve one parameter store over a live "
                    "transport (repro.serve)")
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--transport", default="tcp",
                    choices=("tcp", "shmem"))
    ap.add_argument("--sync", default="dssp",
                    choices=("bsp", "ssp", "dssp", "asp"))
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--requests", type=int, default=16,
                    help="closed-loop requests per replica")
    ap.add_argument("--request-every-ms", type=float, default=100.0)
    ap.add_argument("--start-at-version", type=int, default=1,
                    help="hold requests until the server has applied "
                         "this many updates (serving overlaps live "
                         "training, not worker compile time)")
    ap.add_argument("--refresh-every-s", type=float, default=0.05)
    ap.add_argument("--staleness-bound", type=int, default=4)
    ap.add_argument("--batch-window-ms", type=float, default=2.0)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--trace", default="",
                    help="write the merged run trace here (.jsonl or "
                         "chrome .json)")
    args = ap.parse_args()

    from repro.api import build_session
    spec = build_spec(args)
    with build_session(spec) as session:
        metrics = session.run(steps=args.steps)

    serve = metrics.get("serve", {})
    print(f"\narch={args.arch} transport={args.transport} "
          f"workers={args.workers} replicas={args.replicas}")
    print(f"train: pushes={metrics['pushes']} "
          f"applied_updates={metrics['applied_updates']} "
          f"final_loss={metrics['final_loss']}")
    print("serve:", json.dumps(serve, indent=2, sort_keys=True))
    if serve.get("violations", 0):
        raise SystemExit(
            f"{serve['violations']} staleness-bound violations — the "
            "admission gate failed")


if __name__ == "__main__":
    main()
