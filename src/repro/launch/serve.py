"""Serving driver: batched prefill + decode with the production step
bundles (the same functions the decode_32k / long_500k dry-run cells
lower at scale).

On this container it serves the reduced configs on one CPU device; on a
pod the identical code path runs under the production mesh via
``build_serve_step``.

CLI:
  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --smoke --batch 4 --prompt-len 16 --max-new 16
"""

from __future__ import annotations

import argparse
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.synthetic import DataConfig, MarkovLM
from repro.models import registry, transformer


def generate(cfg, params, prompts: jax.Array, max_new: int,
             ) -> Tuple[np.ndarray, float]:
    """Greedy continuation. Dense/MoE/VLM get fused prefill; recurrent
    families (ssm/hybrid) prefill by scanning their decode step (their
    per-token state update IS the prefill)."""
    b, prompt_len = prompts.shape
    fam = registry.family(cfg)
    total = prompt_len + max_new
    t0 = time.monotonic()

    if cfg.family in ("dense", "moe", "vlm"):
        logits, cache = jax.jit(
            lambda p, t: transformer.forward_prefill(cfg, p, t)
        )(params, prompts)
        cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, total - v.shape[2]),
                                (0, 0), (0, 0)))
                 for k, v in cache.items()}
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        start = prompt_len
    else:
        state = (fam.init_state(cfg, b, total, total)
                 if cfg.family == "audio"
                 else fam.init_state(cfg, b, total))
        step = jax.jit(lambda p, t, s, i: fam.decode_fn(cfg, p, t, s, i))
        logits = None
        for i in range(prompt_len):
            logits, state = step(params, prompts[:, i:i + 1], state,
                                 jnp.int32(i))
        cache = state
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        start = prompt_len

    decode = jax.jit(lambda p, t, c, i: fam.decode_fn(cfg, p, t, c, i))
    out = [next_tok]
    for j in range(max_new - 1):
        logits, cache = decode(params, next_tok, cache,
                               jnp.int32(start + j))
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(next_tok)
    tokens = np.asarray(jnp.concatenate(out, axis=1))
    return tokens, time.monotonic() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "audio":
        raise SystemExit("audio serving demo: see examples/serve_decode.py"
                         " (needs encoder frames)")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    chain = MarkovLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                global_batch=args.batch))
    rows = chain.sample_rows(0, np.arange(args.batch))
    prompts = jnp.asarray(rows[:, :args.prompt_len])
    tokens, dt = generate(cfg, params, prompts, args.max_new)
    per_tok = dt / (args.max_new * args.batch) * 1e3
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.max_new}")
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({per_tok:.1f} ms/token incl. compile)")
    print("sample:", tokens[0][:12].tolist())


if __name__ == "__main__":
    main()
