"""Train / serve step builders with full sharding metadata.

``build_train_step``/``build_serve_step`` return (fn, in_shardings,
out_shardings, donate) ready for ``jax.jit`` — used identically by the
real trainer (examples/), the dry-run (lower+compile only) and the
benchmarks.  The DSSP delayed-gradient pipeline threads through the train
step when ``sync != 'bsp'``; its delay is a traced scalar so the
controller re-tunes it without recompiles.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import dssp_spmd
from repro.configs.shapes import ShapeSpec, input_specs, state_sds
from repro.models import registry, transformer
from repro.models.config import ModelConfig
from repro.models.params import sds_tree, spec_tree
from repro.models.sharding import (AxisRules, rules_for_mesh, shard,
                                    use_rules)
from repro.optim import make_optimizer
from repro.optim.optimizers import Optimizer, state_partition_specs


@dataclasses.dataclass
class StepBundle:
    fn: Callable
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    input_sds: Tuple            # ShapeDtypeStructs matching fn's signature
    rules: AxisRules


def _named(mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg: ModelConfig, rules: AxisRules, sds: Dict[str, Any]):
    def spec(x):
        axes = ["batch"] + [None] * (len(x.shape) - 1)
        return rules.spec(axes, x.shape)

    return jax.tree_util.tree_map(spec, sds)


# ------------------------------------------------------------------ train
def build_train_step(cfg: ModelConfig, mesh, shape: ShapeSpec, *,
                     sync: str = "dssp", s_upper: int = 1,
                     optimizer: Optional[Optimizer] = None,
                     lr: float = 3e-4) -> StepBundle:
    rules = rules_for_mesh(mesh, sp=cfg.sequence_parallel,
                           role=cfg.model_axis_role)
    opt = optimizer or make_optimizer(cfg.optimizer, lr)
    lfn = registry.loss_fn(cfg)
    use_pipeline = sync in ("ssp", "dssp")

    defs = registry.param_defs(cfg)
    p_sds = sds_tree(defs, cfg.dtype)
    p_spec = spec_tree(defs, rules)
    o_sds = jax.eval_shape(opt.init, p_sds)
    o_spec = state_partition_specs(opt, p_spec, p_sds)
    b_sds = input_specs(cfg, shape)
    b_spec = batch_specs(cfg, rules, b_sds)

    if use_pipeline:
        grads_sds = p_sds  # grads shaped like params (cast to cfg dtype)
        pipe_sds = jax.eval_shape(
            functools.partial(dssp_spmd.init_pipeline, depth=s_upper + 1),
            grads_sds)
        pipe_spec = dssp_spmd.pipeline_specs(p_spec, s_upper + 1)
    else:
        pipe_sds, pipe_spec = (), ()

    import math as _math
    accum = _math.gcd(max(1, cfg.grad_accum), shape.global_batch)

    def _grads(params, batch):
        """value_and_grad with microbatch accumulation: remat saves one
        residual stack per *microbatch*, so 88-layer models fit
        16 GB/chip at global batch 256 (see DESIGN.md §9)."""
        if accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                lfn, has_aux=True)(params, batch)
            return loss, grads

        def split(x):
            # interleaved split: microbatch a = rows a::accum, so each
            # microbatch holds exactly rows_per_device/accum rows on
            # every device (a local view of the 'data'-sharded batch —
            # a contiguous split would put whole microbatches on single
            # devices and force a reshard per scan step)
            mb = x.shape[0] // accum
            perm = (1, 0) + tuple(range(2, x.ndim + 1))
            return x.reshape((mb, accum) + x.shape[1:]).transpose(perm)

        micro_batches = jax.tree_util.tree_map(split, batch)

        def micro(g_acc, mb):
            mb = jax.tree_util.tree_map(
                lambda x: shard(x, "batch", *([None] * (x.ndim - 1))), mb)
            (loss, _), g = jax.value_and_grad(lfn, has_aux=True)(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32) / accum, g_acc, g)
            return g_acc, loss

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, losses = jax.lax.scan(micro, g0, micro_batches)
        grads = jax.tree_util.tree_map(
            lambda g, p: g.astype(p.dtype), grads, params)
        return jnp.mean(losses), grads

    def train_step(params, opt_state, pipeline, batch, delay):
        with use_rules(rules):
            loss, grads = _grads(params, batch)
            if use_pipeline:
                grads, valid, pipeline = dssp_spmd.push_pop(
                    pipeline, grads, delay)
                staleness = delay
                lr_scale = valid
            else:
                staleness, lr_scale = 0, 1.0
            params, opt_state = opt.update(grads, opt_state, params,
                                           staleness=staleness,
                                           lr_scale=lr_scale)
        out_metrics = {"loss": loss}
        return params, opt_state, pipeline, out_metrics

    metrics_spec = jax.tree_util.tree_map(
        lambda _: P(), jax.eval_shape(
            train_step, p_sds, o_sds, pipe_sds, b_sds,
            jax.ShapeDtypeStruct((), jnp.int32))[3])

    in_sh = (_named(mesh, p_spec), _named(mesh, o_spec),
             _named(mesh, pipe_spec), _named(mesh, b_spec),
             NamedSharding(mesh, P()))
    out_sh = (_named(mesh, p_spec), _named(mesh, o_spec),
              _named(mesh, pipe_spec), _named(mesh, metrics_spec))
    input_sds = (p_sds, o_sds, pipe_sds, b_sds,
                 jax.ShapeDtypeStruct((), jnp.int32))
    return StepBundle(train_step, in_sh, out_sh, (0, 1, 2), input_sds,
                      rules)


# ------------------------------------------------------------------ prefill
def build_prefill_step(cfg: ModelConfig, mesh, shape: ShapeSpec) -> StepBundle:
    # prefill/serve always use TP weight layouts: model_axis_role='dp' is
    # a TRAINING choice (batch 256 covers the joint axes); at prefill
    # batch 32 the model axis would sit idle (measured 5.5 -> 74 s on
    # h2o prefill under dp rules)
    rules = rules_for_mesh(mesh, sp=cfg.sequence_parallel, role="tp")
    defs = registry.param_defs(cfg)
    p_sds = sds_tree(defs, cfg.dtype)
    p_spec = spec_tree(defs, rules)
    b_sds = input_specs(cfg, shape)
    b_spec = batch_specs(cfg, rules, b_sds)
    fam = registry.family(cfg)

    if cfg.family in ("dense", "moe", "vlm"):
        def prefill(params, batch):
            with use_rules(rules):
                logits, cache = transformer.forward_prefill(
                    cfg, params, batch["tokens"])
                token = jnp.argmax(logits[:, -1], axis=-1)
            return token, cache

        cache_spec = transformer.cache_specs(
            cfg, shape.global_batch, shape.seq_len, rules)
        tok_spec = rules.spec(("batch",), (shape.global_batch,))
        out_sh = (NamedSharding(mesh, tok_spec), _named(mesh, cache_spec))
    else:
        # ssm/hybrid/audio: prefill = full forward, greedy last token
        # (state capture for these families happens step-wise; noted in
        # DESIGN.md — the trunk compute is identical)
        def prefill(params, batch):
            with use_rules(rules):
                loss_like = fam.loss_fn(cfg, params, batch)
            return loss_like[0]

        out_sh = NamedSharding(mesh, P())

    in_sh = (_named(mesh, p_spec), _named(mesh, b_spec))
    return StepBundle(prefill, in_sh, out_sh, (), (p_sds, b_sds), rules)


# ------------------------------------------------------------------ decode
def build_serve_step(cfg: ModelConfig, mesh, shape: ShapeSpec) -> StepBundle:
    # decode always uses TP weight sharding (sp=False, role='tp'):
    # SP-mode replicates attention weights over 'model' (right for
    # seq-sharded training, wrong per-token at decode — §Perf it.11) and
    # dp-role leaves the model axis idle at batch < 256
    rules = rules_for_mesh(mesh, sp=False, role="tp")
    if not cfg.decode_batch_shard:
        # qwen1.5-32b: the 40-head MHA cache only fits when cache_seq
        # takes BOTH mesh axes; batch stays replicated (decode compute is
        # one token -- the cache is the footprint that matters)
        rules = AxisRules(dict(rules.rules, batch=None),
                          rules.axis_sizes, rules.mesh)
    defs = registry.param_defs(cfg)
    p_sds = sds_tree(defs, cfg.dtype)
    p_spec = spec_tree(defs, rules)
    fam = registry.family(cfg)

    cache_sds = state_sds(cfg, shape)
    b, l = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        cache_spec = fam.state_specs(cfg, b, l, l, rules)
    else:
        cache_spec = fam.state_specs(cfg, b, l, rules)
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_spec = rules.spec(("batch", None), (b, 1))

    def serve_step(params, token, cache, index):
        with use_rules(rules):
            logits, new_cache = fam.decode_fn(cfg, params, token, cache,
                                              index)
            next_token = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return next_token, new_cache

    in_sh = (_named(mesh, p_spec), NamedSharding(mesh, tok_spec),
             _named(mesh, cache_spec), NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, tok_spec), _named(mesh, cache_spec))
    input_sds = (p_sds, tok_sds, cache_sds,
                 jax.ShapeDtypeStruct((), jnp.int32))
    return StepBundle(serve_step, in_sh, out_sh, (2,), input_sds, rules)


def build_step(cfg: ModelConfig, mesh, shape: ShapeSpec, **kw) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape)
    return build_serve_step(cfg, mesh, shape)
