"""Process worker pool: real OS processes driving the PS over a transport.

The threaded ``PSWorker`` shares a Python heap with the server, so the
packed wire buffer never actually crosses a process boundary and
stragglers are simulated with sleeps against GIL-released compute.
``ProcessWorkerPool`` spawns N *processes* instead: each one rebuilds
the model deterministically from its ``WorkerTask`` spec (same
``PRNGKey(0)`` init and ``ShardPlan`` as the parent — the plan is pure
metadata, so both sides derive identical wire layouts), connects to the
server's transport address, and runs the paper's worker loop

    pull packed params -> jitted step (unpack, grad, re-pack) ->
    push packed grads -> blocked until the sync policy releases it

entirely in frame bytes.  A per-worker ``slowdown`` factor sleeps
``(slowdown - 1) x measured_compute`` per iteration, which now creates
*genuine* heterogeneous stragglers — separate interpreters, separate
GILs, real wire in between — the regime DSSP's dynamic threshold is
designed for.

Workers are spawned (never forked): forking a process with a live JAX
runtime is undefined behavior, and spawn also matches how a multi-host
deployment would launch ranks.
"""

from __future__ import annotations

import dataclasses
import multiprocessing
import os
import time
import traceback
from typing import Any, Dict, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class WorkerTask:
    """Everything a spawned worker needs to rebuild its half of the run.

    Must stay picklable and small — it crosses the spawn boundary, the
    weights do not (the worker pulls them over the transport).
    """

    arch: str                 # repro.configs key, e.g. "xlstm-125m"
    n_shards: int             # parent's ShardPlan arity (layout must match)
    n_iterations: int
    smoke: bool = True
    kernels: str = "auto"     # model.kernels dispatch string (registry)
    seq_len: int = 64
    global_batch: int = 8
    data_seed: int = 0        # worker w streams shard seed data_seed+1+w
    compress: str = "none"    # frame-level wire compression (int8)
    delta_pull: bool = False  # version-delta pulls over PULL_DELTA frames
    trace: bool = False       # arm the worker's repro.obs ring buffer
    trace_spill: str = ""     # dir for the per-worker JSONL spill file
    trace_flush_every: int = 32  # iterations between TRACE-frame flushes
    # -- fault tolerance (repro.ft) ---------------------------------
    reconnect_tries: int = 0  # per-outage reconnect budget (0 = die)
    reconnect_base_s: float = 0.1
    reconnect_max_s: float = 2.0
    fault_plan: Optional[Dict[str, Any]] = None  # FaultPlan.to_dict()

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_spec(cls, spec, n_iterations: int, *, trace_spill: str = "",
                  trace_flush_every: int = 32) -> "WorkerTask":
        """Derive the spawn payload from a ``repro.api.RunSpec``.

        Only the int8 compression rides the frames (bytes shrink on the
        OS wire; the codec dequantizes on receipt) — topk has no
        frame-level encoding and stays a server-side pass.

        ``n_shards`` is clamped to >= 1: a monolithic spec may carry
        ``ps.shards=0`` (the ServerSpec default), but the worker-side
        ``build_shard_plan`` — and the mono server's own packed plan —
        are single-shard.
        """
        return cls(arch=spec.model.arch,
                   n_shards=max(1, spec.ps.shards),
                   n_iterations=n_iterations,
                   smoke=spec.model.smoke,
                   kernels=spec.model.kernels,
                   seq_len=spec.data.seq_len,
                   global_batch=spec.data.global_batch,
                   data_seed=spec.data.seed,
                   compress=("int8" if spec.wire.compression == "int8"
                             else "none"),
                   delta_pull=spec.wire.delta_pull,
                   trace=bool(getattr(spec, "obs", None)
                              and spec.obs.trace),
                   trace_spill=trace_spill,
                   trace_flush_every=trace_flush_every,
                   reconnect_tries=spec.ft.reconnect_tries,
                   reconnect_base_s=spec.ft.reconnect_base_s,
                   reconnect_max_s=spec.ft.reconnect_max_s,
                   fault_plan=(spec.ft.fault_plan().to_dict()
                               if spec.ft.faults else None))


@dataclasses.dataclass
class WorkerResult:
    worker_id: int
    iterations_done: int
    error: Optional[str] = None      # traceback text for failed workers
    exitcode: Optional[int] = None


def _worker_main(task: Dict[str, Any], address, worker_id: int,
                 slowdown: float, queue) -> None:
    """Entry point of one spawned worker process."""
    done = 0
    try:
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import functools

        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.configs import get_config, get_smoke_config
        from repro.data.synthetic import DataConfig, batches
        from repro.models import registry
        from repro.ps.sharded.plan import build_shard_plan
        from repro.transport import connect
        from repro.wireformat import WIRE_LANES, FrameError

        cfg = (get_smoke_config(task["arch"]) if task["smoke"]
               else get_config(task["arch"]))
        if task.get("kernels", "auto") != cfg.kernels:
            cfg = dataclasses.replace(cfg, kernels=task["kernels"])
        data_cfg = DataConfig(vocab_size=cfg.vocab_size,
                              seq_len=task["seq_len"],
                              global_batch=task["global_batch"],
                              seed=task["data_seed"] + 1 + worker_id)
        loss_fn = registry.loss_fn(cfg)
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        plan = build_shard_plan(params, task["n_shards"])
        layout = plan.wire_layout()
        del params  # the live weights come over the wire

        def make_step(plan):
            @functools.partial(jax.jit, donate_argnums=(1,))
            def packed_step(wire_p, wire_g_prev, batch):
                p = plan.unpack(wire_p)
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(p, batch)
                return wire_g_prev.at[:].set(plan.pack(grads)), loss
            return packed_step

        packed_step = make_step(plan)

        from repro.ft.backoff import BackoffPolicy
        from repro.ft.faults import FaultPlan, kill_self, wrap_channel
        from repro.transport.base import TransportClosed

        fault_plan = FaultPlan.from_dict(task.get("fault_plan"))
        reconnect_tries = int(task.get("reconnect_tries", 0))
        reconnect_policy = (BackoffPolicy(
            base_s=task.get("reconnect_base_s", 0.1), factor=2.0,
            max_s=task.get("reconnect_max_s", 2.0),
            max_tries=reconnect_tries) if reconnect_tries > 0 else None)

        tracer = spill_fh = None
        if task.get("trace"):
            from repro.obs.trace import TRACE as tracer
            tracer.enable(source=f"w{worker_id}")
            if task.get("trace_spill"):
                # Append-mode JSONL spill: every drained batch lands on
                # disk BEFORE the frame send, so a worker killed mid-run
                # leaves its events recoverable (collector dedups the
                # ones that also made it over the wire).
                os.makedirs(task["trace_spill"], exist_ok=True)
                spill_fh = open(os.path.join(task["trace_spill"],
                                             f"w{worker_id}.jsonl"),
                                "a", encoding="utf-8")

        client = connect(address, worker_id, compress=task["compress"])
        if fault_plan.wants_channel:
            # Drop/delay faults wrap the live channel AND the factory,
            # so a post-reconnect channel stays faulty too.
            client.channel = wrap_channel(client.channel, fault_plan, worker_id)
            inner_factory = client.channel_factory
            if inner_factory is not None:
                client.channel_factory = (
                    lambda: wrap_channel(inner_factory(), fault_plan, worker_id))

        def flush_trace() -> None:
            if tracer is None:
                return
            events = tracer.drain()
            if not events:
                return
            if spill_fh is not None:
                import json
                for e in events:
                    spill_fh.write(json.dumps(e, separators=(",", ":")))
                    spill_fh.write("\n")
                spill_fh.flush()
            try:
                client.send_trace(events)
            except Exception:
                pass  # server gone — the spill file still has them

        rows = client.hello()
        if rows != layout.total_rows:
            raise ValueError(
                f"server wire layout has {rows} rows, local plan derives "
                f"{layout.total_rows} — task spec out of sync with server")
        wire_g = jnp.zeros((layout.total_rows, WIRE_LANES), layout.dtype)
        stream = batches(cfg, data_cfg)
        # Version-delta pulls keep a RESIDENT host-side buffer: only the
        # shard regions whose version advanced since the last pull cross
        # the wire, and they are patched into the buffer in place.
        delta_pull = bool(task.get("delta_pull"))
        wire_host = np.zeros((layout.total_rows, WIRE_LANES),
                             layout.dtype) if delta_pull else None
        versions = (-1,) * task["n_shards"]
        row_start = layout.shard_row_start
        epoch = client.reshard_epoch

        def rebuild_layout(n_shards: int, new_epoch: int) -> None:
            """Live-reshard rebuild: the server's shard arity changed
            under us (delta reply carried a new epoch / a different
            version-vector length).  Re-derive the plan at the new
            arity — ``rebuild`` only needs leaf shapes, never weights —
            re-jit the step, and re-size every layout-shaped buffer.
            The triggering reply is a full snapshot in the NEW layout,
            so patching it into the fresh host buffer is complete."""
            nonlocal plan, layout, row_start, wire_host, wire_g
            nonlocal packed_step, epoch
            plan = plan.rebuild(n_shards)
            layout = plan.wire_layout()
            row_start = layout.shard_row_start
            if wire_host is not None:
                wire_host = np.zeros((layout.total_rows, WIRE_LANES),
                                     layout.dtype)
            wire_g = jnp.zeros((layout.total_rows, WIRE_LANES),
                               layout.dtype)
            packed_step = make_step(plan)
            epoch = new_epoch
            # Future pushes are packed against the NEW layout; stamp
            # the epoch they should be applied under.
            client.reshard_epoch = new_epoch
        try:
            it = 0
            while it < task["n_iterations"]:
                if fault_plan.worker_kill_due(worker_id, it):
                    flush_trace()
                    kill_self()  # pragma: no cover - process dies here
                try:
                    # copy=True (the default): on CPU, jnp.asarray may
                    # ALIAS host memory instead of copying, and a device
                    # buffer aliasing the shmem slot would outlive the
                    # RPC lifetime contract (and pin the mapping at
                    # close).
                    if delta_pull:
                        d = client.pull_delta(versions, copy=False)
                        if d is None:
                            break  # server stopped
                        if (d.epoch != epoch
                                or len(d.versions) != len(versions)):
                            rebuild_layout(len(d.versions), d.epoch)
                            if not d.full:
                                # Paranoia: an epoch change must arrive
                                # as a full snapshot; if it somehow did
                                # not, re-pull from scratch.
                                d = client.pull_delta(
                                    (-1,) * len(d.versions), copy=False)
                                if d is None:
                                    break
                        for j, region in zip(d.shards, d.regions):
                            wire_host[row_start[j]:
                                      row_start[j]
                                      + region.shape[0]] = region
                        versions = d.versions
                        # jnp.array COPIES (asarray may alias on CPU,
                        # and the resident buffer mutates in place next
                        # pull).
                        wire_p = jnp.array(wire_host)
                    else:
                        wire_np = client.pull_packed()
                        if wire_np is None:
                            break  # server stopped
                        if wire_np.shape[0] != layout.total_rows:
                            # Live reshard changed the wire layout.  A
                            # plain pull carries no arity, so probe it
                            # with a deliberately-mismatched delta pull:
                            # the full-fallback reply's version vector
                            # length IS the new arity, and it carries
                            # the new epoch.
                            probe = client.pull_delta((-1,))
                            if probe is None:
                                break
                            rebuild_layout(len(probe.versions),
                                           probe.epoch)
                            wire_np = client.pull_packed()
                            if wire_np is None:
                                break
                        wire_p = jnp.asarray(wire_np)
                    batch = {k: jnp.asarray(v)
                             for k, v in next(stream).items()}
                    t_tr = tracer.now() if tracer is not None else 0.0
                    t0 = time.monotonic()
                    wire_g, loss = packed_step(wire_p, wire_g, batch)
                    loss = float(jax.block_until_ready(loss))
                    compute = time.monotonic() - t0
                    if slowdown > 1.0:
                        # The sleep IS the emulated slower device, so
                        # the compute_step span includes it.
                        time.sleep(compute * (slowdown - 1.0))
                    if tracer is not None:
                        tracer.span("compute_step", t_tr,
                                    worker=worker_id, clock=it,
                                    args={"loss": loss})
                    client.record_loss(it, loss)
                    if not client.push_packed(np.asarray(wire_g),
                                              clock=it):
                        done += 1
                        break  # released with a STOP: training is over
                except FrameError as e:
                    if "resync" not in str(e):
                        raise
                    # Retryable bounce: the server could not place this
                    # frame under any layout it still knows (e.g. a
                    # failed-over server restored to a plan that
                    # predates our epoch).  Retry the iteration — the
                    # pull at the top full-resyncs and rebuilds the
                    # local layout first.
                    continue
                except (TransportClosed, OSError):
                    # The server died under us.  With a reconnect
                    # budget: back off, rebuild the channel, re-HELLO
                    # (idempotent — the seat is re-acquired, never
                    # duplicated), and RETRY this same iteration.  The
                    # kept `versions` vector is now ahead of the
                    # restored server's, so the next pull_delta
                    # dominance check forces a full resync; a push that
                    # died mid-gate is re-sent (duplicate-apply is
                    # ordinary async-SGD noise, loss is never lost
                    # silently).
                    if reconnect_policy is None:
                        raise
                    client.reconnect(reconnect_policy, seed=worker_id)
                    continue
                done += 1
                it += 1
                if it % max(1, task.get("trace_flush_every", 32)) == 0:
                    flush_trace()
        finally:
            flush_trace()
            client.bye()
            client.close()
            if spill_fh is not None:
                spill_fh.close()
        queue.put(WorkerResult(worker_id, done))
    except BaseException:
        queue.put(WorkerResult(worker_id, done,
                               error=traceback.format_exc()))
        raise


class ProcessWorkerPool:
    """Spawn/join N transport workers with per-worker slowdown factors."""

    def __init__(self, address, task: WorkerTask, n_workers: int, *,
                 slowdowns: Optional[Sequence[float]] = None,
                 mp_context: str = "spawn"):
        if slowdowns is not None and len(slowdowns) != n_workers:
            raise ValueError(f"{len(slowdowns)} slowdown factors for "
                             f"{n_workers} workers")
        self.address = address
        self.task = task
        self.n_workers = n_workers
        self.slowdowns = list(slowdowns or [1.0] * n_workers)
        self._ctx = multiprocessing.get_context(mp_context)
        self._queue = self._ctx.Queue()
        self.procs: List[multiprocessing.Process] = []

    def start(self) -> None:
        task = self.task.to_dict()
        for w in range(self.n_workers):
            self.procs.append(self._spawn(w, task))

    def _spawn(self, w: int, task: Dict[str, Any]):
        p = self._ctx.Process(
            target=_worker_main,
            args=(task, self.address, w, self.slowdowns[w], self._queue),
            name=f"ps-proc-worker-{w}", daemon=True)
        p.start()
        return p

    @staticmethod
    def _respawn_task(task: Dict[str, Any]) -> Dict[str, Any]:
        """The task a replacement worker runs: identical, minus the
        self-kill fault (a respawned worker re-killing itself at the
        same round would churn forever)."""
        clean = dict(task)
        fp = dict(clean.get("fault_plan") or {})
        if fp:
            fp["kill_worker"] = -1
            fp["kill_worker_round"] = -1
            clean["fault_plan"] = fp
        return clean

    def join(self, timeout: float = 900.0, *, endpoint=None,
             respawn: int = 0) -> List[WorkerResult]:
        """Join all workers; reap stragglers; surface per-worker results.

        ``endpoint`` (a ``PSServerEndpoint``) gets ``on_disconnect`` for
        every abnormal exit — transports without connection semantics
        (shmem) cannot detect a dead peer themselves, and a corpse must
        not keep its seat in the barrier group.

        ``respawn`` is the elastic-fleet budget: up to that many
        abnormally-dead workers are replaced by fresh processes running
        the same task (its barrier seat was freed by ``on_disconnect``
        first, and the replacement's HELLO re-acquires it — exactly
        once).  The replacement restarts its local loop from iteration
        0: worker iterations are interchangeable SGD contributions, so
        elasticity costs repeated work, never corrupted state.
        """
        deadline = time.monotonic() + timeout
        # Poll instead of a blocking per-process join: a worker that
        # dies abnormally must release its barrier seat IMMEDIATELY
        # (endpoint.on_disconnect), or gate-blocked survivors would
        # wait on the corpse for the rest of the timeout.  tcp detects
        # this by EOF on its own; shmem has no connection, so this loop
        # is the only death detector it gets.
        reported = set()
        respawn_left = int(respawn)
        respawn_task = self._respawn_task(self.task.to_dict())
        self.respawned: List[int] = []
        while time.monotonic() < deadline:
            alive = False
            for w, p in enumerate(self.procs):
                if p.is_alive():
                    alive = True
                elif p.exitcode not in (0, None) and w not in reported:
                    if endpoint is not None:
                        endpoint.on_disconnect(w)
                    reported.add(w)
                    if respawn_left > 0:
                        respawn_left -= 1
                        self.procs[w] = self._spawn(w, respawn_task)
                        self.respawned.append(w)
                        reported.discard(w)
                        alive = True
            if not alive:
                break
            time.sleep(0.05)
        by_worker: Dict[int, WorkerResult] = {}
        while not self._queue.empty():
            r = self._queue.get_nowait()
            by_worker[r.worker_id] = r
        results = []
        for w, p in enumerate(self.procs):
            if p.is_alive():
                p.terminate()
                p.join(timeout=5.0)
            r = by_worker.get(w) or WorkerResult(w, 0, error="no result "
                                                 "(killed or timed out)")
            r.exitcode = p.exitcode
            if (r.error or p.exitcode not in (0, None)) \
                    and endpoint is not None and w not in reported:
                endpoint.on_disconnect(w)
            results.append(r)
        return results

    def terminate(self) -> None:
        for p in self.procs:
            if p.is_alive():
                p.terminate()
        for p in self.procs:
            p.join(timeout=5.0)

    def alive(self) -> List[int]:
        return [w for w, p in enumerate(self.procs) if p.is_alive()]


def raise_on_failure(results: Sequence[WorkerResult]) -> None:
    failed = [r for r in results if r.error]
    if failed:
        msgs = "\n".join(f"-- worker {r.worker_id} "
                         f"(exit {r.exitcode}) --\n{r.error}"
                         for r in failed)
        raise RuntimeError(f"{len(failed)} worker process(es) failed:\n"
                           f"{msgs}")
