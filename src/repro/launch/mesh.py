"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).

  single pod:  (16, 16)        axes ('data', 'model')   = 256 chips
  multi pod:   (2, 16, 16)     axes ('pod', 'data', 'model') = 512 chips
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence[jax.Device]] = None,
              ) -> jax.sharding.Mesh:
    """jax.make_mesh over the first prod(shape) devices (the dry-run
    forces 512 host devices; the single-pod mesh uses the first 256)."""
    need = math.prod(shape)
    devs = list(devices if devices is not None else jax.devices())
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {tuple(shape)} needs {need} devices, have {len(devs)} — "
            "run under dryrun.py (it forces 512 host devices) or shrink "
            "the mesh")
    devs = devs[:need]
    try:
        return jax.make_mesh(tuple(shape), tuple(axes), devices=devs)
    except TypeError:
        # older jax: make_mesh without the devices kwarg
        import numpy as np
        arr = np.asarray(devs).reshape(tuple(shape))
        return jax.sharding.Mesh(arr, tuple(axes))


def elastic_mesh(n_devices: int, *, model_parallel: int = 16,
                 axes: Tuple[str, str] = ("data", "model"),
                 ) -> jax.sharding.Mesh:
    """Largest (data, model) mesh that fits ``n_devices`` — used by the
    elastic-scaling path after node loss (launch/elastic.py)."""
    mp = math.gcd(model_parallel, n_devices)
    dp = n_devices // mp
    return make_mesh((dp, mp), axes, devices=jax.devices()[:dp * mp])
