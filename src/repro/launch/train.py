"""End-to-end trainer: DSSP-SPMD pipeline + controller + checkpoints.

Runs on anything from 1 CPU device (smoke/reduced configs — this
container) to the production mesh (full configs — the same step bundle
the dry-run compiles).  The synchronization mode is first-class:

    --sync bsp    psum-every-step baseline
    --sync ssp    delayed-gradient pipeline, fixed delay = s_lower
    --sync dssp   delayed-gradient pipeline, delay re-tuned every step by
                  DsspScheduleController from measured step/collective
                  times (no recompile: the delay is a traced scalar)

Fault tolerance: atomic async checkpoints every ``save_every`` steps
(params, optimizer state, DSSP ring buffer, data cursor); ``--resume``
restores all of it and continues bit-exact w.r.t. the data stream.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.core import dssp_spmd
from repro.data.synthetic import DataConfig, batches, loss_floor
from repro.models import registry
from repro.models.sharding import use_rules
from repro.optim import make_optimizer
from repro.optim.compression import make_compressor


@dataclasses.dataclass
class TrainLog:
    steps: List[int] = dataclasses.field(default_factory=list)
    losses: List[float] = dataclasses.field(default_factory=list)
    delays: List[int] = dataclasses.field(default_factory=list)
    step_times: List[float] = dataclasses.field(default_factory=list)

    def record(self, step, loss, delay, dt):
        self.steps.append(step)
        self.losses.append(float(loss))
        self.delays.append(int(delay))
        self.step_times.append(dt)


class Trainer:
    def __init__(self, cfg, data_cfg: DataConfig, *, sync: str = "dssp",
                 s_lower: int = 0, s_upper: int = 3, lr: float = 3e-3,
                 optimizer: Optional[str] = None,
                 checkpoint_dir: Optional[str] = None, keep: int = 3,
                 save_every: int = 50, rules=None,
                 compressor: str = "none",
                 collective_time_fn: Optional[Callable[[], float]] = None,
                 staleness_damping: bool = True):
        if sync not in ("bsp", "ssp", "dssp"):
            raise ValueError(f"sync {sync!r} not trainable in SPMD mode "
                             "(asp exists in the PS layer only)")
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.sync = sync
        self.s_lower, self.s_upper = s_lower, s_upper
        self.use_pipeline = sync in ("ssp", "dssp")
        self.rules = rules
        self.controller = dssp_spmd.DsspScheduleController(
            max(s_lower, 1) if self.use_pipeline else 0, s_upper)
        self.collective_time_fn = collective_time_fn or (lambda: 0.0)
        self.compressor = make_compressor(compressor)
        self.log = TrainLog()

        opt_kw = {}
        opt_name = optimizer or cfg.optimizer
        if opt_name in ("momentum", "adamw", "sgd"):
            opt_kw["staleness_damping"] = staleness_damping
        self.opt = make_optimizer(opt_name, lr, **opt_kw)
        self.loss_fn = registry.loss_fn(cfg)

        self.params = registry.init_params(cfg, jax.random.PRNGKey(0))
        self.opt_state = self.opt.init(self.params)
        if self.use_pipeline:
            grads_like = jax.eval_shape(lambda p: p, self.params)
            zero = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), grads_like)
            self.pipeline = dssp_spmd.init_pipeline(zero, s_upper + 1)
        else:
            self.pipeline = ()
        # identity compressor: keep the jitted step's error operand empty
        # instead of threading a dead params-sized buffer through it
        self.err_state = (self.compressor.init_error(self.params)
                          if self.compressor.name != "none" else ())
        self.step_idx = 0

        self.ckpt = (CheckpointManager(checkpoint_dir, keep=keep)
                     if checkpoint_dir else None)
        self.save_every = save_every
        self._jit_step = self._build_step()

    # ------------------------------------------------------------ step fn
    def _build_step(self):
        opt, loss_fn = self.opt, self.loss_fn
        use_pipeline = self.use_pipeline
        compressor = self.compressor
        rules = self.rules

        def step(params, opt_state, pipeline, err, batch, delay):
            with use_rules(rules):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
                if compressor.name != "none":
                    grads, err = compressor.apply(grads, err)
                if use_pipeline:
                    grads, valid, pipeline = dssp_spmd.push_pop(
                        pipeline, grads, delay)
                    staleness, lr_scale = delay, valid
                else:
                    staleness, lr_scale = 0, 1.0
                params, opt_state = opt.update(
                    grads, opt_state, params, staleness=staleness,
                    lr_scale=lr_scale)
            return params, opt_state, pipeline, err, loss

        return jax.jit(step, donate_argnums=(0, 1, 2, 3))

    # ------------------------------------------------------------ resume
    def resume(self) -> bool:
        if self.ckpt is None:
            return False
        state_like = {"params": self.params, "opt": self.opt_state,
                      "pipeline": self.pipeline}
        got = self.ckpt.restore_latest(state_like)
        if got is None:
            return False
        step, tree, extras = got
        self.params = tree["params"]
        self.opt_state = jax.tree_util.tree_map(
            jnp.asarray, tree["opt"])
        self.pipeline = jax.tree_util.tree_map(
            jnp.asarray, tree["pipeline"])
        self.step_idx = extras["next_step"]
        return True

    # ------------------------------------------------------------ train
    def train(self, n_steps: int, *, log_every: int = 10,
              verbose: bool = False) -> TrainLog:
        it = batches(self.cfg, self.data_cfg, start_step=self.step_idx)
        end = self.step_idx + n_steps
        while self.step_idx < end:
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            if self.sync == "dssp":
                delay = self.controller.delay()
            elif self.sync == "ssp":
                delay = max(self.s_lower, 1)
            else:
                delay = 0
            t0 = time.monotonic()
            (self.params, self.opt_state, self.pipeline,
             self.err_state, loss) = self._jit_step(
                self.params, self.opt_state, self.pipeline,
                self.err_state, batch, jnp.int32(delay))
            loss = jax.block_until_ready(loss)
            dt = time.monotonic() - t0
            self.controller.observe(dt, self.collective_time_fn())
            self.log.record(self.step_idx, loss, delay, dt)
            if verbose and self.step_idx % log_every == 0:
                print(f"step {self.step_idx:5d} loss {float(loss):.4f} "
                      f"delay {delay} dt {dt * 1e3:.0f}ms")
            self.step_idx += 1
            if (self.ckpt is not None and self.save_every
                    and self.step_idx % self.save_every == 0):
                self.save()
        if self.ckpt is not None:
            self.save()
            self.ckpt.wait()
        return self.log

    def save(self) -> None:
        self.ckpt.save(self.step_idx, {
            "params": self.params, "opt": self.opt_state,
            "pipeline": self.pipeline,
        }, extras={"next_step": self.step_idx,
                   "data_seed": self.data_cfg.seed})


# ----------------------------------------------------- sharded-PS path
def train_ps(cfg, data_cfg: DataConfig, *, sync: str, n_steps: int,
             lr: float, n_shards: int, n_workers: int = 4,
             s_lower: int = 0, s_upper: int = 3,
             compressor: str = "none", apply_mode: str = "tree",
             gating: str = "sharded", straggler: float = 1.0,
             wire_format: str = "tree", transport: str = "inproc",
             arch: Optional[str] = None, smoke: bool = True,
             verbose: bool = False):
    """Real-training path through the sharded threaded parameter server.

    ``n_workers`` threads run the same jitted value_and_grad step on
    worker-seeded shards of the synthetic stream and push raw gradients
    into a ``ShardedParameterServer`` (``--ps-shards N``); per-shard wire
    compression and the batched fused apply are selectable.  This is the
    Algorithm-1 execution model (the SPMD ``Trainer`` is the
    delayed-gradient emulation of it).

    ``wire_format='packed'`` (requires/implies ``apply_mode='fused'``)
    runs the zero-repack hot path: each worker's jitted step takes the
    server's packed (rows, 512) wire buffer, unpacks it to params as
    in-jit views, differentiates, and re-packs the gradients into its
    own donated wire buffer — the pytree<->wire boundary is crossed once
    per direction per step, and the server never repacks.  The tree
    ``compressor`` becomes the server's fused wire compression.

    ``transport='tcp'``/``'shmem'`` replaces the worker THREADS with
    spawned worker PROCESSES (``repro.launch.proc_pool``) that speak the
    packed frame protocol to a ``PSServerEndpoint`` — the same packed
    buffer, now as bytes on a real wire, with ``straggler`` producing a
    genuinely slower separate interpreter.  Implies the packed wire
    format; ``arch`` must name the config so workers can rebuild it.
    """
    from repro.core.policies import make_policy_factory
    from repro.data.synthetic import batches as data_batches
    from repro.ps.server import ServerOptimizer
    from repro.ps.sharded import ShardedParameterServer
    from repro.ps.worker import PSWorker, run_cluster

    if wire_format not in ("tree", "packed"):
        raise ValueError(f"unknown wire format {wire_format!r}")
    if transport not in ("inproc", "tcp", "shmem"):
        raise ValueError(f"unknown transport {transport!r}")
    if transport != "inproc":
        wire_format = "packed"  # frames carry the packed buffer only
    packed = wire_format == "packed"
    if packed and apply_mode == "tree":
        apply_mode = "fused"   # packed pushes fold through the kernel

    loss_fn = registry.loss_fn(cfg)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))

    def worker_batches(w: int):
        wcfg = dataclasses.replace(data_cfg, seed=data_cfg.seed + 1 + w)
        for b in data_batches(cfg, wcfg):
            yield {k: jnp.asarray(v) for k, v in b.items()}

    policy_factory = make_policy_factory(
        sync, n_workers=n_workers, staleness=max(s_lower, 1),
        s_lower=s_lower, s_upper=s_upper)
    # Where compression happens depends on where the wire is.  On the
    # process transports, int8 compresses the FRAMES (bytes actually
    # shrink on the OS wire; the codec dequantizes on receipt, so the
    # server must not quantize again).  In-process, it is the server's
    # fused error-feedback pass, as before.  topk has no frame-level
    # encoding and stays server-side on every path.
    frame_compress = ("int8" if transport != "inproc"
                      and compressor == "int8" else "none")
    wire_compression = (None if frame_compress != "none"
                        else compressor if packed else None)
    server = ShardedParameterServer(
        params, policy_factory, lambda: ServerOptimizer(lr=lr),
        n_workers, n_shards, gating=gating, apply_mode=apply_mode,
        compressor=None if packed else make_compressor(compressor),
        wire_compression=wire_compression)
    if verbose:
        print(server.plan.describe())

    if transport != "inproc":
        # ---- process-isolated path: bytes on a real wire ----
        from repro.launch.proc_pool import (ProcessWorkerPool, WorkerTask,
                                            raise_on_failure)
        from repro.transport import PSServerEndpoint, make_transport

        if arch is None:
            raise ValueError("transport workers rebuild the model from its "
                             "config name — pass arch=")
        endpoint = PSServerEndpoint(server)
        tp = make_transport(transport, n_workers=n_workers)
        tp.serve(endpoint)
        iters = max(1, n_steps // n_workers)
        task = WorkerTask(arch=arch, n_shards=n_shards, n_iterations=iters,
                          smoke=smoke,
                          seq_len=data_cfg.seq_len,
                          global_batch=data_cfg.global_batch,
                          data_seed=data_cfg.seed,
                          compress=frame_compress)
        slowdowns = [straggler if w == n_workers - 1 else 1.0
                     for w in range(n_workers)]
        pool = ProcessWorkerPool(tp.address(), task, n_workers,
                                 slowdowns=slowdowns)
        pool.start()
        try:
            results = pool.join(timeout=1200.0, endpoint=endpoint)
        finally:
            server.stop()
            tp.shutdown()
            pool.terminate()
        raise_on_failure(results)
        if verbose:
            m = server.metrics
            done = sum(r.iterations_done for r in results)
            print(f"workers={n_workers} ({transport}) iterations={done} "
                  f"pushes={m.total_pushes} applied_shard_updates="
                  f"{server.version} max_stale={m.max_staleness}")
        return server

    if packed:
        plan = server.plan

        @functools.partial(jax.jit, donate_argnums=(1,))
        def _packed_step(wire_p, wire_g_prev, batch):
            p = plan.unpack(wire_p)
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(p, batch)
            # Write the packed grads INTO the donated buffer: the output
            # aliases wire_g_prev's memory.  A plain `return plan.pack(...)`
            # would leave wire_g_prev unread, and jit's keep_unused=False
            # prunes unread args before donation can apply.
            return wire_g_prev.at[:].set(plan.pack(grads)), {"loss": loss}

        def make_step():
            # Each worker owns ONE gradient wire buffer, donated back
            # into the jit every iteration (the output reuses its
            # memory) — the params wire buffer is the server's shared
            # snapshot and must NOT be donated.
            from repro.wireformat import WIRE_LANES
            layout = plan.wire_layout()
            state = {"g": jnp.zeros((layout.total_rows, WIRE_LANES),
                                    layout.dtype)}

            def step(wire_p, batch):
                g, aux = _packed_step(wire_p, state["g"], batch)
                state["g"] = g
                return g, aux

            return step
    else:
        @jax.jit
        def _tree_step(p, batch):
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(p, batch)
            return grads, {"loss": loss}

        def make_step():
            return _tree_step

    iters = max(1, n_steps // n_workers)
    workers = [PSWorker(w, server, make_step(), worker_batches(w), iters,
                        speed_factor=(straggler if w == n_workers - 1
                                      else 1.0),
                        wire_format=wire_format,
                        loss_from_aux=lambda a: float(a["loss"]))
               for w in range(n_workers)]
    run_cluster(server, workers, timeout=1200.0)
    if verbose:
        m = server.metrics
        print(f"pushes={m.total_pushes} applied_shard_updates="
              f"{server.version} wait_s={m.total_wait:.2f} "
              f"max_stale={m.max_staleness}")
        for sm in server.shard_metrics():
            print(f"  {sm.policy}: max_stale={sm.max_staleness} "
                  f"wait_s={sm.total_wait:.2f}")
    return server


# -------------------------------------------------------------------- CLI
def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (full configs need a TPU mesh)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--sync", default="dssp",
                    choices=["bsp", "ssp", "dssp", "asp"],
                    help="asp is valid only with --ps-shards (PS layer)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--s-lower", type=int, default=0)
    ap.add_argument("--s-upper", type=int, default=3)
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ps-shards", type=int, default=0, metavar="N",
                    help="train through a sharded threaded parameter "
                         "server with N shards (0 = SPMD pipeline path)")
    ap.add_argument("--ps-workers", type=int, default=4)
    ap.add_argument("--ps-apply", default="tree", choices=["tree", "fused"],
                    help="per-shard apply: tree_map or one fused Pallas "
                         "launch over the packed shard (fused runs in "
                         "interpret mode on CPU — correctness validation "
                         "only; native speed needs TPU)")
    ap.add_argument("--ps-wire", default="tree", choices=["tree", "packed"],
                    help="push/pull wire format: per-leaf pytrees, or the "
                         "zero-repack packed (rows, 512) buffer (packed "
                         "implies --ps-apply fused; --compress becomes the "
                         "fused wire compression)")
    ap.add_argument("--ps-gating", default="sharded",
                    choices=["sharded", "global"])
    ap.add_argument("--ps-straggler", type=float, default=1.0,
                    help="speed factor of the last PS worker (>1 = slower)")
    ap.add_argument("--transport", default="inproc",
                    choices=["inproc", "tcp", "shmem"],
                    help="PS worker isolation: inproc = threads sharing "
                         "the heap (the classic path); tcp/shmem = spawned "
                         "worker PROCESSES pushing packed frames over a "
                         "real wire (implies --ps-wire packed; enables "
                         "--ps-shards 1 if unset)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)

    if args.transport != "inproc" and args.ps_shards < 1:
        args.ps_shards = 1  # process transports live in the PS layer

    if args.ps_shards >= 1:
        ignored = [flag for flag, on in (
            ("--checkpoint-dir", bool(args.checkpoint_dir)),
            ("--resume", args.resume),
            ("--optimizer", args.optimizer is not None)) if on]
        if ignored:
            print(f"warning: {', '.join(ignored)} only apply to the SPMD "
                  "path and are ignored with --ps-shards (the PS server "
                  "optimizer is SGD/momentum; checkpointing the sharded "
                  "store is future work)")
        print(f"arch={cfg.name} sync={args.sync} "
              f"ps_shards={args.ps_shards} workers={args.ps_workers} "
              f"params={registry.count_params(cfg):,}")
        server = train_ps(cfg, data_cfg, sync=args.sync,
                          n_steps=args.steps, lr=args.lr,
                          n_shards=args.ps_shards,
                          n_workers=args.ps_workers,
                          s_lower=args.s_lower, s_upper=args.s_upper,
                          compressor=args.compress,
                          apply_mode=args.ps_apply,
                          gating=args.ps_gating,
                          straggler=args.ps_straggler,
                          wire_format=args.ps_wire,
                          transport=args.transport,
                          arch=args.arch, smoke=args.smoke,
                          verbose=True)
        losses = [l for _, _, l in server.metrics.loss_trajectory]
        if losses:
            print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
        return

    trainer = Trainer(cfg, data_cfg, sync=args.sync, lr=args.lr,
                      optimizer=args.optimizer,
                      s_lower=args.s_lower, s_upper=args.s_upper,
                      compressor=args.compress,
                      checkpoint_dir=args.checkpoint_dir or None,
                      save_every=args.save_every)
    if args.resume:
        resumed = trainer.resume()
        print(f"resume: {'ok, at step ' + str(trainer.step_idx) if resumed else 'no checkpoint'}")
    print(f"arch={cfg.name} sync={args.sync} params="
          f"{registry.count_params(cfg):,} "
          f"loss_floor~{loss_floor(data_cfg):.3f}")
    log = trainer.train(args.steps, verbose=True)
    print(f"final loss {log.losses[-1]:.4f} "
          f"(first {log.losses[0]:.4f}); mean delay "
          f"{np.mean(log.delays):.2f}")


if __name__ == "__main__":
    main()
